"""L2 model tests: flat-param accounting, kernel/oracle parity, learning
signal sanity, and SPSA delta consistency against true directional
derivatives."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.models import cnn, common, lm, vit

REG = M.registry()


def _batch(v, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or v.batch
    if v.kind == "image":
        c = v.cfg
        x = jnp.asarray(rng.normal(size=(b, c.img, c.img, c.channels)) * 0.5, jnp.float32)
        y = jnp.asarray(rng.integers(0, v.classes, (b,)), jnp.int32)
        mask = jnp.ones((b,), jnp.float32)
    else:
        c = v.cfg
        x = jnp.asarray(rng.integers(0, c.vocab, (b, c.seq)), jnp.int32)
        y = jnp.asarray(rng.integers(0, c.vocab, (b, c.seq)), jnp.int32)
        mask = jnp.ones((b, c.seq), jnp.float32)
    return x, y, mask


@pytest.mark.parametrize("name", sorted(REG))
def test_specs_consistent(name):
    v = REG[name]
    specs = v.specs
    names = [s.name for s in specs]
    assert len(names) == len(set(names)), "duplicate param names"
    assert v.dim == sum(s.size for s in specs)
    for s in specs:
        assert s.size > 0
        assert s.kind in {"conv", "dense", "bias", "norm_scale", "norm_bias", "embed", "pos"}
        if s.kind in {"conv", "dense", "embed", "pos"}:
            assert s.fan_in > 0


@pytest.mark.parametrize("name", ["cnn10", "vit10", "lm"])
def test_fwd_shapes_and_reader_completion(name):
    v = REG[name]
    flat = jnp.asarray(common.init_flat(v.specs, 0))
    x, y, mask = _batch(v, batch=4 if v.kind == "image" else None)
    logits, y2, m2 = v.apply_fn()(flat, x, y, mask)  # ParamReader asserts completion
    assert logits.shape[-1] == v.classes
    assert logits.shape[0] == y2.shape[0] == m2.shape[0]
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["cnn10", "vit10", "lm"])
def test_kernel_oracle_parity(name):
    """Pallas forward path must numerically match the differentiable oracle
    path — this is what licenses mixing them across artifacts."""
    v = REG[name]
    flat = jnp.asarray(common.init_flat(v.specs, 1))
    x, y, mask = _batch(v, seed=2, batch=4 if v.kind == "image" else None)
    lk, yk, mk = v.apply_fn()(flat, x, y, mask, use_kernel=True)
    lo, yo, mo = v.apply_fn()(flat, x, y, mask, use_kernel=False)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lo), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yo))


def test_ce_loss_sum_masking():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0], [5.0, 0.0]])
    y = jnp.asarray([0, 1, 1])
    full, corr_full = common.ce_loss_sum(logits, y, jnp.asarray([1.0, 1.0, 1.0]))
    part, corr_part = common.ce_loss_sum(logits, y, jnp.asarray([1.0, 1.0, 0.0]))
    assert float(corr_full) == 2.0 and float(corr_part) == 2.0
    assert float(part) < float(full)
    zero, corr0 = common.ce_loss_sum(logits, y, jnp.zeros(3))
    assert float(zero) == 0.0 and float(corr0) == 0.0


def test_ce_loss_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(16, 10)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 16), jnp.int32)
    loss, _ = common.ce_loss_sum(logits, y, jnp.ones(16))
    ref = -np.sum(
        np.log(np.exp(logits)[np.arange(16), y] / np.exp(logits).sum(-1))
    )
    np.testing.assert_allclose(float(loss), ref, rtol=1e-5)


@pytest.mark.parametrize("name", ["cnn10", "lm"])
def test_sgd_step_reduces_loss(name):
    v = REG[name]
    flat = jnp.asarray(common.init_flat(v.specs, 3))
    x, y, mask = _batch(v, seed=4, batch=8 if v.kind == "image" else None)
    ap = v.apply_fn()
    step = jax.jit(common.make_sgd_step(ap))
    fwd = jax.jit(common.make_fwd_loss(ap))
    l0, _ = fwd(flat, x, y, mask)
    for _ in range(5):
        flat, _ = step(flat, x, y, mask, jnp.float32(0.05))
    l1, _ = fwd(flat, x, y, mask)
    assert float(l1) < float(l0), f"loss {float(l0)} -> {float(l1)}"


def test_sgd_step_respects_mask():
    """Padding rows must not influence the gradient."""
    v = REG["cnn10"]
    flat = jnp.asarray(common.init_flat(v.specs, 5))
    x, y, _ = _batch(v, seed=6, batch=8)
    mask = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    step = jax.jit(common.make_sgd_step(v.apply_fn()))
    out1, _ = step(flat, x, y, mask, jnp.float32(0.1))
    # corrupt the padding rows; result must be identical
    x2 = x.at[4:].set(123.0)
    y2 = y.at[4:].set(0)
    out2, _ = step(flat, x2, y2, mask, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-6)


def test_zo_delta_tracks_directional_derivative():
    """ΔL/(2c) must approximate zᵀ∇L: SPSA's core identity (eq. 2)."""
    v = REG["cnn10"]
    flat = jnp.asarray(common.init_flat(v.specs, 7))
    x, y, mask = _batch(v, seed=8, batch=8)
    ap = v.apply_fn()
    c = 1e-3

    def mean_loss(w):
        logits, y2, m2 = ap(w, x, y, mask, use_kernel=False)
        s, _ = common.ce_loss_sum(logits, y2, m2)
        return s

    grad = jax.grad(mean_loss)(flat)
    zo = jax.jit(common.make_zo_delta(ap))
    for seed in (0, 1, 2):
        key = jax.random.PRNGKey(seed)
        bits = jax.random.bits(key, shape=flat.shape, dtype=jnp.uint32)
        z = 1.0 - 2.0 * (bits & jnp.uint32(1)).astype(jnp.float32)
        dl, msum = zo(flat, jnp.int32(seed), jnp.float32(c), x, y, mask)
        assert float(msum) == 8.0
        # (a) mechanics parity: the in-graph ΔL must equal the oracle-path
        # central difference at the identical perturbed weights.
        manual = mean_loss(flat + c * z) - mean_loss(flat - c * z)
        assert abs(float(dl) - float(manual)) < 5e-3 * max(1.0, abs(float(manual)))
        # (b) SPSA identity: ΔL/(2c) ≈ zᵀ∇L up to curvature (|cz|₂≈0.4 here,
        # so allow a generous band — sign and scale must agree).
        want = float(jnp.vdot(z, grad))
        got = float(dl) / (2 * c)
        assert got * want > 0, (seed, got, want)
        assert abs(got - want) < 0.5 * max(20.0, abs(want)), (seed, got, want)


def test_zo_delta_zero_coeff_is_zero():
    v = REG["lm"]
    flat = jnp.asarray(common.init_flat(v.specs, 9))
    x, y, mask = _batch(v, seed=10)
    zo = jax.jit(common.make_zo_delta(v.apply_fn()))
    dl, _ = zo(flat, jnp.int32(5), jnp.float32(0.0), x, y, mask)
    assert float(dl) == 0.0


def test_init_flat_statistics():
    specs = REG["cnn10"].specs
    flat = common.init_flat(specs, 0)
    offset = 0
    for s in specs:
        part = flat[offset : offset + s.size]
        offset += s.size
        if s.fan_in == 0:
            assert np.all(part == s.fill)
        elif s.size >= 256:
            want = np.sqrt(2.0 / s.fan_in)
            assert abs(part.std() - want) / want < 0.25, s.name
    assert offset == flat.size


def test_init_flat_seed_determinism():
    specs = REG["lm"].specs
    a = common.init_flat(specs, 4)
    b = common.init_flat(specs, 4)
    c = common.init_flat(specs, 5)
    np.testing.assert_array_equal(a, b)
    assert np.any(a != c)


def test_half_width_is_smaller_and_sliceable():
    full, half = REG["cnn10"], REG["cnn10_half"]
    assert half.dim < full.dim / 2
    sf = {s.name: s.shape for s in full.specs}
    sh = {s.name: s.shape for s in half.specs}
    assert set(sf) == set(sh), "HeteroFL pairing requires identical tensor names"
    for name, shape in sf.items():
        for a, b in zip(sh[name], shape):
            assert a <= b, (name, sh[name], shape)


@pytest.mark.parametrize("name", sorted(REG))
def test_act_sizes_positive(name):
    v = REG[name]
    sizes = v.module.act_sizes(v.cfg)
    assert all(s > 0 for s in sizes)
    summary = M.act_summary(v)
    assert summary["max"] <= summary["sum"]


def test_group_norm_normalizes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(3.0, 5.0, size=(2, 8, 8, 16)), jnp.float32)
    out = common.group_norm(x, jnp.ones(16), jnp.zeros(16), groups=8)
    g = np.asarray(out).reshape(2, 8, 8, 8, 2)
    assert abs(g.mean(axis=(1, 2, 4))).max() < 1e-4
    assert abs(g.std(axis=(1, 2, 4)) - 1).max() < 1e-3


def test_causal_attention_no_future_leak():
    """Perturbing tokens at position t must not change logits before t."""
    v = REG["lm"]
    flat = jnp.asarray(common.init_flat(v.specs, 11))
    x, y, mask = _batch(v, seed=12)
    logits1, _, _ = v.apply_fn()(flat, x, y, mask, use_kernel=False)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % v.cfg.vocab)
    logits2, _, _ = v.apply_fn()(flat, x2, y, mask, use_kernel=False)
    t = v.cfg.seq
    l1 = np.asarray(logits1).reshape(v.batch, t, -1)
    l2 = np.asarray(logits2).reshape(v.batch, t, -1)
    np.testing.assert_allclose(l1[:, : t - 1], l2[:, : t - 1], rtol=1e-5, atol=1e-6)
    assert np.abs(l1[:, -1] - l2[:, -1]).max() > 1e-6
