"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

hypothesis sweeps shapes/dtypes/activations/block sizes; assert_allclose
against ref.py. interpret=True everywhere (CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, perturb, ref

settings.register_profile("ci", deadline=None, max_examples=40)
settings.load_profile("ci")


def _np_rng(seed):
    return np.random.default_rng(seed)


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 96))
    k = draw(st.integers(1, 160))
    n = draw(st.integers(1, 96))
    act = draw(st.sampled_from(["none", "relu", "gelu"]))
    dtype = draw(st.sampled_from([np.float32, jnp.bfloat16]))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, act, dtype, seed


@given(matmul_case())
def test_matmul_matches_ref(case):
    m, k, n, act, dtype, seed = case
    rng = _np_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    b = jnp.asarray(rng.normal(size=(n,)), dtype)
    got = matmul.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


@pytest.mark.parametrize("block", [(8, 128, 8), (16, 128, 16), (64, 128, 64), (64, 256, 64)])
def test_matmul_block_invariance(block):
    """Result must not depend on the tiling choice."""
    rng = _np_rng(7)
    x = jnp.asarray(rng.normal(size=(70, 130)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(130, 50)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(50,)), jnp.float32)
    base = ref.matmul_bias_act(x, w, b, act="relu")
    got = matmul.matmul_bias_act(x, w, b, act="relu", block=block)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 3))
    b = jnp.zeros((3,))
    with pytest.raises(AssertionError):
        matmul.matmul_bias_act(x, w, b)


def test_matmul_zero_padding_exact():
    """Padding path: K not a multiple of bk must still be exact (zeros
    contribute nothing to the contraction)."""
    rng = _np_rng(3)
    x = jnp.asarray(rng.normal(size=(9, 129)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(129, 7)), jnp.float32)
    b = jnp.zeros((7,), jnp.float32)
    np.testing.assert_allclose(
        matmul.matmul_bias_act(x, w, b),
        ref.matmul_bias_act(x, w, b),
        rtol=1e-5,
        atol=1e-5,
    )


def test_vmem_estimate_positive():
    assert matmul.vmem_bytes() > 0
    assert matmul.vmem_bytes((8, 128, 8)) < matmul.vmem_bytes((128, 512, 128))
    assert 0.0 < matmul.mxu_utilization(33, 70, 17) <= 1.0
    assert matmul.mxu_utilization(64, 128, 64) == 1.0


@st.composite
def perturb_case(draw):
    d = draw(st.integers(1, 200_000))
    coeff = draw(st.floats(-1.0, 1.0, allow_nan=False))
    block = draw(st.sampled_from([128, 4096, 65536]))
    seed = draw(st.integers(0, 2**31 - 1))
    return d, coeff, block, seed


@given(perturb_case())
@settings(deadline=None, max_examples=25)
def test_perturb_matches_ref(case):
    d, coeff, block, seed = case
    rng = _np_rng(seed)
    w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    bits = jnp.asarray(rng.integers(0, 2**32, size=(d,), dtype=np.uint32))
    got = perturb.rademacher_axpy(w, bits, coeff, block=block)
    want = ref.rademacher_axpy(w, bits, coeff)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_perturb_two_sided_symmetry():
    """w+cz and w-cz must bracket w exactly: (p+ + p-)/2 == w."""
    rng = _np_rng(11)
    w = jnp.asarray(rng.normal(size=(10_001,)), jnp.float32)
    bits = jnp.asarray(rng.integers(0, 2**32, size=(10_001,), dtype=np.uint32))
    p_plus = perturb.rademacher_axpy(w, bits, 0.25)
    p_minus = perturb.rademacher_axpy(w, bits, -0.25)
    np.testing.assert_allclose((np.asarray(p_plus) + np.asarray(p_minus)) / 2, w, rtol=0, atol=1e-6)
    # and the step magnitude is 0.25 everywhere (up to f32 rounding of w±c)
    np.testing.assert_allclose(np.abs(np.asarray(p_plus) - np.asarray(w)), 0.25, rtol=1e-5)


def test_perturb_from_seed_deterministic():
    w = jnp.zeros((5000,), jnp.float32)
    a = perturb.perturb_from_seed(w, jnp.int32(42), 1.0)
    b = perturb.perturb_from_seed(w, jnp.int32(42), 1.0)
    c = perturb.perturb_from_seed(w, jnp.int32(43), 1.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.mean(np.asarray(a) != np.asarray(c)) > 0.3  # different seed, different z


def test_perturb_from_seed_is_rademacher():
    """Signs should be ±1 balanced (law check, not just mechanics)."""
    w = jnp.zeros((100_000,), jnp.float32)
    z = np.asarray(perturb.perturb_from_seed(w, jnp.int32(0), 1.0))
    assert set(np.unique(z)) == {-1.0, 1.0}
    assert abs(z.mean()) < 0.02  # ~3 sigma for n=1e5


def test_hbm_traffic_model():
    assert perturb.hbm_traffic_bytes(1000) == 12_000
