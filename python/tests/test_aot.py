"""AOT pipeline tests: manifest integrity + HLO text well-formedness."""

import json
import os

import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build one small model into a temp dir (module-scoped: lowering is
    the slow part)."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, names=["lm"], verbose=False)
    return out, manifest


def test_manifest_offsets_contiguous(built):
    _, manifest = built
    entry = manifest["models"]["lm"]
    offset = 0
    for p in entry["params"]:
        assert p["offset"] == offset
        assert p["size"] == int__prod(p["shape"])
        offset += p["size"]
    assert offset == entry["dim"]


def int__prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def test_manifest_matches_registry(built):
    _, manifest = built
    v = M.registry()["lm"]
    entry = manifest["models"]["lm"]
    assert entry["dim"] == v.dim
    assert entry["batch"] == v.batch
    assert entry["classes"] == v.classes
    assert set(entry["artifacts"]) == {"fwd_loss", "sgd_step", "zo_delta"}


def test_hlo_text_files_exist_and_parse_header(built):
    out, manifest = built
    for fname in manifest["models"]["lm"]["artifacts"].values():
        path = os.path.join(out, fname)
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text
        # the interchange gotcha: must be text, never a serialized proto
        assert "\x00" not in text


def test_manifest_json_round_trips(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest
    assert loaded["version"] == 1


def test_registry_names_stable():
    names = set(M.registry())
    assert names == {"cnn10", "cnn10_half", "cnn100", "cnn100_half", "vit10", "lm"}


def test_entry_points_have_expected_arity():
    v = M.registry()["cnn10"]
    eps = v.entry_points()
    assert len(eps["fwd_loss"][1]) == 4
    assert len(eps["sgd_step"][1]) == 5
    assert len(eps["zo_delta"][1]) == 6
