"""Layer-2 registry: named model variants and their AOT artifact recipes.

Each variant binds an architecture config to fixed AOT shapes (batch size,
input shape) and exposes the three lowerable entry points:

  fwd_loss(flat, x, y, mask)          -> (loss_sum, correct)      [Pallas path]
  sgd_step(flat, x, y, mask, lr)      -> (flat', loss_sum)        [oracle path]
  zo_delta(flat, seed, coeff, x, y, mask) -> (delta_l, mask_sum)  [Pallas path]

The Rust coordinator selects variants by name via artifacts/manifest.json.
"""

import dataclasses
import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .models import cnn, common, lm, vit


@dataclasses.dataclass(frozen=True)
class Variant:
    """A model architecture pinned to concrete AOT shapes."""

    name: str
    kind: str  # "image" | "lm"
    cfg: object
    module: object
    batch: int

    @property
    def specs(self):
        return self.module.specs(self.cfg)

    @property
    def dim(self) -> int:
        return common.total_dim(self.specs)

    @property
    def classes(self) -> int:
        return self.cfg.classes if self.kind == "image" else self.cfg.vocab

    def apply_fn(self) -> Callable:
        return functools.partial(self.module.apply, self.cfg)

    def input_shapes(self) -> Dict[str, Tuple]:
        """ShapeDtypeStructs for (x, y, mask) at the AOT batch size."""
        b = self.batch
        f32, i32 = jnp.float32, jnp.int32
        if self.kind == "image":
            c = self.cfg
            return {
                "x": jax.ShapeDtypeStruct((b, c.img, c.img, c.channels), f32),
                "y": jax.ShapeDtypeStruct((b,), i32),
                "mask": jax.ShapeDtypeStruct((b,), f32),
            }
        c = self.cfg
        return {
            "x": jax.ShapeDtypeStruct((b, c.seq), i32),
            "y": jax.ShapeDtypeStruct((b, c.seq), i32),
            "mask": jax.ShapeDtypeStruct((b, c.seq), f32),
        }

    def entry_points(self) -> Dict[str, Tuple[Callable, Tuple]]:
        """name -> (callable, example_args) for jax.jit(...).lower()."""
        ap = self.apply_fn()
        shp = self.input_shapes()
        flat = jax.ShapeDtypeStruct((self.dim,), jnp.float32)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        seed = jax.ShapeDtypeStruct((), jnp.int32)
        x, y, mask = shp["x"], shp["y"], shp["mask"]
        return {
            "fwd_loss": (common.make_fwd_loss(ap), (flat, x, y, mask)),
            "sgd_step": (common.make_sgd_step(ap), (flat, x, y, mask, scalar)),
            "zo_delta": (common.make_zo_delta(ap), (flat, seed, scalar, x, y, mask)),
        }


def registry() -> Dict[str, Variant]:
    """All AOT-built variants. cnn*_half are the HeteroFL sub-networks."""
    out = {}

    def add(v):
        out[v.name] = v

    add(Variant("cnn10", "image", cnn.Config(width=16, classes=10), cnn, 64))
    add(Variant("cnn10_half", "image", cnn.Config(width=8, classes=10), cnn, 64))
    add(Variant("cnn100", "image", cnn.Config(width=16, classes=100), cnn, 64))
    add(Variant("cnn100_half", "image", cnn.Config(width=8, classes=100), cnn, 64))
    add(Variant("vit10", "image", vit.Config(classes=10), vit, 64))
    add(Variant("lm", "lm", lm.Config(), lm, 16))
    return out


def act_summary(v: Variant) -> dict:
    return common.checkerboard_sizes(v.module.act_sizes(v.cfg))
