"""AOT pipeline: lower every model entry point to HLO *text* + manifest.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the HLO text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:
  cd python && python -m compile.aot --out ../artifacts [--models cnn10,lm]

Python runs ONLY here (and in pytest); the Rust binary is self-contained
once artifacts/ exists.
"""

import argparse
import json
import os
import time

import jax

from . import model as model_registry
from .models import common


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True so
    multi-output entry points become a single tuple the Rust side unpacks)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def manifest_entry(v) -> dict:
    params = []
    offset = 0
    for s in v.specs:
        params.append(
            {
                "name": s.name,
                "shape": list(s.shape),
                "offset": offset,
                "size": s.size,
                "fan_in": s.fan_in,
                "kind": s.kind,
                "fill": s.fill,
            }
        )
        offset += s.size
    shp = v.input_shapes()
    return {
        "dim": v.dim,
        "batch": v.batch,
        "kind": v.kind,
        "classes": v.classes,
        "input_shape": list(shp["x"].shape),
        "mask_shape": list(shp["mask"].shape),
        "act": model_registry.act_summary(v),
        "params": params,
        "artifacts": {},
    }


def build(out_dir: str, names=None, verbose: bool = True) -> dict:
    reg = model_registry.registry()
    names = names or sorted(reg)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "models": {}}
    for name in names:
        v = reg[name]
        entry = manifest_entry(v)
        for ep_name, (fn, args) in v.entry_points().items():
            t0 = time.time()
            text = lower_entry(fn, args)
            fname = f"{name}_{ep_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry["artifacts"][ep_name] = fname
            if verbose:
                print(
                    f"  {fname:32s} {len(text)/1e6:6.2f} MB  "
                    f"({time.time()-t0:5.1f}s, d={v.dim})"
                )
        manifest["models"][name] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {out_dir}/manifest.json ({len(names)} models)")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--models", default=None, help="comma-separated subset")
    args = p.parse_args()
    names = args.models.split(",") if args.models else None
    build(args.out, names)


if __name__ == "__main__":
    main()
