"""Tiny decoder-only language model (Figure 5 stand-in).

The paper's Fig. 5 compares FedKSeed with 200 local ZO steps against the
1-step modification on DataJuicer-1.3B / Natural-Instructions. That claim
is about optimizer dynamics at equal data, so we reproduce it with a
byte-vocabulary causal transformer on a synthetic Markov-grammar corpus
(DESIGN.md §2). Shares the attention/dense machinery with vit.py.
"""

import dataclasses
from typing import List

import jax.numpy as jnp

from . import common, vit
from .common import ParamSpec


@dataclasses.dataclass(frozen=True)
class Config:
    vocab: int = 64
    dim: int = 64
    depth: int = 2
    heads: int = 4
    mlp: int = 128
    seq: int = 64


def _ln_specs(prefix: str, d: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{prefix}.ln_scale", (d,), 0, "norm_scale", fill=1.0),
        ParamSpec(f"{prefix}.ln_bias", (d,), 0, "norm_bias", fill=0.0),
    ]


def specs(cfg: Config) -> List[ParamSpec]:
    d = cfg.dim
    out = [
        ParamSpec("embed", (cfg.vocab, d), d, "embed"),
        ParamSpec("pos", (cfg.seq, d), d, "pos"),
    ]
    for i in range(cfg.depth):
        p = f"blk{i}"
        out += [
            *_ln_specs(f"{p}.ln1", d),
            ParamSpec(f"{p}.qkv.w", (d, 3 * d), d, "dense"),
            ParamSpec(f"{p}.qkv.b", (3 * d,), 0, "bias"),
            ParamSpec(f"{p}.proj.w", (d, d), d, "dense"),
            ParamSpec(f"{p}.proj.b", (d,), 0, "bias"),
            *_ln_specs(f"{p}.ln2", d),
            ParamSpec(f"{p}.fc1.w", (d, cfg.mlp), d, "dense"),
            ParamSpec(f"{p}.fc1.b", (cfg.mlp,), 0, "bias"),
            ParamSpec(f"{p}.fc2.w", (cfg.mlp, d), cfg.mlp, "dense"),
            ParamSpec(f"{p}.fc2.b", (d,), 0, "bias"),
        ]
    out += [
        *_ln_specs("final", d),
        ParamSpec("head.w", (d, cfg.vocab), d, "dense"),
        ParamSpec("head.b", (cfg.vocab,), 0, "bias"),
    ]
    return out


def _block(r, p, h, cfg, use_kernel):
    d = cfg.dim
    b, t, _ = h.shape
    x1 = common.layer_norm(h, r.take(f"{p}.ln1.ln_scale"), r.take(f"{p}.ln1.ln_bias"))
    h = h + vit.attention(
        x1,
        r.take(f"{p}.qkv.w"),
        r.take(f"{p}.qkv.b"),
        r.take(f"{p}.proj.w"),
        r.take(f"{p}.proj.b"),
        cfg.heads,
        use_kernel,
        causal=True,
    )
    x2 = common.layer_norm(h, r.take(f"{p}.ln2.ln_scale"), r.take(f"{p}.ln2.ln_bias"))
    m = common.dense(x2.reshape(b * t, d), r.take(f"{p}.fc1.w"), r.take(f"{p}.fc1.b"), act="gelu", use_kernel=use_kernel)
    m = common.dense(m, r.take(f"{p}.fc2.w"), r.take(f"{p}.fc2.b"), use_kernel=use_kernel)
    return h + m.reshape(b, t, d)


def apply(cfg: Config, flat, x, y, mask, use_kernel: bool = True):
    """Next-token LM.

    x: [B, T] int32 tokens; y: [B, T] int32 targets (x shifted left, with
    padding positions arbitrary); mask: [B, T] f32. Returns flattened
    ([B*T, vocab] logits, [B*T] y, [B*T] mask) for the shared CE head.
    """
    r = common.ParamReader(flat, specs(cfg))
    b, t = x.shape
    embed = r.take("embed")
    h = jnp.take(embed, x, axis=0) + r.take("pos")[None]
    for i in range(cfg.depth):
        h = _block(r, f"blk{i}", h, cfg, use_kernel)
    h = common.layer_norm(h, r.take("final.ln_scale"), r.take("final.ln_bias"))
    logits = common.dense(
        h.reshape(b * t, cfg.dim), r.take("head.w"), r.take("head.b"), use_kernel=use_kernel
    )
    r.done()
    return logits, y.reshape(b * t), mask.reshape(b * t)


def act_sizes(cfg: Config) -> List[int]:
    t, d = cfg.seq, cfg.dim
    sizes = [t * d]
    for _ in range(cfg.depth):
        sizes += [t * 3 * d, cfg.heads * t * t, t * d, t * cfg.mlp, t * d]
    sizes += [t * cfg.vocab]
    return sizes
