"""ViT-tiny for 32x32x3 images (the paper's ViT-B/16 stand-in, Table 5).

Patch-4 embedding, learned positional embeddings, pre-LN transformer
blocks with mean-pool head. All projections route through the Layer-1
Pallas matmul kernel on forward-only graphs.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from . import common
from .common import ParamSpec


@dataclasses.dataclass(frozen=True)
class Config:
    dim: int = 64
    depth: int = 4
    heads: int = 4
    mlp: int = 128
    patch: int = 4
    classes: int = 10
    img: int = 32
    channels: int = 3

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels


def _ln_specs(prefix: str, d: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{prefix}.ln_scale", (d,), 0, "norm_scale", fill=1.0),
        ParamSpec(f"{prefix}.ln_bias", (d,), 0, "norm_bias", fill=0.0),
    ]


def specs(cfg: Config) -> List[ParamSpec]:
    d = cfg.dim
    out = [
        ParamSpec("embed.w", (cfg.patch_dim, d), cfg.patch_dim, "dense"),
        ParamSpec("embed.b", (d,), 0, "bias"),
        ParamSpec("pos", (cfg.tokens, d), d, "pos"),
    ]
    for i in range(cfg.depth):
        p = f"blk{i}"
        out += [
            *_ln_specs(f"{p}.ln1", d),
            ParamSpec(f"{p}.qkv.w", (d, 3 * d), d, "dense"),
            ParamSpec(f"{p}.qkv.b", (3 * d,), 0, "bias"),
            ParamSpec(f"{p}.proj.w", (d, d), d, "dense"),
            ParamSpec(f"{p}.proj.b", (d,), 0, "bias"),
            *_ln_specs(f"{p}.ln2", d),
            ParamSpec(f"{p}.fc1.w", (d, cfg.mlp), d, "dense"),
            ParamSpec(f"{p}.fc1.b", (cfg.mlp,), 0, "bias"),
            ParamSpec(f"{p}.fc2.w", (cfg.mlp, d), cfg.mlp, "dense"),
            ParamSpec(f"{p}.fc2.b", (d,), 0, "bias"),
        ]
    out += [
        *_ln_specs("final", d),
        ParamSpec("head.w", (d, cfg.classes), d, "dense"),
        ParamSpec("head.b", (cfg.classes,), 0, "bias"),
    ]
    return out


def attention(x, qkv_w, qkv_b, proj_w, proj_b, heads: int, use_kernel: bool, causal: bool = False):
    """Multi-head self-attention; projections via the Pallas dense layer."""
    b, t, d = x.shape
    hd = d // heads
    qkv = common.dense(x.reshape(b * t, d), qkv_w, qkv_b, use_kernel=use_kernel)
    qkv = qkv.reshape(b, t, 3, heads, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [b, t, h, hd]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    if causal:
        causal_mask = jnp.tril(jnp.ones((t, t), bool))
        scores = jnp.where(causal_mask[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b * t, d)
    out = common.dense(out, proj_w, proj_b, use_kernel=use_kernel)
    return out.reshape(b, t, d)


def _block(r, p, h, cfg, use_kernel):
    d = cfg.dim
    b, t, _ = h.shape
    x1 = common.layer_norm(h, r.take(f"{p}.ln1.ln_scale"), r.take(f"{p}.ln1.ln_bias"))
    h = h + attention(
        x1,
        r.take(f"{p}.qkv.w"),
        r.take(f"{p}.qkv.b"),
        r.take(f"{p}.proj.w"),
        r.take(f"{p}.proj.b"),
        cfg.heads,
        use_kernel,
    )
    x2 = common.layer_norm(h, r.take(f"{p}.ln2.ln_scale"), r.take(f"{p}.ln2.ln_bias"))
    m = common.dense(x2.reshape(b * t, d), r.take(f"{p}.fc1.w"), r.take(f"{p}.fc1.b"), act="gelu", use_kernel=use_kernel)
    m = common.dense(m, r.take(f"{p}.fc2.w"), r.take(f"{p}.fc2.b"), use_kernel=use_kernel)
    return h + m.reshape(b, t, d)


def apply(cfg: Config, flat, x, y, mask, use_kernel: bool = True):
    """x: [B, 32, 32, 3] -> (logits [B, classes], y, mask)."""
    r = common.ParamReader(flat, specs(cfg))
    b = x.shape[0]
    g = cfg.img // cfg.patch
    patches = x.reshape(b, g, cfg.patch, g, cfg.patch, cfg.channels)
    patches = patches.transpose(0, 1, 3, 2, 4, 5).reshape(b * cfg.tokens, cfg.patch_dim)
    h = common.dense(patches, r.take("embed.w"), r.take("embed.b"), use_kernel=use_kernel)
    h = h.reshape(b, cfg.tokens, cfg.dim) + r.take("pos")[None]
    for i in range(cfg.depth):
        h = _block(r, f"blk{i}", h, cfg, use_kernel)
    h = common.layer_norm(h, r.take("final.ln_scale"), r.take("final.ln_bias"))
    pooled = h.mean(axis=1)
    logits = common.dense(pooled, r.take("head.w"), r.take("head.b"), use_kernel=use_kernel)
    r.done()
    return logits, y, mask


def act_sizes(cfg: Config) -> List[int]:
    t, d = cfg.tokens, cfg.dim
    sizes = [t * d]
    for _ in range(cfg.depth):
        sizes += [t * 3 * d, cfg.heads * t * t, t * d, t * cfg.mlp, t * d]
    sizes += [d, cfg.classes]
    return sizes
