"""Mini-ResNet for 32x32x3 images (the paper's ResNet18 stand-in).

Three stages of two basic residual blocks each (GroupNorm, stateless), a
global-average-pool and a Pallas-dense head. Width is configurable: the
default (w=16, ~230k params) is the full HeteroFL network, w=8 the
half-width sub-network (DESIGN.md §2 scale substitution — the paper's
11.2M ResNet18 is not tractable for 500 rounds x 50 clients on one CPU
core; Table 1's cost model is additionally evaluated at the true ResNet18
sizes).
"""

import dataclasses
from typing import List

from . import common
from .common import ParamSpec


@dataclasses.dataclass(frozen=True)
class Config:
    width: int = 16
    classes: int = 10
    groups: int = 8
    img: int = 32
    channels: int = 3

    @property
    def stage_widths(self):
        return (self.width, 2 * self.width, 4 * self.width)


def _gn_specs(prefix: str, ch: int) -> List[ParamSpec]:
    return [
        ParamSpec(f"{prefix}.gn_scale", (ch,), 0, "norm_scale", fill=1.0),
        ParamSpec(f"{prefix}.gn_bias", (ch,), 0, "norm_bias", fill=0.0),
    ]


def _block_specs(prefix: str, cin: int, cout: int, downsample: bool) -> List[ParamSpec]:
    specs = [
        ParamSpec(f"{prefix}.conv1", (3, 3, cin, cout), 9 * cin, "conv"),
        *_gn_specs(f"{prefix}.n1", cout),
        ParamSpec(f"{prefix}.conv2", (3, 3, cout, cout), 9 * cout, "conv"),
        *_gn_specs(f"{prefix}.n2", cout),
    ]
    if downsample:
        specs += [
            ParamSpec(f"{prefix}.short", (1, 1, cin, cout), cin, "conv"),
            *_gn_specs(f"{prefix}.ns", cout),
        ]
    return specs


def specs(cfg: Config) -> List[ParamSpec]:
    """Flat-vector layout; order must match ``apply`` exactly."""
    w1, w2, w3 = cfg.stage_widths
    out = [
        ParamSpec("stem.conv", (3, 3, cfg.channels, w1), 9 * cfg.channels, "conv"),
        *_gn_specs("stem.n", w1),
    ]
    chains = [(w1, w1, False), (w1, w2, True), (w2, w3, True)]
    for si, (cin, cout, down) in enumerate(chains):
        out += _block_specs(f"s{si}.b0", cin, cout, down)
        out += _block_specs(f"s{si}.b1", cout, cout, False)
    out += [
        ParamSpec("head.w", (w3, cfg.classes), w3, "dense"),
        ParamSpec("head.b", (cfg.classes,), 0, "bias"),
    ]
    return out


def _block(r, prefix, x, cin, cout, downsample, groups, stride):
    h = common.conv3x3(x, r.take(f"{prefix}.conv1"), stride=stride)
    h = common.group_norm(h, r.take(f"{prefix}.n1.gn_scale"), r.take(f"{prefix}.n1.gn_bias"), groups)
    h = common.kref.apply_act(h, "relu")
    h = common.conv3x3(h, r.take(f"{prefix}.conv2"))
    h = common.group_norm(h, r.take(f"{prefix}.n2.gn_scale"), r.take(f"{prefix}.n2.gn_bias"), groups)
    if downsample:
        s = common.conv1x1(x, r.take(f"{prefix}.short"), stride=stride)
        s = common.group_norm(s, r.take(f"{prefix}.ns.gn_scale"), r.take(f"{prefix}.ns.gn_bias"), groups)
    else:
        s = x
    return common.kref.apply_act(h + s, "relu")


def apply(cfg: Config, flat, x, y, mask, use_kernel: bool = True):
    """Forward pass. x: [B, 32, 32, 3] f32; returns (logits, y, mask)."""
    r = common.ParamReader(flat, specs(cfg))
    w1, w2, w3 = cfg.stage_widths
    h = common.conv3x3(x, r.take("stem.conv"))
    h = common.group_norm(h, r.take("stem.n.gn_scale"), r.take("stem.n.gn_bias"), cfg.groups)
    h = common.kref.apply_act(h, "relu")
    chains = [(w1, w1, False, 1), (w1, w2, True, 2), (w2, w3, True, 2)]
    for si, (cin, cout, down, stride) in enumerate(chains):
        h = _block(r, f"s{si}.b0", h, cin, cout, down, cfg.groups, stride)
        h = _block(r, f"s{si}.b1", h, cout, cout, False, cfg.groups, 1)
    pooled = h.mean(axis=(1, 2))  # global average pool -> [B, 4w]
    logits = common.dense(
        pooled, r.take("head.w"), r.take("head.b"), act="none", use_kernel=use_kernel
    )
    r.done()
    return logits, y, mask


def act_sizes(cfg: Config) -> List[int]:
    """Per-example activation element counts, per stored layer output —
    feeds the eq. 4/5 memory model (comm/cost.rs)."""
    w1, w2, w3 = cfg.stage_widths
    i = cfg.img
    sizes = [i * i * w1]  # stem
    for (wch, scale) in ((w1, 1), (w2, 2), (w3, 4)):
        hw = (i // scale) ** 2
        # two blocks x (conv1, conv2, sum) outputs
        sizes += [hw * wch] * 6
    sizes += [w3, cfg.classes]
    return sizes
