"""Shared Layer-2 model machinery: flat parameter vectors, layers, losses.

Every model in this repo is a pure function over a single flat ``f32[d]``
parameter vector. The flat layout (offsets per named tensor) is exported in
``artifacts/manifest.json`` so the Rust coordinator can initialize, slice
(HeteroFL) and perturb (SPSA) parameters without ever seeing Python.

``use_kernel`` selects the Layer-1 Pallas kernel for dense layers on
forward-only graphs (ZO delta, fwd_loss/eval — the paper's low-resource
path never backprops, which is its whole point) and the identical-math
jnp oracle on differentiable graphs (warm-phase sgd_step): interpret-mode
``pallas_call`` has no autodiff rule. pytest asserts the two paths agree.
"""

import dataclasses
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import matmul as kmatmul
from ..kernels import perturb as kperturb
from ..kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named tensor inside the flat parameter vector."""

    name: str
    shape: tuple
    fan_in: int  # He/Glorot fan-in for Rust-side init (0 => init to `fill`)
    kind: str  # "conv" | "dense" | "bias" | "norm_scale" | "norm_bias" | "embed" | "pos"
    fill: float = 0.0  # constant init when fan_in == 0

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


class ParamReader:
    """Sequential reader over the flat vector following a spec list."""

    def __init__(self, flat, specs: Sequence[ParamSpec]):
        self.flat = flat
        self.specs = list(specs)
        self.offset = 0
        self.index = 0

    def take(self, name: str):
        spec = self.specs[self.index]
        assert spec.name == name, f"spec order mismatch: {spec.name} != {name}"
        t = jax.lax.dynamic_slice(self.flat, (self.offset,), (spec.size,))
        t = t.reshape(spec.shape)
        self.offset += spec.size
        self.index += 1
        return t

    def done(self):
        assert self.index == len(self.specs), (
            f"consumed {self.index}/{len(self.specs)} params"
        )
        assert self.offset == self.flat.shape[0], (
            f"offset {self.offset} != dim {self.flat.shape[0]}"
        )


def total_dim(specs: Sequence[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def init_flat(specs: Sequence[ParamSpec], seed: int) -> np.ndarray:
    """He-style init of the flat vector (mirrors rust/src/model/init.rs)."""
    rng = np.random.default_rng(seed)
    parts = []
    for s in specs:
        if s.fan_in == 0:
            parts.append(np.full(s.size, s.fill, np.float32))
        else:
            std = np.sqrt(2.0 / s.fan_in)
            parts.append(rng.normal(0.0, std, s.size).astype(np.float32))
    return np.concatenate(parts)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def dense(x, w, b, act: str = "none", use_kernel: bool = True):
    """act(x @ w + b); Pallas kernel or oracle depending on the graph kind."""
    fn = kmatmul.matmul_bias_act if use_kernel else kref.matmul_bias_act
    return fn(x, w, b, act=act)


def conv3x3(x, w, stride: int = 1):
    """NHWC 3x3 same-padding convolution. w: [kh, kw, cin, cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv1x1(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def group_norm(x, scale, bias, groups: int, eps: float = 1e-5):
    """GroupNorm over NHWC (stateless — no running stats, federated-friendly;
    the paper uses GN for FedAdam runs)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def layer_norm(x, scale, bias, eps: float = 1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


# ---------------------------------------------------------------------------
# Loss heads
# ---------------------------------------------------------------------------


def ce_loss_sum(logits, y, mask):
    """Masked cross-entropy sum + masked correct-prediction count.

    logits: [N, C] f32; y: [N] i32; mask: [N] f32 (0 for padding).
    Sum (not mean) so the Rust side can chunk a client's full dataset
    through a fixed-batch artifact and accumulate exactly (§3.1 single
    full-batch ZO step).
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    loss = (lse - picked) * mask
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32) * mask
    return loss.sum(), correct.sum()


# ---------------------------------------------------------------------------
# Artifact factories (the functions that get AOT-lowered)
# ---------------------------------------------------------------------------


def make_fwd_loss(apply_fn: Callable):
    """(flat, x, y, mask) -> (loss_sum, correct). Forward-only: Pallas path."""

    def fwd_loss(flat, x, y, mask):
        logits, y2, mask2 = apply_fn(flat, x, y, mask, use_kernel=True)
        return ce_loss_sum(logits, y2, mask2)

    return fwd_loss


def make_sgd_step(apply_fn: Callable):
    """(flat, x, y, mask, lr) -> (flat', loss_sum). Differentiable: oracle path."""

    def mean_loss(flat, x, y, mask):
        logits, y2, mask2 = apply_fn(flat, x, y, mask, use_kernel=False)
        loss_sum, _ = ce_loss_sum(logits, y2, mask2)
        return loss_sum / jnp.maximum(mask2.sum(), 1.0), loss_sum

    def sgd_step(flat, x, y, mask, lr):
        (_, loss_sum), grad = jax.value_and_grad(mean_loss, has_aux=True)(
            flat, x, y, mask
        )
        return flat - lr * grad, loss_sum

    return sgd_step


def make_zo_delta(apply_fn: Callable):
    """(flat, seed, coeff, x, y, mask) -> (delta_l_sum, mask_sum).

    The graph-mode SPSA numerator: ΔL = L(w+cz) − L(w−cz) with
    z = Rademacher(seed) regenerated in-graph (threefry) and applied by the
    fused Pallas perturb kernel. coeff = ε·τ. The artifact input is only the
    scalar seed — the d-length z never leaves the graph, matching the
    paper's seed-only protocol.
    """

    def zo_delta(flat, seed, coeff, x, y, mask):
        key = jax.random.PRNGKey(seed)
        bits = jax.random.bits(key, shape=flat.shape, dtype=jnp.uint32)
        w_plus = kperturb.rademacher_axpy(flat, bits, coeff)
        w_minus = kperturb.rademacher_axpy(flat, bits, -coeff)
        lp, _ = ce_loss_sum(*apply_fn(w_plus, x, y, mask, use_kernel=True))
        lm, _ = ce_loss_sum(*apply_fn(w_minus, x, y, mask, use_kernel=True))
        return lp - lm, mask.sum()

    return zo_delta


def act_elems_conv(b: int, h: int, w: int, c: int) -> int:
    return b * h * w * c


def checkerboard_sizes(sizes: List[int]) -> dict:
    """Activation-memory summary for the eq. 4/5 cost model (per batch el.)."""
    return {"sum": int(sum(sizes)), "max": int(max(sizes)) if sizes else 0}
