"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (same-math) reference here;
pytest compares kernel output against these under shape/dtype sweeps
(hypothesis) at build time. The oracles are also what the L2 models would
use if Pallas were unavailable, so they double as documentation of the
kernel semantics.
"""

import jax.numpy as jnp


def apply_act(x, act: str):
    """Activation used by both kernel and reference (keep in sync)."""
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        # tanh-approximation GELU, matching the kernel exactly.
        c = jnp.asarray(0.7978845608028654, x.dtype)  # sqrt(2/pi)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
    raise ValueError(f"unknown act {act!r}")


def matmul_bias_act(x, w, b, act: str = "none"):
    """Reference for kernels.matmul.matmul_bias_act.

    Computes ``act(x @ w + b)`` with f32 accumulation regardless of input
    dtype, mirroring the kernel's MXU-style accumulator.

    Args:
      x: [M, K] input.
      w: [K, N] weights.
      b: [N] bias (may be zeros).
      act: one of "none", "relu", "gelu".
    Returns:
      [M, N] in x.dtype.
    """
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    acc = acc + b.astype(jnp.float32)[None, :]
    acc = apply_act(acc, "none" if act == "none" else act)
    return acc.astype(x.dtype)


def rademacher_axpy(w, bits, coeff):
    """Reference for kernels.perturb.rademacher_axpy.

    ``w + coeff * sign(bits)`` where ``sign(bits) = 1 - 2*(bits & 1)`` maps
    uniform random u32 bits to a Rademacher(+1/-1) variate per element.

    Args:
      w: [D] f32 parameter vector.
      bits: [D] uint32 random bits.
      coeff: scalar f32 (typically ±ε·τ).
    Returns:
      [D] f32 perturbed vector.
    """
    sign = 1.0 - 2.0 * (bits & jnp.uint32(1)).astype(jnp.float32)
    return w + jnp.asarray(coeff, jnp.float32) * sign
