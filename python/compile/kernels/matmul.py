"""Layer-1 Pallas kernel: tiled matmul + bias + activation.

This is the dense-layer workhorse for every model in the repo (CNN head,
ViT attention/MLP projections, LM projections). It is written for the TPU
execution model — blocks sized for VMEM residency, MXU-friendly tile
multiples, a 3-d grid with the contraction dimension innermost and an f32
accumulator carried in the output block — and executed here with
``interpret=True`` because the CPU PJRT plugin cannot run Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation).

VMEM footprint per grid step (f32): ``bm*bk + bk*bn + bm*bn`` words; the
default (64, 128, 64) tile is ~48 KiB — far below the ~16 MiB VMEM budget,
leaving room to double-buffer the HBM→VMEM streams.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block shape: multiples of the 8x128 TPU vreg tile; bm=bn=64 keeps
# the MXU (128x128 systolic array) half-fed per step, which is the sweet
# spot for the small-model shapes in this repo (EXPERIMENTS.md §Perf L1).
DEFAULT_BLOCK = (64, 128, 64)


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act, nk):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks.

    The f32 output block doubles as the accumulator: initialized at the
    first K step, bias+activation folded in at the last.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-style: accumulate in f32 whatever the input dtype.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = ref.apply_act(out, act)


def _pad_to(a, target, axis):
    pad = target - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _ceil_to(n, b):
    return -(-n // b) * b


@functools.partial(jax.jit, static_argnames=("act", "block"))
def matmul_bias_act(x, w, b, act: str = "none", block=None):
    """``act(x @ w + b)`` via the tiled Pallas kernel.

    Shapes need not be multiples of the block: inputs are zero-padded up to
    the grid (exact for matmul; bias/activation applied after contraction)
    and the result is sliced back.

    Args:
      x: [M, K] input (f32 or bf16).
      w: [K, N] weights.
      b: [N] bias.
      act: "none" | "relu" | "gelu".
      block: optional (bm, bk, bn) override.
    Returns:
      [M, N] in x.dtype.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bk, bn = block or DEFAULT_BLOCK
    # Clamp blocks to the (8/128-aligned) problem size so tiny layers do
    # not inflate to a full default tile.
    bm = min(bm, _ceil_to(m, 8))
    bk = min(bk, _ceil_to(k, 128))
    bn = min(bn, _ceil_to(n, 128))

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad_to(_pad_to(x, mp, 0), kp, 1)
    wp = _pad_to(_pad_to(w, kp, 0), np_, 1)
    bp = _pad_to(b, np_, 0)
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, act=act, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls.
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)


def vmem_bytes(block=None, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step (for EXPERIMENTS.md §Perf)."""
    bm, bk, bn = block or DEFAULT_BLOCK
    # x tile + w tile + f32 accumulator/output tile (+bias row).
    return dtype_bytes * (bm * bk + bk * bn) + 4 * (bm * bn + bn)


def mxu_utilization(m: int, k: int, n: int, block=None) -> float:
    """Fraction of MXU-issue slots doing useful work for an [m,k]x[k,n]
    problem under the padded tiling — the TPU efficiency estimate recorded
    in EXPERIMENTS.md §Perf (interpret-mode wallclock is NOT a TPU proxy).
    """
    bm, bk, bn = block or DEFAULT_BLOCK
    bm = min(bm, _ceil_to(m, 8))
    bk = min(bk, _ceil_to(k, 128))
    bn = min(bn, _ceil_to(n, 128))
    padded = _ceil_to(m, bm) * _ceil_to(k, bk) * _ceil_to(n, bn)
    return (m * k * n) / padded
