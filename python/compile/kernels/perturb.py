"""Layer-1 Pallas kernel: fused Rademacher perturbation (the ZO hot spot).

MeZO-style zeroth-order optimization never materializes the perturbation
vector z: it is regenerated from a seed wherever needed. The compute shape
is ``w' = w + c * sign(bits)`` over the full flat parameter vector — a
purely memory-bound streaming op. On TPU the roofline is HBM bandwidth, so
the kernel fuses the bit→sign map and the axpy into a single pass over
``w`` (one read + one write of d words, plus one read of d bit-words),
tiled through VMEM in 1-d blocks. Executed with ``interpret=True`` here
(CPU PJRT cannot run Mosaic custom-calls).

The random bits are produced by jax.random (threefry) *outside* the kernel
— in the AOT graph they derive from the scalar round seed, so the artifact
input is still just (params, seed, coeff), matching the paper's
seed-only communication protocol.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-d block: one vreg-aligned stripe; 64k f32 = 256 KiB/stream in VMEM.
DEFAULT_BLOCK = 65536


def _kernel(w_ref, bits_ref, c_ref, o_ref):
    sign = 1.0 - 2.0 * (bits_ref[...] & jnp.uint32(1)).astype(jnp.float32)
    o_ref[...] = w_ref[...] + c_ref[0] * sign


def _ceil_to(n, b):
    return -(-n // b) * b


@functools.partial(jax.jit, static_argnames=("block",))
def rademacher_axpy(w, bits, coeff, block: int = DEFAULT_BLOCK):
    """``w + coeff * rademacher(bits)`` elementwise over a flat vector.

    Args:
      w: [D] f32 parameters.
      bits: [D] uint32 random bits (low bit consumed).
      coeff: scalar f32, e.g. +ε·τ or −2·ε·τ for the two SPSA sides.
      block: 1-d tile length.
    Returns:
      [D] f32 perturbed parameters.
    """
    (d,) = w.shape
    assert bits.shape == (d,), f"bits shape {bits.shape} != ({d},)"
    b = min(block, _ceil_to(d, 128))
    dp = _ceil_to(d, b)
    wp = jnp.pad(w, (0, dp - d))
    bitsp = jnp.pad(bits, (0, dp - d))
    c = jnp.reshape(jnp.asarray(coeff, jnp.float32), (1,))

    out = pl.pallas_call(
        _kernel,
        grid=(dp // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(wp, bitsp, c)
    return out[:d]


def perturb_from_seed(w, seed, coeff, block: int = DEFAULT_BLOCK):
    """Seed → threefry bits → fused Rademacher axpy.

    ``seed`` may be a traced int32 scalar, so this composes into the AOT
    ZO-delta artifact where the seed is a runtime input from the Rust
    coordinator.
    """
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bits(key, shape=w.shape, dtype=jnp.uint32)
    return rademacher_axpy(w, bits, coeff, block=block)


def hbm_traffic_bytes(d: int) -> int:
    """Bytes moved per perturbation on TPU (roofline model for §Perf):
    read w (4d) + read bits (4d) + write w' (4d)."""
    return 12 * d
