#!/usr/bin/env python3
"""Unit tests for tools/bench_gate.py (stdlib unittest; CI lint job).

The gate guards the bench trajectory, so its own exit-code contract is
pinned here: regression -> 1, stale-fast baseline -> 0 with a re-bless
notice, unmeasured baseline -> 0 skip, and --require failing closed
(exit 1) even while the baseline is still the unmeasured placeholder.

Run directly:  python3 tools/test_bench_gate.py
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest
from unittest import mock

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_gate  # noqa: E402


def group(name, rows):
    """A util::bench::Bench::to_json-shaped group."""
    return {
        "group": name,
        "results": [dict(r, name=r["name"]) for r in rows],
    }


def row(name, mean_ns, p50_ns=None):
    r = {"name": name, "mean_ns": mean_ns}
    if p50_ns is not None:
        r["p50_ns"] = p50_ns
    return r


class GateCase(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)

    def path(self, name, payload):
        p = os.path.join(self.dir.name, name)
        with open(p, "w") as f:
            json.dump(payload, f)
        return p

    def run_gate(self, baseline, fresh_groups, extra=()):
        base = self.path("baseline.json", baseline)
        fresh = [
            self.path(f"fresh_{i}.json", g) for i, g in enumerate(fresh_groups)
        ]
        argv = ["bench_gate.py", base, *fresh, *extra]
        out = io.StringIO()
        with mock.patch.object(sys, "argv", argv):
            with contextlib.redirect_stdout(out):
                code = bench_gate.main()
        return code, out.getvalue()

    # -- helpers under test directly ------------------------------------

    def test_load_rows_flattens_groups_and_keeps_optional_p50(self):
        rows = bench_gate.load_rows(
            [
                group("zo", [row("fold_k64", 100.0, p50_ns=90.0)]),
                group("fed", [row("round", 2000.0)]),
            ]
        )
        self.assertEqual(set(rows), {("zo", "fold_k64"), ("fed", "round")})
        self.assertEqual(rows[("zo", "fold_k64")]["p50_ns"], 90.0)
        self.assertIsNone(rows[("fed", "round")]["p50_ns"])

    def test_metric_prefers_p50_only_when_both_sides_carry_it(self):
        p50 = {"p50_ns": 90.0, "mean_ns": 100.0}
        mean_only = {"p50_ns": None, "mean_ns": 120.0}
        self.assertEqual(bench_gate.metric(p50, p50), ("p50_ns", 90.0, 90.0))
        # either side missing p50 -> mean comparison for the pair
        self.assertEqual(
            bench_gate.metric(p50, mean_only), ("mean_ns", 100.0, 120.0)
        )
        self.assertEqual(
            bench_gate.metric(mean_only, p50), ("mean_ns", 120.0, 100.0)
        )

    # -- exit-code contract ---------------------------------------------

    def test_unmeasured_baseline_skips_with_notice(self):
        code, out = self.run_gate(
            {"status": "unmeasured", "groups": []},
            [group("zo", [row("fold_k64", 100.0)])],
        )
        self.assertEqual(code, 0)
        self.assertIn("SKIPPED", out)

    def test_regression_beyond_tolerance_fails(self):
        code, out = self.run_gate(
            {"status": "measured", "groups": [group("zo", [row("fold_k64", 100.0)])]},
            [group("zo", [row("fold_k64", 140.0)])],  # +40% > +/-30%
        )
        self.assertEqual(code, 1)
        self.assertIn("::error::bench regression", out)

    def test_within_tolerance_passes(self):
        code, out = self.run_gate(
            {"status": "measured", "groups": [group("zo", [row("fold_k64", 100.0)])]},
            [group("zo", [row("fold_k64", 125.0)])],  # +25% < +/-30%
        )
        self.assertEqual(code, 0)
        self.assertIn("bench gate OK", out)

    def test_stale_fast_baseline_is_a_notice_not_a_failure(self):
        code, out = self.run_gate(
            {"status": "measured", "groups": [group("zo", [row("fold_k64", 100.0)])]},
            [group("zo", [row("fold_k64", 50.0)])],  # -50% improvement
        )
        self.assertEqual(code, 0)
        self.assertIn("re-bless the baseline", out)

    def test_comparison_uses_p50_when_available(self):
        # mean regresses wildly but p50 is flat: p50 must win (that is
        # the whole point of preferring it on noisy CI runners)
        code, out = self.run_gate(
            {
                "status": "measured",
                "groups": [group("zo", [row("fold_k64", 100.0, p50_ns=100.0)])],
            },
            [group("zo", [row("fold_k64", 900.0, p50_ns=105.0)])],
        )
        self.assertEqual(code, 0, out)

    def test_custom_tolerance_is_respected(self):
        code, _ = self.run_gate(
            {"status": "measured", "groups": [group("zo", [row("fold_k64", 100.0)])]},
            [group("zo", [row("fold_k64", 120.0)])],  # +20%
            extra=["--tolerance", "0.10"],
        )
        self.assertEqual(code, 1)

    # -- row set drift ---------------------------------------------------

    def test_new_and_vanished_rows_are_notices_not_failures(self):
        code, out = self.run_gate(
            {"status": "measured", "groups": [group("zo", [row("old_row", 100.0)])]},
            [group("zo", [row("new_row", 100.0)])],
        )
        self.assertEqual(code, 0)
        self.assertIn("has no baseline yet", out)
        self.assertIn("was not produced by this run", out)

    # -- --require fails closed ------------------------------------------

    def test_require_missing_fails_even_while_unmeasured(self):
        code, out = self.run_gate(
            {"status": "unmeasured", "groups": []},
            [group("zo", [row("fold_k64", 100.0)])],
            extra=["--require", "d11m"],
        )
        self.assertEqual(code, 1)
        self.assertIn("required bench row missing", out)

    def test_require_satisfied_by_substring_then_skips_unmeasured(self):
        code, out = self.run_gate(
            {"status": "unmeasured", "groups": []},
            [group("zo", [row("zoupdate_d11m_lanes", 100.0)])],
            extra=["--require", "d11m"],
        )
        self.assertEqual(code, 0)
        self.assertIn("SKIPPED", out)


if __name__ == "__main__":
    unittest.main()
