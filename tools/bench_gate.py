#!/usr/bin/env python3
"""CI bench-regression gate.

Diffs the bench-smoke JSON emitted by the `zo_core` / `fed_primitives`
benches against the committed `BENCH_baseline.json`, row by row, with a
relative tolerance on `mean_ns` (default +/-30%).

  python3 tools/bench_gate.py BENCH_baseline.json \
      rust/runs/BENCH_zo_core.json rust/runs/BENCH_fed_primitives.json \
      [--tolerance 0.30] [--require SUBSTRING ...]

Behavior:
  * rows are compared on `p50_ns` when both sides carry it (robust to
    the scheduler noise of quick-mode runs on shared CI runners),
    falling back to `mean_ns`;
  * every `--require SUBSTRING` must match at least one fresh row name
    (case-sensitive substring). This runs BEFORE the unmeasured-baseline
    skip below, so load-bearing rows (e.g. the d=11M kernel matchup)
    cannot silently vanish from a bench while the baseline is still a
    placeholder;
  * while the baseline still carries the `"status": "unmeasured"`
    sentinel (no toolchain has blessed a first trajectory point yet) the
    gate auto-skips with a visible notice and exits 0;
  * a fresh row slower than baseline * (1 + tolerance) is a REGRESSION
    and fails the gate (exit 1);
  * a fresh row faster than baseline * (1 - tolerance) is reported as a
    stale-baseline notice (the win should be committed), not a failure;
  * rows present on one side only are reported as notices — new benches
    are expected to appear before their baseline is re-blessed.

Baseline schema: {"status": "measured"|"unmeasured", "groups": [<group>]}
where each <group> is a `util::bench::Bench::to_json` object:
{"group": str, "results": [{"name": str, "mean_ns": float, ...}]}.
"""

import argparse
import json
import sys


def load_rows(groups):
    """Flatten groups to {(group, name): {"p50_ns": x|None, "mean_ns": y}}."""
    rows = {}
    for g in groups:
        for r in g.get("results", []):
            rows[(g.get("group", "?"), r["name"])] = {
                "p50_ns": float(r["p50_ns"]) if "p50_ns" in r else None,
                "mean_ns": float(r["mean_ns"]),
            }
    return rows


def metric(base_row, fresh_row):
    """Pick the comparison metric: p50 when both sides have it, else mean."""
    if base_row["p50_ns"] is not None and fresh_row["p50_ns"] is not None:
        return "p50_ns", base_row["p50_ns"], fresh_row["p50_ns"]
    return "mean_ns", base_row["mean_ns"], fresh_row["mean_ns"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh", nargs="+", help="per-group bench JSON files")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="SUBSTRING",
        help="fail unless some fresh row name contains SUBSTRING "
        "(checked even while the baseline is unmeasured)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    fresh_groups = []
    for path in args.fresh:
        with open(path) as f:
            fresh_groups.append(json.load(f))
    fresh_rows = load_rows(fresh_groups)

    missing = [
        req
        for req in args.require
        if not any(req in name for _, name in fresh_rows)
    ]
    if missing:
        for req in missing:
            print(f"::error::required bench row missing: no fresh row name contains {req!r}")
        return 1

    if baseline.get("status") != "measured":
        print(
            "::notice file={}::bench gate SKIPPED — baseline status is "
            "{!r}; commit a measured baseline (the bench-smoke step prints "
            "one) to arm the +/-{:.0%} regression gate".format(
                args.baseline, baseline.get("status"), args.tolerance
            )
        )
        return 0

    base_rows = load_rows(baseline.get("groups", []))

    regressions, improvements = [], []
    for key, fresh_row in sorted(fresh_rows.items()):
        base_row = base_rows.get(key)
        if base_row is None:
            print(f"::notice::new bench row {key} has no baseline yet")
            continue
        name, base_ns, fresh_ns = metric(base_row, fresh_row)
        if base_ns <= 0:
            continue
        ratio = fresh_ns / base_ns
        label = (
            f"{key[0]} / {key[1]} [{name}]: {base_ns:.0f} ns -> "
            f"{fresh_ns:.0f} ns ({ratio:.2f}x)"
        )
        if ratio > 1.0 + args.tolerance:
            regressions.append(label)
        elif ratio < 1.0 - args.tolerance:
            improvements.append(label)
    for key in sorted(set(base_rows) - set(fresh_rows)):
        print(f"::notice::baseline row {key} was not produced by this run")

    for label in improvements:
        print(f"::notice::bench improved beyond tolerance (re-bless the baseline): {label}")
    if regressions:
        for label in regressions:
            print(f"::error::bench regression beyond +/-{args.tolerance:.0%}: {label}")
        return 1
    print(
        f"bench gate OK: {len(fresh_rows)} rows within +/-{args.tolerance:.0%} "
        f"of baseline ({len(improvements)} faster-than-tolerance)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
