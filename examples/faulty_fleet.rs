//! Scenario: the same federation under progressively nastier fleets.
//!
//! The `sim` capability engine replaces the binary High/Low flag with
//! per-client profiles (memory budget, bandwidth, compute speed, failure
//! rate) and gives rounds deadline semantics: clients whose simulated
//! wall-time blows the deadline drop out mid-round, the server folds only
//! survivors, and the ledger charges only bytes actually transmitted.
//!
//! This example runs ZOWarmUp on identical data under four fleets —
//! the paper's binary split, a four-tier edge spectrum, a deadline-bound
//! straggler fleet, and a flaky fleet losing a quarter of its clients per
//! round — and reports accuracy, drop counts, and measured communication.
//!
//!     cargo run --release --example faulty_fleet
//!
//! Expected shape: drops cost accuracy far less than excluding the
//! low-resource fleet outright would (ZO contributions are cheap and
//! redundant), while the ledger shrinks with every lost upload.

use zowarmup::config::Scale;
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{run_method, Method};
use zowarmup::metrics::MdTable;
use zowarmup::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let data = scale.data();

    let mut t = MdTable::new(&[
        "Fleet",
        "final acc %",
        "dropped (client-rounds)",
        "up-link MB",
        "down-link MB",
    ]);
    for name in ["binary", "edge-spectrum", "stragglers", "flaky"] {
        let mut cfg = scale.fed();
        cfg.hi_frac = 0.1; // the paper's motivating 10/90 split (binary only)
        cfg.scenario = Scenario::preset(name).expect("known preset");
        let t0 = std::time::Instant::now();
        let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
        let (up, down) = log.total_bytes();
        t.row(vec![
            name.to_string(),
            format!("{:.1}", log.final_accuracy() * 100.0),
            log.total_dropped().to_string(),
            format!("{:.2}", up as f64 / 1e6),
            format!("{:.2}", down as f64 / 1e6),
        ]);
        eprintln!(
            "[{name}] done in {:.1}s ({} drops)",
            t0.elapsed().as_secs_f64(),
            log.total_dropped()
        );
    }
    println!("{}", t.render());
    println!(
        "Scenarios are presets or JSON specs (schema: rust/src/exp/README.md);\n\
         try `zowarmup train --scenario stragglers` or point --scenario at a file."
    );
    Ok(())
}
