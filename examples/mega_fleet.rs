//! Scenario: ten million clients, one laptop.
//!
//! The lazy population layer (`fed::population`) makes a federation over
//! 10^7 clients cost O(sampled) per round: a client's capability profile
//! is a pure function of `(scenario, seed, id)` and its data shard is a
//! keyed on-demand draw, so nothing per-client is ever materialized. The
//! server's sync ledger is sparse — only clients that ever participated
//! occupy memory.
//!
//!     cargo run --release --example mega_fleet
//!
//! The example builds a 10M-client federation under the `fleet` preset
//! (a 2% FO-capable backbone over a ZO-only edge), runs the two-phase
//! protocol for a few rounds, and reports what the population actually
//! cost — population-layer bytes vs the naive materialized estimate,
//! per-round wall time, and the sparse ledger's footprint.

use std::sync::Arc;

use zowarmup::config::{PopulationMode, Scale};
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test, SynthKind};
use zowarmup::exp::common::{linear_lrs, probe_backend};
use zowarmup::fed::server::Federation;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

const N_CLIENTS: usize = 10_000_000;

fn main() -> anyhow::Result<()> {
    let mut cfg = Scale::Smoke.fed();
    linear_lrs(&mut cfg);
    cfg.clients = N_CLIENTS;
    cfg.population = PopulationMode::Lazy; // Auto would pick lazy too, at this N
    cfg.scenario = Scenario::preset("fleet").expect("bundled preset");
    cfg.sample_zo = 64;
    cfg.sample_warm = 8;
    cfg.rounds_total = 8;
    cfg.pivot = 3;
    cfg.eval_every = 4;

    let data = Scale::Smoke.data();
    let (train, test) = train_test(SynthKind::Synth10, data.n_train, data.n_test, cfg.seed);
    let backend = probe_backend(SynthKind::Synth10.classes());
    let init = ParamVec::zeros(backend.dim());

    let t0 = std::time::Instant::now();
    let mut fed = Federation::new_lazy(
        cfg,
        &backend,
        Source::Image(Arc::new(train)),
        Source::Image(Arc::new(test)),
        init,
    )?;
    let setup = t0.elapsed();
    println!(
        "federation over {N_CLIENTS} clients built in {:.2} ms",
        setup.as_secs_f64() * 1e3
    );

    fed.run()?;

    let state = fed.pop.approx_state_bytes();
    // what materializing would have cost: ~per-client profile + shard view
    let naive_estimate = N_CLIENTS as u64 * 150;
    let round_ms: f64 = fed.log.rounds.iter().map(|r| r.wall_ms).sum::<f64>()
        / fed.log.rounds.len().max(1) as f64;
    println!(
        "population layer: {state} B resident (materialized estimate ~{:.1} GB)",
        naive_estimate as f64 / 1e9
    );
    println!(
        "rounds: {} run, {:.1} ms mean wall, {} client-drops, {} sync-ledger entries",
        fed.log.rounds.len(),
        round_ms,
        fed.log.total_dropped(),
        fed.synced.deviated(),
    );
    println!(
        "final signal {:.4}, test acc {:.1}% | up {:.3} MB down {:.3} MB",
        fed.log.rounds.last().map(|r| r.train_loss).unwrap_or(0.0),
        fed.log.final_accuracy() * 100.0,
        fed.log.total_bytes().0 as f64 / 1e6,
        fed.log.total_bytes().1 as f64 / 1e6,
    );
    println!(
        "\nEvery number above is O(sampled): the same run at --clients 1000 \
         allocates the same population state.\nTry `zowarmup train --scenario \
         fleet --clients 10000000 --scale smoke` for the CLI path."
    );
    Ok(())
}
