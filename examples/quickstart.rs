//! Quickstart: a minimal ZOWarmUp federation in ~40 lines.
//!
//! Runs the two-phase protocol (FedAvg warm-up → seed-based ZO updates)
//! over 8 simulated clients on the synthetic CIFAR-10 substitute, using
//! the host-side linear probe backend (no artifacts needed).
//!
//!     cargo run --release --example quickstart

use zowarmup::config::Scale;
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{image_setup, linear_lrs};
use zowarmup::fed::server::Federation;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;

fn main() -> anyhow::Result<()> {
    // 1. configuration: 8 clients, 25% high-resource, pivot at round 6
    let mut cfg = Scale::Smoke.fed();
    cfg.hi_frac = 0.25;
    cfg.eval_every = 2;
    linear_lrs(&mut cfg);
    let data = Scale::Smoke.data();

    // 2. data: procedural dataset + Dirichlet(0.1) non-IID shards
    let setup = image_setup(SynthKind::Synth10, &data, &cfg);

    // 3. federate
    let init = ParamVec::zeros(setup.backend.dim());
    let mut fed = Federation::new(cfg, &setup.backend, setup.shards, setup.test, init)?;
    fed.run()?;

    // 4. inspect
    for r in fed.log.rounds.iter().filter(|r| !r.test_acc.is_nan()) {
        println!(
            "round {:3} [{}]  acc {:5.1}%  up {:>10} B",
            r.round,
            r.phase.as_str(),
            r.test_acc * 100.0,
            r.bytes_up
        );
    }
    let (up, down) = fed.log.total_bytes();
    println!(
        "\nfinal accuracy {:.1}% | total comm: {:.2} MB up, {:.2} MB down",
        fed.log.final_accuracy() * 100.0,
        up as f64 / 1e6,
        down as f64 / 1e6
    );
    println!("note how up-link bytes collapse once the ZO phase starts.");
    Ok(())
}
