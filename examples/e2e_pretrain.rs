//! End-to-end driver: federated PRE-TRAINING of the CNN from a random
//! initialization over the full three-layer stack — Rust coordinator →
//! PJRT-compiled HLO artifacts → JAX/Pallas compute — proving all layers
//! compose (system prompt deliverable; recorded in EXPERIMENTS.md §E2E).
//!
//! Two-phase run on the synthetic CIFAR-10 substitute:
//!   phase 1: FedAvg over high-resource clients (backprop via sgd_step)
//!   phase 2: seed-based SPSA over ALL clients (forward-only fwd_loss)
//! Loss/accuracy curve goes to runs/e2e_pretrain.csv.
//!
//!     make artifacts && cargo run --release --example e2e_pretrain
//!     # quick variant:
//!     cargo run --release --example e2e_pretrain -- --rounds 12 --pivot 6
//!
//! Full defaults train a few hundred rounds; on the 1-core CPU testbed
//! this takes tens of minutes (the PJRT CPU backend interprets the Pallas
//! kernels). Use --rounds/--pivot to scale.

use std::sync::Arc;

use zowarmup::config::Scale;
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test_cfg, GenConfig, SynthKind};
use zowarmup::exp::common::run_path;
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::model::manifest::Manifest;
use zowarmup::model::params::ParamVec;
use zowarmup::runtime::Engine;
use zowarmup::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rounds = args.usize_or("rounds", 300)?;
    let pivot = args.usize_or("pivot", 120)?;
    let clients = args.usize_or("clients", 10)?;
    let hi_frac = args.f64_or("hi-frac", 0.3)?;
    let n_train = args.usize_or("n-train", 800)?;
    let n_test = args.usize_or("n-test", 200)?;
    let alpha = args.f64_or("alpha", 0.1)?;
    let lr_warm = args.f64_or("lr-warm", 0.05)? as f32;
    let lr_zo = args.f64_or("lr-zo", 0.02)? as f32;
    let local_epochs = args.usize_or("local-epochs", 1)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;

    println!("== e2e federated pre-training over XLA/PJRT (cnn10) ==");
    let manifest = Manifest::load(&artifacts)?;
    manifest.validate()?;
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let t_compile = std::time::Instant::now();
    let backend = engine.backend(&manifest, "cnn10")?;
    let entry = manifest.model("cnn10")?;
    println!(
        "compiled fwd_loss/sgd_step/zo_delta for cnn10 (d={}) in {:.1}s",
        entry.dim,
        t_compile.elapsed().as_secs_f64()
    );

    let mut cfg = Scale::Smoke.fed();
    cfg.clients = clients;
    cfg.hi_frac = hi_frac;
    cfg.rounds_total = rounds;
    cfg.pivot = pivot;
    cfg.sample_warm = 3;
    cfg.sample_zo = 4;
    cfg.local_epochs = local_epochs;
    cfg.batch = entry.batch;
    cfg.eval_every = (rounds / 30).max(1);
    cfg.lr_client_warm = lr_warm;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = lr_zo;
    cfg.zo.eps = 1e-3;

    // Lower-noise generator than the probe sweeps: the e2e driver's job is
    // to prove the three layers compose on a learnable workload within a
    // CPU round budget (EXPERIMENTS.md §E2E).
    let gen = GenConfig {
        noise: args.f64_or("noise", 0.35)? as f32,
        contrast_jitter: 0.3,
        seed: cfg.seed,
    };
    let (train, test) = train_test_cfg(SynthKind::Synth10, n_train, n_test, gen);
    let part = dirichlet_split(&train, cfg.clients, alpha, cfg.seed);
    let src = Source::Image(Arc::new(train));
    let shards = shards_from_partition(&src, &part);
    let init = ParamVec::he_init(entry, cfg.seed);

    let mut fed = Federation::new(cfg, &backend, shards, Source::Image(Arc::new(test)), init)?;
    let t0 = std::time::Instant::now();
    while fed.round < fed.cfg.rounds_total {
        fed.step()?;
        let r = fed.log.rounds.last().unwrap();
        if !r.test_acc.is_nan() {
            println!(
                "round {:4}/{} [{}]  train {:7.4}  test acc {:5.1}%  loss {:.4}  ({:.0} ms/round)",
                r.round,
                fed.cfg.rounds_total,
                r.phase.as_str(),
                r.train_loss,
                r.test_acc * 100.0,
                r.test_loss,
                r.wall_ms,
            );
        }
    }
    let out = run_path("e2e_pretrain.csv");
    fed.log.write_csv(&out)?;
    let (up, down) = fed.log.total_bytes();
    println!(
        "\n== done in {:.0}s ==\nfinal acc {:.1}% (best {:.1}%) | comm up {:.2} MB / down {:.2} MB | curve: {out}",
        t0.elapsed().as_secs_f64(),
        fed.log.final_accuracy() * 100.0,
        fed.log.best_accuracy() * 100.0,
        up as f64 / 1e6,
        down as f64 / 1e6,
    );
    Ok(())
}
