//! Scenario: buffered-asynchronous aggregation on an edge spectrum.
//!
//! The synchronous barrier waits for its slowest sampled client every
//! round, so a heterogeneous fleet's simulated makespan is paced by the
//! straggler tail. With `--engine async` the server instead keeps a
//! pipeline of dispatches in flight on a discrete event clock and folds
//! the first `--buffer-k` arrivals per logical round — late arrivals
//! still count, but their contributions were computed against an older
//! model version and are discounted by the polynomial staleness weight
//! `(1 + s)^(-decay)`. The event ordering (not thread scheduling)
//! decides everything, so the engine stays bit-identical at every
//! `--threads` count.
//!
//! This example runs the sync barrier and the async engine at two decay
//! settings on identical data, then compares accuracy, mean staleness,
//! simulated makespan, and traffic.
//!
//!     cargo run --release --example async_fleet
//!
//! Expected shape: the async rows finish the same number of folds in a
//! fraction of the barrier's simulated makespan while reporting nonzero
//! mean staleness; stronger decay discounts stale folds harder, trading
//! event-clock speed against step freshness.

use zowarmup::config::{EngineKind, Scale};
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{image_setup, linear_lrs};
use zowarmup::fed::server::Federation;
use zowarmup::metrics::{MdTable, Phase};
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let data_cfg = scale.data();

    let mut t = MdTable::new(&[
        "mode",
        "final acc %",
        "mean staleness",
        "sim makespan s",
        "dropped",
        "up-link KB",
    ]);
    for (label, engine, decay) in [
        ("sync barrier", EngineKind::Sync, 0.0),
        ("async d=0.5", EngineKind::Async, 0.5),
        ("async d=2.0", EngineKind::Async, 2.0),
    ] {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = Scenario::preset("edge-spectrum").expect("bundled preset");
        cfg.engine = engine;
        cfg.async_zo.staleness_decay = decay;
        let s = image_setup(SynthKind::Synth10, &data_cfg, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        t.row(vec![
            label.to_string(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            format!("{:.2}", fed.log.mean_staleness()),
            format!("{:.2}", fed.log.total_makespan_ms() / 1e3),
            fed.log.total_dropped().to_string(),
            format!("{:.3}", fed.ledger.up_total as f64 / 1e3),
        ]);
        eprintln!(
            "[{label}] done in {:.1}s ({} folded events, model version {})",
            t0.elapsed().as_secs_f64(),
            fed.async_trace().len(),
            fed.model_version,
        );
        // the per-round view: staleness and event-clock makespan are new
        // CSV columns (see metrics::RoundRecord), printed here for the
        // first few ZO rounds
        if engine == EngineKind::Async {
            for r in fed
                .log
                .rounds
                .iter()
                .filter(|r| r.phase == Phase::Zo)
                .take(3)
            {
                eprintln!(
                    "  round {:3}: staleness {:.2}  makespan {:.1} ms  v{}",
                    r.round, r.staleness, r.makespan_ms, r.model_version
                );
            }
        }
    }
    println!("{}", t.render());
    println!(
        "Knobs: `--engine async --buffer-k 4 --staleness-decay 0.5 \
         --concurrency 8 --arrival-rate 0.05`\n\
         (also valid in --config JSON). Try\n\
         `zowarmup train --scenario edge-spectrum --engine async` or\n\
         `zowarmup exp async --scale smoke` for the decay ablation."
    );
    Ok(())
}
