//! Scenario: a 90%-low-resource fleet (the paper's motivating setting).
//!
//! Compares three deployments on the same data and client population:
//!   1. High-Res-Only — exclude the 90% (system-induced bias)
//!   2. HeteroFL      — give the 90% half-width sub-networks
//!   3. ZOWarmUp      — warm up on the 10%, then seed-based ZO for all
//! and reports accuracy + per-client communication budgets.
//!
//!     cargo run --release --example lowres_fleet

use zowarmup::config::Scale;
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{run_method, Method};
use zowarmup::metrics::MdTable;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let mut cfg = scale.fed();
    cfg.hi_frac = 0.1; // 10/90: most of the fleet is low-resource
    let data = scale.data();

    println!(
        "fleet: {} clients, {} high-resource / {} low-resource, Dirichlet α={}",
        cfg.clients,
        cfg.hi_count(),
        cfg.clients - cfg.hi_count(),
        data.alpha
    );
    println!("dataset: synth10, {} train / {} test\n", data.n_train, data.n_test);

    let mut t = MdTable::new(&[
        "Deployment",
        "final acc %",
        "up-link MB (total)",
        "down-link MB (total)",
    ]);
    for (method, label) in [
        (Method::HighResOnly, "exclude low-res (status quo)"),
        (Method::HeteroFl, "HeteroFL sub-networks"),
        (Method::ZoWarmup, "ZOWarmUp (this paper)"),
    ] {
        let t0 = std::time::Instant::now();
        let log = run_method(method, SynthKind::Synth10, &data, &cfg)?;
        let (up, down) = log.total_bytes();
        t.row(vec![
            label.to_string(),
            format!("{:.1}", log.final_accuracy() * 100.0),
            format!("{:.2}", up as f64 / 1e6),
            format!("{:.2}", down as f64 / 1e6),
        ]);
        eprintln!("[{label}] done in {:.1}s", t0.elapsed().as_secs_f64());
    }
    println!("{}", t.render());
    println!(
        "Expected: ZOWarmUp recovers the accuracy the status quo leaves on the\n\
         table by tapping the 90% fleet — at negligible extra up-link cost."
    );
    Ok(())
}
