//! Scenario: two-tier edge aggregation on a geo-distributed IoT fleet.
//!
//! Real fleets upload through regional edge aggregators, not straight to
//! one planetary server. `--edges E` partitions the population across E
//! aggregators by a keyed draw from the master seed; each edge folds its
//! cohort's fused (seed, coeff) items and the root merges the partials
//! in edge order — bit-identical to the flat fold, so on a scenario
//! without edge profiles the flag is pure ledger attribution. The
//! `geo-iot` preset *does* declare edge profiles (metro / rural /
//! industrial / remote), so the topology genuinely bites: client links
//! bottleneck at the regional backhaul, two regions run tighter
//! deadlines, and the rural/remote regions occasionally go dark for a
//! round, dropping their whole sampled cohort (the `edge_drops` CSV
//! column).
//!
//!     cargo run --release --example edge_fleet
//!
//! Expected shape: the flat run and the E=4 run train to similar
//! accuracy, but the E=4 rows lose whole cohorts to edge outages and
//! the per-edge ledger shows the asymmetric backhaul split — while still
//! summing back to the flat totals integer-for-integer (DESIGN.md §13).

use zowarmup::config::Scale;
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{image_setup, linear_lrs};
use zowarmup::fed::server::Federation;
use zowarmup::metrics::MdTable;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let data_cfg = scale.data();
    let scenario = Scenario::preset("geo-iot").expect("bundled preset");

    let mut t = MdTable::new(&[
        "topology",
        "final acc %",
        "dropped",
        "edge drops",
        "up-link KB",
        "catch-up KB",
    ]);
    for (label, edges) in [("flat (E=1)", 1usize), ("two-tier (E=4)", 4)] {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = scenario.clone();
        cfg.edges = edges;
        // geo-iot's FO gateway tier is 5% of the fleet — run pure ZO so
        // the demo never depends on the warm-capable draw
        cfg.pivot = 0;
        cfg.ckpt_every = 4;
        let s = image_setup(SynthKind::Synth10, &data_cfg, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        fed.run()?;
        t.row(vec![
            label.to_string(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            fed.log.total_dropped().to_string(),
            fed.log.total_edge_drops().to_string(),
            format!("{:.3}", fed.ledger.up_total as f64 / 1e3),
            format!("{:.3}", fed.ledger.catch_up_down_total as f64 / 1e3),
        ]);
        // the per-edge attribution: which region's backhaul carries the
        // round, and the reduction back to the flat totals
        if edges > 1 {
            eprintln!("[{label}] per-edge ledger:");
            for (e, row) in fed.ledger.per_edge.iter().enumerate() {
                let name = fed
                    .cfg
                    .scenario
                    .edge_profile(e)
                    .map(|ep| ep.name.as_str())
                    .unwrap_or("edge");
                eprintln!(
                    "  edge {e} ({name:>10}): up {:>9} B  down {:>9} B  catch-up {:>7} B",
                    row.up, row.down, row.catch_up_down
                );
            }
            let (eu, ed, ec) = fed.ledger.edge_totals();
            assert_eq!(
                (eu, ed, ec),
                (
                    fed.ledger.up_total,
                    fed.ledger.down_total,
                    fed.ledger.catch_up_down_total
                ),
                "per-edge ledger must sum to the flat totals"
            );
            eprintln!(
                "  reduction check: per-edge sums == flat totals \
                 ({eu} B up, {ed} B down, {ec} B catch-up)"
            );
        }
    }
    println!("{}", t.render());
    println!(
        "Knobs: `--edges 4` (also valid in --config JSON); edge \
         rate/deadline/outage modeling needs a scenario with an \
         `\"edges\": [...]` block (geo-iot / geo-phones presets). Try\n\
         `zowarmup train --scenario geo-iot --edges 4 --pivot 0` or\n\
         `zowarmup exp topo --scale smoke` for the E x N sweep."
    );
    Ok(())
}
