//! Scenario: late joiners and flaky rejoiners under seed-history
//! checkpointing.
//!
//! The seed protocol's negligible downlink assumes every participant
//! receives every round's (seed, ΔL) broadcast. A client that joins late
//! or sits rounds out is *stale*: it must replay the seed history it
//! missed before it can evaluate seeds against the current model. The
//! `ckpt` subsystem bounds that catch-up — the server snapshots the
//! parameters every `--ckpt-every` ZO rounds, compacts the seed log to
//! the tail, and charges each stale client the cheaper of
//! `snapshot + tail` vs pure tail replay (DESIGN.md §7).
//!
//! This example runs ZOWarmUp on identical data under the `churn` fleet
//! (25% always-on anchors, 35% clients absent a third of their rounds,
//! 40% joining only at round 8) while sweeping the checkpoint cadence,
//! and reports accuracy, client-rounds missed, and where the downlink
//! goes. The `off` row is the seed repo's implicit free-rejoin
//! accounting.
//!
//!     cargo run --release --example late_joiners
//!
//! Expected shape: accuracy is cadence-independent (reconstruction is
//! bit-exact; only accounting changes), total downlink grows with the
//! honesty of the catch-up charge, and frequent snapshots trade longer
//! tail replays for snapshot-sized downloads.

use zowarmup::config::Scale;
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{image_setup, linear_lrs};
use zowarmup::fed::server::Federation;
use zowarmup::metrics::MdTable;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let data_cfg = scale.data();

    let mut t = MdTable::new(&[
        "ckpt-every",
        "final acc %",
        "missed (client-rounds)",
        "catch-up MB",
        "down-link MB",
        "snapshots",
        "max tail (rounds)",
    ]);
    for every in [0usize, 1, 5, 20] {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = Scenario::preset("churn").expect("bundled preset");
        cfg.ckpt_every = every;
        let s = image_setup(SynthKind::Synth10, &data_cfg, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        t.row(vec![
            if every == 0 { "off".into() } else { every.to_string() },
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            fed.log.total_dropped().to_string(),
            format!("{:.4}", fed.ledger.catch_up_down_total as f64 / 1e6),
            format!("{:.4}", fed.ledger.down_total as f64 / 1e6),
            fed.ckpt.snapshots_taken.to_string(),
            fed.ckpt.max_tail_rounds.to_string(),
        ]);
        eprintln!(
            "[ckpt-every {every}] done in {:.1}s ({} client-rounds missed)",
            t0.elapsed().as_secs_f64(),
            fed.log.total_dropped()
        );
    }
    println!("{}", t.render());
    println!(
        "Churn fields are per-tier scenario JSON (`join_round`, `absent_rate`;\n\
         schema: README.md / rust/src/exp/README.md). Try\n\
         `zowarmup train --scenario churn --ckpt-every 5` or\n\
         `zowarmup exp ckpt --scale smoke` for the full cadence ablation."
    );
    Ok(())
}
