//! Scenario: capability-adaptive seed budgets on an edge spectrum.
//!
//! The uniform protocol issues every ZO client the same S probes per
//! round, so the round is paced by its slowest participant while the
//! strong tiers idle after finishing early. With `--adaptive-s` the
//! server inverts the round-timeline model instead (DESIGN.md §9): each
//! sampled client gets the largest S_j ∈ [s-min, s-max] whose simulated
//! download → compute → upload timeline (catch-up charge included) fits
//! the round budget — the scenario deadline when one is set, otherwise
//! the slowest sampled client's uniform-S timeline. Strong devices
//! convert their idle wait into extra perturbations; the aggregate's
//! variance drops; the uplink grows by only 4 bytes per extra probe.
//!
//! This example prints the per-tier probe budgets the planner assigns
//! under the `edge-spectrum` fleet, then runs uniform vs adaptive vs
//! adaptive+guard federations on identical data and compares accuracy,
//! issued probes, and effective variance.
//!
//!     cargo run --release --example adaptive_fleet
//!
//! Expected shape: servers/desktops get the s-max ceiling, mobiles sit in
//! the middle, IoT devices near the uniform S; adaptive rows issue
//! several times the probes at (near-)identical simulated round time, and
//! the effective variance of the aggregated SPSA step drops accordingly.

use zowarmup::config::{Scale, VarianceGuard};
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{image_setup, linear_lrs};
use zowarmup::fed::server::Federation;
use zowarmup::metrics::MdTable;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let scale = Scale::Default;
    let data_cfg = scale.data();

    // ---- the planner's view: per-tier probe budgets -------------------
    let mut cfg = scale.fed();
    linear_lrs(&mut cfg);
    cfg.scenario = Scenario::preset("edge-spectrum").expect("bundled preset");
    cfg.zo.adaptive_s = true;
    cfg.zo.s_min = 1;
    cfg.zo.s_max = 16;
    let s = image_setup(SynthKind::Synth10, &data_cfg, &cfg);
    let init = ParamVec::zeros(s.backend.dim());
    let fed = Federation::new(cfg.clone(), &s.backend, s.shards, s.test, init)?;
    let all: Vec<usize> = (0..cfg.clients).collect();
    let mut per_tier: Vec<(String, Vec<usize>)> = Vec::new();
    for (cid, s_j) in fed.planned_seed_counts(&all) {
        let tier = fed.pop.profile(cid).tier;
        match per_tier.iter_mut().find(|(t, _)| *t == tier) {
            Some((_, v)) => v.push(s_j),
            None => per_tier.push((tier, vec![s_j])),
        }
    }
    println!("Planned probe budgets (uniform S = {}):\n", cfg.zo.s_seeds);
    let mut t = MdTable::new(&["tier", "clients", "min S_j", "mean S_j", "max S_j"]);
    for (tier, v) in &per_tier {
        let mean = v.iter().sum::<usize>() as f64 / v.len() as f64;
        t.row(vec![
            tier.clone(),
            v.len().to_string(),
            v.iter().min().unwrap().to_string(),
            format!("{mean:.1}"),
            v.iter().max().unwrap().to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- end-to-end: uniform vs adaptive vs adaptive+guard ------------
    let mut t = MdTable::new(&[
        "mode",
        "final acc %",
        "probes issued",
        "up-link KB",
        "mean eff. var",
    ]);
    for (label, adaptive, guard) in [
        ("uniform", false, VarianceGuard::Off),
        ("adaptive", true, VarianceGuard::Off),
        ("adaptive+invvar", true, VarianceGuard::InvVar),
    ] {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = Scenario::preset("edge-spectrum").expect("bundled preset");
        cfg.zo.adaptive_s = adaptive;
        cfg.zo.guard = guard;
        let s = image_setup(SynthKind::Synth10, &data_cfg, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        t.row(vec![
            label.to_string(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            fed.ledger.seeds_total.to_string(),
            format!("{:.3}", fed.ledger.up_total as f64 / 1e3),
            format!("{:.3e}", fed.log.mean_eff_var()),
        ]);
        eprintln!(
            "[{label}] done in {:.1}s ({} probes issued)",
            t0.elapsed().as_secs_f64(),
            fed.ledger.seeds_total
        );
    }
    println!("{}", t.render());
    println!(
        "Knobs: `--adaptive-s true --s-min 1 --s-max 16 --guard invvar`\n\
         (also valid in --config JSON). Try\n\
         `zowarmup train --scenario edge-spectrum --adaptive-s true` or\n\
         `zowarmup exp adaptive --scale smoke` for the full ablation."
    );
    Ok(())
}
