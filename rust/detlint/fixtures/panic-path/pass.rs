//@path rust/src/fed/engine.rs
// Errors propagate; infallible fallbacks use the _or family, which the
// rule deliberately does not match.
pub fn next_event(queue: &mut Vec<usize>) -> Option<usize> {
    queue.pop()
}

pub fn first_or_zero(queue: &[usize]) -> usize {
    queue.first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn pops() {
        // unwrap in test scaffolding is fine — masked
        assert_eq!(super::next_event(&mut vec![7]).unwrap(), 7);
    }
}
