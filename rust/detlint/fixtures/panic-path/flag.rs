//@path rust/src/fed/engine.rs
// A panic in the event loop deadlocks in-flight workers.
pub fn next_event(queue: &mut Vec<usize>) -> usize {
    queue.pop().expect("event queue must not be empty")
}
