//@path rust/src/sim/fixture.rs
// Salts are re-exported from the central registry, never defined here.
pub use crate::util::rng::salts::SIM_SALT;

pub fn stream(seed: u64) -> u64 {
    seed ^ SIM_SALT
}
