pub mod salts {
    pub const ALPHA_SALT: u64 = 0x51D_7E57;
    pub const BETA_SALT: u64 = 0xC4_0E11;
    pub const GAMMA_SALT: u64 = 0xA51_C51D;
}
