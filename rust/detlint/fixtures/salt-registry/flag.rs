//@path rust/src/sim/fixture.rs
// A stream salt defined at a use site instead of the central registry:
// nothing checks it against the other domains' salts for distinctness.
pub const ROGUE_SALT: u64 = 0xBAD_CAFE;

pub fn stream(seed: u64) -> u64 {
    seed ^ ROGUE_SALT
}
