//@path rust/src/fed/fixture.rs
// detlint: allow(hash-iter)
use std::collections::HashMap;

// detlint: allow(no-such-rule) — the rule id does not exist
pub type Cache = HashMap<usize, usize>;
