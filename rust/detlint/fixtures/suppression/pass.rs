//@path rust/src/fed/fixture.rs
pub struct Cache {
    // detlint: allow(hash-iter) — keyed get/insert only, never
    // iterated, so the nondeterministic order cannot reach any fold
    map: std::collections::HashMap<usize, usize>,
}

impl Cache {
    pub fn get(&self, k: usize) -> Option<usize> {
        self.map.get(&k).copied()
    }
}
