//@path rust/src/zo/fixture.rs
// Hard bound: holds in release builds too.
pub fn pack(round: usize, cid: usize) -> u64 {
    assert!(round < (1 << 24), "round overflows the 24-bit field");
    ((round as u64) << 40) | cid as u64
}
