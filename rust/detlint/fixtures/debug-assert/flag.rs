//@path rust/src/zo/fixture.rs
// A debug_assert guarding a seed-packing bound vanishes in release:
// an overflowing field silently aliases another stream.
pub fn pack(round: usize, cid: usize) -> u64 {
    debug_assert!(round < (1 << 24), "round overflows the 24-bit field");
    ((round as u64) << 40) | cid as u64
}
