//@path rust/src/zo/fixture.rs
// partial_cmp on floats panics on NaN (or silently reorders under
// max_by) — a diverged run would crash or fork the trace.
pub fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
