//@path rust/src/zo/fixture.rs
// total_cmp is a total order: NaN sorts deterministically, no panic.
pub fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

pub struct Version(u64);

impl PartialEq for Version {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}

impl Eq for Version {}

impl Ord for Version {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.cmp(&other.0)
    }
}

impl PartialOrd for Version {
    // defining the trait method is fine — only call sites are flagged
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
