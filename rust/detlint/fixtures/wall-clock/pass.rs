//@path rust/src/ckpt/fixture.rs
// Simulated time comes in via the event clock, a pure input.
pub fn round_deadline_ms(event_clock_ms: f64) -> f64 {
    event_clock_ms + 250.0
}
