//@path rust/src/ckpt/fixture.rs
// Host wall-clock time in a trace-critical module: every run differs.
pub fn round_deadline_ms() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_millis() + 250
}
