fn bench(b: &mut Bench, workers: usize) {
    for kernel in ["scalar", "lanes"] {
        b.iter(&format!("fold d=11M kernel={kernel} w={workers}"), || 0);
    }
}
