pub const CSV_COLUMNS: [&str; 16] = [
    "round", "phase", "train_loss", "test_acc", "test_loss", "bytes_up",
    "bytes_down", "dropped", "catch_up_down", "seeds_issued", "eff_var",
    "wall_ms", "staleness", "model_version", "makespan_ms", "edge_drops",
];

pub const WALL_MS_FIELD: usize = 12;
