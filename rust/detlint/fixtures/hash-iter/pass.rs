//@path rust/src/fed/fixture.rs
use std::collections::BTreeMap;

// A BTreeMap iterates in key order: the fold is reproducible.
pub fn fold(contributions: &BTreeMap<usize, f64>) -> f64 {
    contributions.values().sum()
}

#[cfg(test)]
mod tests {
    // test scaffolding may use unordered maps freely — masked
    use std::collections::HashMap;

    #[test]
    fn counts() {
        let m: HashMap<usize, f64> = HashMap::new();
        assert!(m.is_empty());
    }
}
