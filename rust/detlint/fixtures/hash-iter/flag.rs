//@path rust/src/fed/fixture.rs
use std::collections::HashMap;

// Iterating an unordered map into a float fold makes the sum depend on
// the hasher's random state — a different trace every run.
pub fn fold(contributions: &HashMap<usize, f64>) -> f64 {
    contributions.values().sum()
}
