//@path rust/src/comm/fixture.rs
// OS-entropy randomness in a trace-critical module: unreproducible.
pub fn jitter_ms() -> u64 {
    let sample: u64 = rand::random();
    sample % 10
}
