//@path rust/src/comm/fixture.rs
// Randomness derives from a seeded in-tree generator.
use crate::util::rng::Xoshiro256;

pub fn jitter_ms(seed: u64) -> u64 {
    Xoshiro256::seed_from(seed).next_u64() % 10
}
