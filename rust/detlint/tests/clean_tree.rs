//! Meta-test: the repo's own tree must be detlint-clean. This is what
//! keeps the lint honest — every rule it enforces is already satisfied
//! (or explicitly, justifiedly suppressed) in the code it polices.

use std::path::Path;

#[test]
fn repo_tree_is_detlint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = detlint::scan_repo(&root);
    assert!(
        findings.is_empty(),
        "detlint found {} violation(s) in the tree:\n{}",
        findings.len(),
        findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
