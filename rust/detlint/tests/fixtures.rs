//! Fixture corpus: every rule has at least one should-flag and one
//! should-pass fixture. Each fixture's first line may carry an
//! `//@path <repo-relative path>` directive so the scan sees it under
//! the scope (trace-critical module, engine file, ...) the rule needs.

use std::path::{Path, PathBuf};

/// Rules exercised through per-file fixtures (`schema-sync` has its own
/// mini repo trees below instead).
const FILE_RULES: [&str; 8] = [
    "salt-registry",
    "hash-iter",
    "float-ord",
    "wall-clock",
    "thread-rng",
    "debug-assert",
    "panic-path",
    "suppression",
];

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn fixture(rel: &str) -> String {
    let p = fixture_dir().join(rel);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("missing fixture {rel}: {e}"))
}

fn pretend_path(text: &str) -> &str {
    text.lines()
        .next()
        .and_then(|l| l.strip_prefix("//@path "))
        .map(str::trim)
        .unwrap_or("rust/src/fed/fixture.rs")
}

#[test]
fn flag_fixtures_trip_their_rule() {
    for rule in FILE_RULES {
        let text = fixture(&format!("{rule}/flag.rs"));
        let findings = detlint::scan_rust_source(pretend_path(&text), &text);
        assert!(
            findings.iter().any(|f| f.rule == rule),
            "fixture {rule}/flag.rs did not trip `{rule}`: {findings:?}"
        );
    }
}

#[test]
fn pass_fixtures_are_clean() {
    for rule in FILE_RULES {
        let text = fixture(&format!("{rule}/pass.rs"));
        let findings = detlint::scan_rust_source(pretend_path(&text), &text);
        assert!(
            findings.is_empty(),
            "fixture {rule}/pass.rs must be clean: {findings:?}"
        );
    }
}

#[test]
fn unjustified_suppression_does_not_suppress() {
    let text = fixture("suppression/flag.rs");
    let findings = detlint::scan_rust_source(pretend_path(&text), &text);
    // the bare allow(hash-iter) is itself flagged AND the HashMap on
    // the next line still fires — an unjustified allow is inert
    assert!(findings.iter().any(|f| f.rule == "suppression"));
    assert!(findings.iter().any(|f| f.rule == "hash-iter"), "{findings:?}");
}

#[test]
fn registry_distinctness() {
    let dup = fixture("salt-registry/registry_dup.rs");
    let findings = detlint::check_salt_registry(detlint::REGISTRY_PATH, &dup);
    assert!(
        findings
            .iter()
            .any(|f| f.rule == "salt-registry" && f.message.contains("duplicates")),
        "{findings:?}"
    );
    let ok = fixture("salt-registry/registry_ok.rs");
    let findings = detlint::check_salt_registry(detlint::REGISTRY_PATH, &ok);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn schema_sync_flag_tree() {
    let findings = detlint::check_schema(&fixture_dir().join("schema-sync/flag_tree"));
    assert!(findings.iter().all(|f| f.rule == "schema-sync"), "{findings:?}");
    // one drift class each: stale cut range, wall_ms included in a
    // diff, phantom --require row
    assert!(
        findings.iter().any(|f| f.message.contains("skips deterministic")),
        "{findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.message.contains("includes wall_ms")),
        "{findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.message.contains("--require")),
        "{findings:?}"
    );
}

#[test]
fn schema_sync_pass_tree() {
    let findings = detlint::check_schema(&fixture_dir().join("schema-sync/pass_tree"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn json_output_is_escaped() {
    let findings = vec![detlint::Finding {
        rule: "schema-sync",
        path: "a\\b.rs".to_string(),
        line: 3,
        message: "quote \" and\nnewline".to_string(),
    }];
    let json = detlint::to_json(&findings);
    assert!(json.contains("\"line\": 3"), "{json}");
    assert!(json.contains("a\\\\b.rs"), "{json}");
    assert!(json.contains("quote \\\" and\\nnewline"), "{json}");
    assert_eq!(detlint::to_json(&[]), "[\n]\n");
}
