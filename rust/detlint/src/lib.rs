//! `detlint` — the workspace's determinism static-analysis pass.
//!
//! The training stack promises bit-exact traces: the same config must
//! produce byte-identical metrics CSVs across thread counts, engine
//! modes and topologies (see `DESIGN.md` §14). Most regressions against
//! that promise are mechanical — an unordered map iterated into a fold,
//! a float sort that panics on NaN, a wall-clock read feeding a
//! compared column, a `debug_assert!` guarding a seed-packing invariant
//! that silently corrupts release builds. This crate is a small,
//! dependency-free line/token scanner that rejects those patterns
//! before they reach a trace.
//!
//! Rules (ids are the `// detlint: allow(<rule>)` suppression keys):
//!
//! * `salt-registry` — every `*_SALT: u64` protocol constant must be
//!   defined in the central registry (`rust/src/util/rng.rs`, module
//!   `salts`) and the registered values must be pairwise distinct.
//! * `hash-iter` — no `HashMap`/`HashSet` in trace-critical modules
//!   (`fed`, `zo`, `sim`, `ckpt`, `comm`): iteration order is
//!   nondeterministic.
//! * `float-ord` — no `partial_cmp` call sites anywhere in `rust/src`
//!   (trait `fn partial_cmp` definitions are exempt): float comparisons
//!   must go through `total_cmp`, which is total and NaN-safe.
//! * `wall-clock` — no `Instant::now`/`SystemTime` in trace-critical
//!   modules; simulated time comes from the event clock.
//! * `thread-rng` — no `thread_rng`/`rand::` in trace-critical
//!   modules; all randomness derives from seeded in-tree generators.
//! * `debug-assert` — no `debug_assert!` in trace-critical modules:
//!   invariants that protect stream derivations must hold in release.
//! * `panic-path` — no `.unwrap()`/`.expect(` in the async engine
//!   event loop (`rust/src/fed/engine.rs`): a panic there deadlocks
//!   in-flight workers instead of surfacing an error.
//! * `schema-sync` — cross-artifact drift: the `cut -d, -f` ranges in
//!   the CI workflow must agree with the metrics CSV column contract
//!   (`CSV_COLUMNS`/`WALL_MS_FIELD`), and every bench-gate `--require`
//!   row must match a bench name template in `rust/benches`.
//! * `suppression` — meta rule: `detlint: allow(...)` comments must
//!   name a known rule and carry a justification on the same line.
//!
//! Suppressions: a comment line `// detlint: allow(<rule>) — <why>`
//! disables `<rule>` on the next line that contains code (intervening
//! comment-only lines extend the justification). The justification text
//! is mandatory. `#[cfg(test)]` items and modules are skipped entirely:
//! the rules police the runtime trace surface, not test scaffolding.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

/// Every rule id, in severity-agnostic canonical order.
pub const RULES: [&str; 9] = [
    "salt-registry",
    "hash-iter",
    "float-ord",
    "wall-clock",
    "thread-rng",
    "debug-assert",
    "panic-path",
    "schema-sync",
    "suppression",
];

/// Repo-relative path of the central salt registry file.
pub const REGISTRY_PATH: &str = "rust/src/util/rng.rs";

/// Repo-relative path of the async engine event loop.
pub const ENGINE_PATH: &str = "rust/src/fed/engine.rs";

/// Module roots under `rust/src/` whose code feeds the bit-exact trace.
pub const TRACE_CRITICAL: [&str; 5] = ["fed", "zo", "sim", "ckpt", "comm"];

/// One violation. `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

// ---------------------------------------------------------------------------
// Lexer: split source into a code stream and a comment stream (both with
// the original line structure), plus the string-literal contents. Rules
// match on the code stream only, so banned tokens inside comments or
// strings can never false-positive; suppression comments are parsed from
// the comment stream; bench name templates come from the string list.
// ---------------------------------------------------------------------------

struct Lexed {
    /// per line: source with comments and string contents blanked out
    code: Vec<String>,
    /// per line: source with everything except comments blanked out
    comments: Vec<String>,
    /// string-literal contents with their 1-based start line
    strings: Vec<(usize, String)>,
}

#[derive(Clone, Copy, PartialEq)]
enum St {
    Code,
    LineComment,
    BlockComment,
    Str,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Detect `r"`, `r#"`, `b"`, `br#"` ... string openers at `i`; returns
/// (chars consumed by the opener, raw-delimiter hash count).
fn raw_string_open(ch: &[char], i: usize) -> Option<(usize, Option<u32>)> {
    if i > 0 && is_ident(ch[i - 1]) {
        return None;
    }
    let mut j = i;
    if ch.get(j) == Some(&'b') {
        j += 1;
    }
    let mut raw = false;
    if ch.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    if j == i {
        return None;
    }
    let mut hashes = 0u32;
    if raw {
        while ch.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
    }
    if ch.get(j) == Some(&'"') {
        return Some((j + 1 - i, if raw { Some(hashes) } else { None }));
    }
    None
}

fn lex(text: &str) -> Lexed {
    let ch: Vec<char> = text.chars().collect();
    let n = ch.len();
    let mut code = String::with_capacity(n);
    let mut com = String::with_capacity(n);
    let mut strings = Vec::new();
    let mut st = St::Code;
    let mut block_depth = 0u32;
    let mut raw_hashes: Option<u32> = None;
    let mut sbuf = String::new();
    let mut sstart = 0usize;
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = ch[i];
        if c == '\n' {
            code.push('\n');
            com.push('\n');
            line += 1;
            i += 1;
            if st == St::LineComment {
                st = St::Code;
            } else if st == St::Str {
                sbuf.push('\n');
            }
            continue;
        }
        match st {
            St::LineComment => {
                com.push(c);
                code.push(' ');
                i += 1;
            }
            St::BlockComment => {
                if c == '*' && ch.get(i + 1) == Some(&'/') {
                    com.push_str("*/");
                    code.push_str("  ");
                    i += 2;
                    block_depth -= 1;
                    if block_depth == 0 {
                        st = St::Code;
                    }
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    com.push_str("/*");
                    code.push_str("  ");
                    i += 2;
                    block_depth += 1;
                } else {
                    com.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                let closed = match raw_hashes {
                    None => {
                        if c == '\\' && i + 1 < n {
                            sbuf.push(c);
                            code.push(' ');
                            com.push(' ');
                            // leave an escaped newline for the top-level
                            // handler so line alignment survives
                            if ch[i + 1] == '\n' {
                                i += 1;
                            } else {
                                sbuf.push(ch[i + 1]);
                                code.push(' ');
                                com.push(' ');
                                i += 2;
                            }
                            continue;
                        }
                        c == '"'
                    }
                    Some(h) => {
                        c == '"' && (1..=h as usize).all(|k| ch.get(i + k) == Some(&'#'))
                    }
                };
                if closed {
                    let extra = raw_hashes.unwrap_or(0) as usize;
                    for _ in 0..=extra {
                        code.push(' ');
                        com.push(' ');
                    }
                    i += 1 + extra;
                    strings.push((sstart, std::mem::take(&mut sbuf)));
                    st = St::Code;
                } else {
                    sbuf.push(c);
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
            }
            St::Code => {
                if c == '/' && ch.get(i + 1) == Some(&'/') {
                    com.push_str("//");
                    code.push_str("  ");
                    i += 2;
                    st = St::LineComment;
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    com.push_str("/*");
                    code.push_str("  ");
                    i += 2;
                    block_depth = 1;
                    st = St::BlockComment;
                } else if let Some((skip, hashes)) =
                    ((c == 'r' || c == 'b').then(|| raw_string_open(&ch, i))).flatten()
                {
                    for _ in 0..skip {
                        code.push(' ');
                        com.push(' ');
                    }
                    i += skip;
                    raw_hashes = hashes;
                    sbuf.clear();
                    sstart = line;
                    st = St::Str;
                } else if c == '"' {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                    raw_hashes = None;
                    sbuf.clear();
                    sstart = line;
                    st = St::Str;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if ch.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < n && ch[j] != '\'' && ch[j] != '\n' {
                            j += 1;
                        }
                        if j < n && ch[j] == '\'' {
                            j += 1;
                        }
                        for _ in i..j {
                            code.push(' ');
                            com.push(' ');
                        }
                        i = j;
                    } else if ch.get(i + 2) == Some(&'\'') && ch.get(i + 1) != Some(&'\'') {
                        code.push_str("   ");
                        com.push_str("   ");
                        i += 3;
                    } else {
                        code.push('\'');
                        com.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    com.push(' ');
                    i += 1;
                }
            }
        }
    }
    Lexed {
        code: code.split('\n').map(str::to_string).collect(),
        comments: com.split('\n').map(str::to_string).collect(),
        strings,
    }
}

// ---------------------------------------------------------------------------
// Test-region masking: `#[cfg(test)]` covers the attributed item — a
// whole `mod tests { .. }`, a single field (terminated by `,`), or a
// single statement/use (terminated by `;`). Masked lines are invisible
// to every rule: test scaffolding may use wall clocks and unwraps.
// ---------------------------------------------------------------------------

fn test_mask(code_lines: &[String]) -> Vec<bool> {
    let joined = code_lines.join("\n");
    let ch: Vec<char> = joined.chars().collect();
    let mut line_of = vec![0usize; ch.len()];
    let mut cur = 0usize;
    for (k, c) in ch.iter().enumerate() {
        line_of[k] = cur;
        if *c == '\n' {
            cur += 1;
        }
    }
    let mut mask = vec![false; code_lines.len()];
    let needle: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut i = 0usize;
    while i + needle.len() <= ch.len() {
        if ch[i..i + needle.len()] != needle[..] {
            i += 1;
            continue;
        }
        let start_line = line_of[i];
        let mut j = i + needle.len();
        // skip whitespace and any further attributes on the item
        loop {
            while j < ch.len() && ch[j].is_whitespace() {
                j += 1;
            }
            if j < ch.len() && ch[j] == '#' {
                let mut depth = 0i32;
                while j < ch.len() {
                    if ch[j] == '[' {
                        depth += 1;
                    } else if ch[j] == ']' {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // walk the item body: a braced item masks to its closing brace,
        // a field/statement masks to the `,`/`;` at top level
        let mut brace = 0i32;
        let mut group = 0i32;
        let mut seen_brace = false;
        while j < ch.len() {
            match ch[j] {
                '{' => {
                    brace += 1;
                    seen_brace = true;
                }
                '}' => {
                    brace -= 1;
                    if seen_brace && brace == 0 {
                        break;
                    }
                }
                '(' | '[' => group += 1,
                ')' | ']' => group -= 1,
                ';' | ',' if !seen_brace && brace == 0 && group == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let end_line = if ch.is_empty() {
            start_line
        } else {
            line_of[j.min(ch.len() - 1)]
        };
        for m in mask.iter_mut().take(end_line + 1).skip(start_line) {
            *m = true;
        }
        i = j.max(i + needle.len());
    }
    mask
}

// ---------------------------------------------------------------------------
// Suppressions
// ---------------------------------------------------------------------------

fn canonical_rule(name: &str) -> Option<&'static str> {
    RULES.iter().find(|r| **r == name).copied()
}

/// Parse `detlint: allow(<rule>)` markers out of one line's comment
/// text. Returns (rule-as-written, justification-present).
fn parse_allows(comment: &str) -> Vec<(String, bool)> {
    const MARKER: &str = "detlint: allow(";
    let mut out = Vec::new();
    let mut search = 0usize;
    while let Some(p) = comment[search..].find(MARKER) {
        let at = search + p + MARKER.len();
        let rest = &comment[at..];
        match rest.find(')') {
            Some(close) => {
                let rule = rest[..close].trim().to_string();
                let tail = rest[close + 1..]
                    .trim_start()
                    .trim_start_matches(['—', '–', '-', ':'])
                    .trim();
                out.push((rule, !tail.is_empty()));
                search = at + close;
            }
            None => {
                out.push((rest.trim().to_string(), false));
                break;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Token helpers (byte-position scans over the blanked code stream)
// ---------------------------------------------------------------------------

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `code` contain `tok` starting at an identifier boundary?
/// (`prefix_only` skips the trailing-boundary check, so `debug_assert`
/// also matches `debug_assert_eq!`.)
fn has_token(code: &str, tok: &str, prefix_only: bool) -> bool {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(p) = code[search..].find(tok) {
        let at = search + p;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + tok.len();
        let after_ok = prefix_only || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        search = at + 1;
    }
    false
}

/// First `partial_cmp` call site on the line, skipping trait method
/// definitions (`fn partial_cmp(...)`).
fn partial_cmp_call(code: &str) -> bool {
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(p) = code[search..].find("partial_cmp") {
        let at = search + p;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + "partial_cmp".len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok && !code[..at].trim_end().ends_with("fn") {
            return true;
        }
        search = at + 1;
    }
    false
}

/// Parse a `const <IDENT>_SALT: u64 [= <literal>];` definition on one
/// line of blanked code. Returns (name, literal-if-present).
fn parse_salt_const(code: &str) -> Option<(String, Option<String>)> {
    let mut search = 0usize;
    while let Some(p) = code[search..].find("const ") {
        let at = search + p;
        let boundary = at == 0 || !is_ident_byte(code.as_bytes()[at - 1]);
        search = at + "const ".len();
        if !boundary {
            continue;
        }
        let rest = code[search..].trim_start();
        let name: String = rest.chars().take_while(|c| is_ident(*c)).collect();
        if name.is_empty() || !name.ends_with("_SALT") {
            continue;
        }
        let after = rest[name.len()..].trim_start();
        let Some(after) = after.strip_prefix(':') else {
            continue;
        };
        let after = after.trim_start();
        if !after.starts_with("u64") {
            continue;
        }
        let after = after["u64".len()..].trim_start();
        let lit = after.strip_prefix('=').map(|v| {
            v.trim_start()
                .chars()
                .take_while(|c| *c != ';')
                .collect::<String>()
                .trim()
                .to_string()
        });
        return Some((name, lit));
    }
    None
}

fn parse_u64_literal(lit: &str) -> Option<u64> {
    let t = lit.trim().trim_end_matches("u64").replace('_', "");
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else if let Some(bin) = t.strip_prefix("0b") {
        u64::from_str_radix(bin, 2).ok()
    } else if let Some(oct) = t.strip_prefix("0o") {
        u64::from_str_radix(oct, 8).ok()
    } else {
        t.parse().ok()
    }
}

// ---------------------------------------------------------------------------
// Per-file scan
// ---------------------------------------------------------------------------

fn is_trace_critical(rel: &str) -> bool {
    match rel.strip_prefix("rust/src/") {
        Some(rest) => TRACE_CRITICAL.iter().any(|d| {
            rest.strip_prefix(d)
                .map(|tail| tail.starts_with('/') || tail == ".rs")
                .unwrap_or(false)
        }),
        None => false,
    }
}

/// Scan one Rust source file. `rel` is its repo-relative path with `/`
/// separators; the path decides which rule scopes apply.
pub fn scan_rust_source(rel: &str, text: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    let lx = lex(text);
    let mask = test_mask(&lx.code);
    let trace_critical = is_trace_critical(&rel);
    let registry = rel == REGISTRY_PATH;
    let engine = rel == ENGINE_PATH;
    let mut out = Vec::new();
    let mut pending: Vec<&'static str> = Vec::new();
    for (idx, code) in lx.code.iter().enumerate() {
        let lineno = idx + 1;
        for (rule, justified) in parse_allows(&lx.comments[idx]) {
            match canonical_rule(&rule) {
                None => out.push(Finding {
                    rule: "suppression",
                    path: rel.clone(),
                    line: lineno,
                    message: format!("unknown rule `{rule}` in `detlint: allow(..)`"),
                }),
                Some(r) if !justified => out.push(Finding {
                    rule: "suppression",
                    path: rel.clone(),
                    line: lineno,
                    message: format!(
                        "`allow({r})` needs a justification on the same line \
                         (`// detlint: allow({r}) — <why this is safe>`)"
                    ),
                }),
                Some(r) => pending.push(r),
            }
        }
        if code.trim().is_empty() {
            continue;
        }
        let active = std::mem::take(&mut pending);
        if mask[idx] {
            continue;
        }
        let mut emit = |rule: &'static str, message: String| {
            if !active.contains(&rule) {
                out.push(Finding {
                    rule,
                    path: rel.clone(),
                    line: lineno,
                    message,
                });
            }
        };
        if !registry {
            if let Some((name, _)) = parse_salt_const(code) {
                emit(
                    "salt-registry",
                    format!(
                        "`{name}` defined outside the central registry — move it to \
                         `util::rng::salts` and re-export it here"
                    ),
                );
            }
        }
        if partial_cmp_call(code) {
            emit(
                "float-ord",
                "`partial_cmp` call site — use `total_cmp` (total order, NaN-safe) \
                 so a NaN cannot panic or reorder a trace"
                    .to_string(),
            );
        }
        if trace_critical {
            for tok in ["HashMap", "HashSet"] {
                if has_token(code, tok, false) {
                    emit(
                        "hash-iter",
                        format!(
                            "`{tok}` in a trace-critical module: iteration order is \
                             nondeterministic — use an index/BTree structure, or \
                             suppress with a keyed-access-only justification"
                        ),
                    );
                }
            }
            if code.contains("Instant::now") || has_token(code, "SystemTime", false) {
                emit(
                    "wall-clock",
                    "host wall-clock read in a trace-critical module — simulated \
                     time must come from the event clock"
                        .to_string(),
                );
            }
            if has_token(code, "thread_rng", false) || code.contains("rand::") {
                emit(
                    "thread-rng",
                    "OS-entropy RNG in a trace-critical module — derive from the \
                     seeded in-tree generators (`util::rng`)"
                        .to_string(),
                );
            }
            if has_token(code, "debug_assert", true) {
                emit(
                    "debug-assert",
                    "`debug_assert!` in a trace-critical module — promote to a hard \
                     `assert!` so release builds cannot silently corrupt a stream"
                        .to_string(),
                );
            }
        }
        if engine && (code.contains(".unwrap()") || code.contains(".expect(")) {
            emit(
                "panic-path",
                "`.unwrap()`/`.expect(..)` in the async engine event loop — a panic \
                 here deadlocks in-flight workers; propagate the error instead"
                    .to_string(),
            );
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Registry-level check
// ---------------------------------------------------------------------------

/// Check the central salt registry file: every registered value must be
/// parseable and pairwise distinct (a collision makes two supposedly
/// independent RNG domains emit identical streams).
pub fn check_salt_registry(rel: &str, text: &str) -> Vec<Finding> {
    let lx = lex(text);
    let mut seen: Vec<(String, u64, usize)> = Vec::new();
    let mut out = Vec::new();
    for (idx, code) in lx.code.iter().enumerate() {
        let Some((name, Some(lit))) = parse_salt_const(code) else {
            continue;
        };
        match parse_u64_literal(&lit) {
            Some(v) => {
                if let Some((other, _, oline)) = seen.iter().find(|(_, ov, _)| *ov == v) {
                    out.push(Finding {
                        rule: "salt-registry",
                        path: rel.to_string(),
                        line: idx + 1,
                        message: format!(
                            "salt `{name}` duplicates the value of `{other}` (line \
                             {oline}): {v:#x} — the two RNG domains would collide"
                        ),
                    });
                }
                seen.push((name, v, idx + 1));
            }
            None => out.push(Finding {
                rule: "salt-registry",
                path: rel.to_string(),
                line: idx + 1,
                message: format!("could not parse the literal of salt `{name}`: `{lit}`"),
            }),
        }
    }
    if seen.is_empty() {
        out.push(Finding {
            rule: "salt-registry",
            path: rel.to_string(),
            line: 1,
            message: "no `*_SALT` constants found in the registry file".to_string(),
        });
    }
    out
}

// ---------------------------------------------------------------------------
// Cross-artifact schema checks
// ---------------------------------------------------------------------------

fn parse_csv_contract(text: &str) -> Option<(usize, usize)> {
    let i = text.find("const CSV_COLUMNS")?;
    let seg = &text[i..];
    let end = seg.find("];")?;
    let ncols = seg[..end].matches('"').count() / 2;
    let j = text.find("const WALL_MS_FIELD")?;
    let eq = text[j..].find('=')?;
    let digits: String = text[j + eq + 1..]
        .chars()
        .skip_while(|c| c.is_whitespace())
        .take_while(|c| c.is_ascii_digit())
        .collect();
    let wall: usize = digits.parse().ok()?;
    if ncols == 0 || wall == 0 || wall > ncols {
        return None;
    }
    Some((ncols, wall))
}

fn parse_field_spec(spec: &str) -> Option<BTreeSet<usize>> {
    let mut set = BTreeSet::new();
    for part in spec.split(',') {
        if let Some((a, b)) = part.split_once('-') {
            let a: usize = a.parse().ok()?;
            let b: usize = b.parse().ok()?;
            if a == 0 || b < a {
                return None;
            }
            set.extend(a..=b);
        } else {
            let f: usize = part.parse().ok()?;
            if f == 0 {
                return None;
            }
            set.insert(f);
        }
    }
    Some(set)
}

fn find_cut_specs(ci: &str) -> Vec<(usize, String)> {
    const CUT: &str = "cut -d, -f";
    let mut out = Vec::new();
    for (idx, line) in ci.lines().enumerate() {
        let mut search = 0usize;
        while let Some(p) = line[search..].find(CUT) {
            let at = search + p + CUT.len();
            let spec: String = line[at..]
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == ',' || *c == '-')
                .collect();
            out.push((idx + 1, spec));
            search = at;
        }
    }
    out
}

fn find_requires(ci: &str) -> Vec<(usize, String)> {
    const REQ: &str = "--require";
    let mut out = Vec::new();
    for (idx, line) in ci.lines().enumerate() {
        let mut search = 0usize;
        while let Some(p) = line[search..].find(REQ) {
            let at = search + p + REQ.len();
            let rest = line[at..].trim_start();
            if let Some(stripped) = rest.strip_prefix('"') {
                if let Some(close) = stripped.find('"') {
                    out.push((idx + 1, stripped[..close].to_string()));
                }
            }
            search = at;
        }
    }
    out
}

/// Split a `format!` template into its literal segments (brace groups
/// become wildcards; `{{`/`}}` are literal braces).
fn template_segments(template: &str) -> Vec<String> {
    let ch: Vec<char> = template.chars().collect();
    let mut segs = vec![String::new()];
    let mut i = 0usize;
    while i < ch.len() {
        match ch[i] {
            '{' if ch.get(i + 1) == Some(&'{') => {
                segs.last_mut().unwrap().push('{');
                i += 2;
            }
            '}' if ch.get(i + 1) == Some(&'}') => {
                segs.last_mut().unwrap().push('}');
                i += 2;
            }
            '{' => {
                while i < ch.len() && ch[i] != '}' {
                    i += 1;
                }
                i += 1;
                segs.push(String::new());
            }
            c => {
                segs.last_mut().unwrap().push(c);
                i += 1;
            }
        }
    }
    segs
}

/// Could `req` (a bench-gate `--require` substring) match some
/// instantiation of the `format!` template? Exact for requires that are
/// full row names or sit inside one literal segment; permissive once a
/// require ends inside a wildcard region (the wildcard can expand to
/// anything, so any tail is satisfiable).
fn glob_could_match(template: &str, req: &str) -> bool {
    let segs = template_segments(template);
    if segs.len() == 1 {
        return segs[0].contains(req);
    }
    if segs.iter().any(|s| !s.is_empty() && s.contains(req)) {
        return true;
    }
    if !req.starts_with(segs[0].as_str()) {
        return false;
    }
    let mut rest = &req[segs[0].len()..];
    for seg in segs.iter().skip(1) {
        if seg.is_empty() {
            continue;
        }
        match rest.find(seg.as_str()) {
            Some(p) => rest = &rest[p + seg.len()..],
            None => return true,
        }
    }
    true
}

fn schema_finding(path: &str, line: usize, message: String) -> Finding {
    Finding {
        rule: "schema-sync",
        path: path.to_string(),
        line,
        message,
    }
}

/// Cross-artifact drift checks rooted at `root`: CI `cut` field ranges
/// vs the metrics CSV contract, and bench-gate `--require` rows vs the
/// bench name templates.
pub fn check_schema(root: &Path) -> Vec<Finding> {
    const METRICS: &str = "rust/src/metrics/mod.rs";
    const CI: &str = ".github/workflows/ci.yml";
    let mut out = Vec::new();
    let Ok(metrics) = fs::read_to_string(root.join(METRICS)) else {
        out.push(schema_finding(
            METRICS,
            1,
            "metrics module missing — cannot check the CSV column contract".to_string(),
        ));
        return out;
    };
    let Some((ncols, wall)) = parse_csv_contract(&metrics) else {
        out.push(schema_finding(
            METRICS,
            1,
            "`CSV_COLUMNS` / `WALL_MS_FIELD` contract constants not found".to_string(),
        ));
        return out;
    };
    let Ok(ci) = fs::read_to_string(root.join(CI)) else {
        out.push(schema_finding(
            CI,
            1,
            "CI workflow missing — cannot cross-check trace diffs".to_string(),
        ));
        return out;
    };
    for (line, spec) in find_cut_specs(&ci) {
        let Some(fields) = parse_field_spec(&spec) else {
            out.push(schema_finding(
                CI,
                line,
                format!("unparseable `cut` field spec `{spec}`"),
            ));
            continue;
        };
        if fields.contains(&wall) {
            out.push(schema_finding(
                CI,
                line,
                format!(
                    "`cut -f{spec}` includes wall_ms (f{wall}) — trace diffs must \
                     exclude the only nondeterministic column"
                ),
            ));
        }
        let Some(&mx) = fields.iter().max() else {
            continue;
        };
        if mx > ncols {
            out.push(schema_finding(
                CI,
                line,
                format!("`cut -f{spec}` references f{mx} beyond the {ncols}-column schema"),
            ));
        }
        if mx > wall {
            let missing: Vec<String> = (wall + 1..=ncols)
                .filter(|f| !fields.contains(f))
                .map(|f| format!("f{f}"))
                .collect();
            if !missing.is_empty() {
                out.push(schema_finding(
                    CI,
                    line,
                    format!(
                        "`cut -f{spec}` skips deterministic column(s) {} — a cut \
                         reaching past wall_ms must cover f{}-f{ncols}",
                        missing.join(","),
                        wall + 1
                    ),
                ));
            }
        }
    }
    let mut templates: Vec<String> = Vec::new();
    if let Ok(rd) = fs::read_dir(root.join("rust/benches")) {
        let mut paths: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
        paths.sort();
        for p in paths {
            if p.extension().and_then(|e| e.to_str()) != Some("rs") {
                continue;
            }
            if let Ok(text) = fs::read_to_string(&p) {
                templates.extend(lex(&text).strings.into_iter().map(|(_, s)| s));
            }
        }
    }
    for (line, req) in find_requires(&ci) {
        if !templates.iter().any(|t| glob_could_match(t, &req)) {
            out.push(schema_finding(
                CI,
                line,
                format!(
                    "`--require \"{req}\"` matches no bench name template under \
                     rust/benches — the gate would fail closed on a phantom row"
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Repo walk + output
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// Scan the whole repo rooted at `root`: every Rust source under
/// `rust/src`, the salt registry, and the cross-artifact schema checks.
/// Findings come back sorted by (path, line, rule).
pub fn scan_repo(root: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut files = Vec::new();
    walk_rs(&root.join("rust").join("src"), &mut files);
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(text) = fs::read_to_string(path) else {
            continue;
        };
        if rel == REGISTRY_PATH {
            out.extend(check_salt_registry(&rel, &text));
        }
        out.extend(scan_rust_source(&rel, &text));
    }
    out.extend(check_schema(root));
    out.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    out
}

fn json_escape(t: &str) -> String {
    let mut s = String::with_capacity(t.len());
    for c in t.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\t' => s.push_str("\\t"),
            '\r' => s.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s
}

/// Machine-readable findings list (a JSON array, one object per
/// finding with `rule`, `path`, `line`, `message`).
pub fn to_json(findings: &[Finding]) -> String {
    let mut s = String::from("[\n");
    for (i, f) in findings.iter().enumerate() {
        let _ = write!(
            s,
            "  {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            json_escape(f.rule),
            json_escape(&f.path),
            f.line,
            json_escape(&f.message)
        );
        if i + 1 < findings.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    s
}
