//! CLI for the determinism lint: scan the repo, print findings, exit
//! nonzero if any survive. See the library docs for the rule set.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
detlint — determinism static analysis (DESIGN.md §14)

USAGE:
    detlint [--root DIR] [--format text|json] [--report FILE]

OPTIONS:
    --root DIR       repo root to scan (default: .)
    --format KIND    findings output: text (default) or json
    --report FILE    additionally write the JSON findings to FILE
    -h, --help       print this help

EXIT CODE: 0 when the tree is clean, 1 when findings exist, 2 on usage
or I/O errors.";

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut format = String::from("text");
    let mut report: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" | "--format" | "--report" => {
                let Some(v) = args.next() else {
                    eprintln!("detlint: `{a}` needs a value\n\n{USAGE}");
                    return ExitCode::from(2);
                };
                match a.as_str() {
                    "--root" => root = PathBuf::from(v),
                    "--format" => format = v,
                    _ => report = Some(PathBuf::from(v)),
                }
            }
            "-h" | "--help" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            _ => {
                eprintln!("detlint: unknown argument `{a}`\n\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if format != "text" && format != "json" {
        eprintln!("detlint: unknown format `{format}` (want text or json)");
        return ExitCode::from(2);
    }
    if !root.join("rust").join("src").is_dir() {
        eprintln!(
            "detlint: no rust/src under `{}` — pass the repo root via --root",
            root.display()
        );
        return ExitCode::from(2);
    }
    let findings = detlint::scan_repo(&root);
    let json = detlint::to_json(&findings);
    if let Some(p) = &report {
        if let Err(e) = std::fs::write(p, &json) {
            eprintln!("detlint: cannot write report `{}`: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if format == "json" {
        print!("{json}");
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message);
        }
        eprintln!("detlint: {} finding(s)", findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
