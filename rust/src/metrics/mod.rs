//! Round-level metrics, CSV export and multi-seed summaries.

use crate::util::csv::CsvWriter;
use crate::util::stats;

/// Training phase of a round (Algorithm 1's two steps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Warm,
    Zo,
}

impl Phase {
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Warm => "warm",
            Phase::Zo => "zo",
        }
    }
}

/// One federated round's record.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub phase: Phase,
    /// mean training loss over participating clients (pre-update)
    pub train_loss: f64,
    /// test metrics (NaN when the round was not evaluated)
    pub test_acc: f64,
    pub test_loss: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// sampled clients that dropped mid-round (deadline / availability)
    /// or were absent / not yet joined (churn)
    pub dropped: usize,
    /// of `bytes_down`, the catch-up downlink charged to stale clients
    /// this round (`ckpt` subsystem; 0 with checkpointing disabled)
    pub catch_up_down: u64,
    /// probes the server issued to this round's ZO participants (0 in
    /// warm rounds; heterogeneous per-client budgets under `--adaptive-s`)
    pub seeds_issued: usize,
    /// effective variance of the round's aggregated SPSA step
    /// (`zo::effective_variance`; always finite, 0.0 when undefined)
    pub eff_var: f64,
    pub wall_ms: f64,
    // New columns are appended AFTER wall_ms: the CI thread-bit-identity
    // steps diff `cut -d, -f1-11` (everything before wall_ms), so the
    // prefix layout is load-bearing.
    /// mean model-version staleness of the contributions this round's
    /// fold accepted (`fed::engine`; 0.0 under the sync barrier, where
    /// every contribution is fresh by construction)
    pub staleness: f64,
    /// server model-version counter after the round (increments only on
    /// parameter-mutating folds, so all-drop rounds hold it flat)
    pub model_version: usize,
    /// simulated wall-clock makespan of the round in scenario ms: the
    /// slowest simulated participant under the sync barrier, the span of
    /// event-clock time the async engine's fold consumed
    pub makespan_ms: f64,
    /// sampled clients lost to a down edge aggregator this round (subset
    /// of `dropped`; always 0 unless the scenario models edges — see the
    /// two-tier topology in `fed::server`)
    pub edge_drops: usize,
}

/// The metrics CSV column contract, in emit order. This is the single
/// source of truth the CI trace diffs and `detlint`'s schema-sync rule
/// key off: columns before [`WALL_MS_FIELD`] are bit-stable across
/// thread counts and engine modes, `wall_ms` is host-timing noise, and
/// every column after it is deterministic again. New columns append at
/// the end — the `cut -d, -f` ranges in `.github/workflows/ci.yml`
/// must cover exactly this list minus `wall_ms` (DESIGN.md §14).
pub const CSV_COLUMNS: [&str; 16] = [
    "round", "phase", "train_loss", "test_acc", "test_loss", "bytes_up",
    "bytes_down", "dropped", "catch_up_down", "seeds_issued", "eff_var",
    "wall_ms", "staleness", "model_version", "makespan_ms", "edge_drops",
];

/// 1-based CSV field number of `wall_ms` — the only column CI trace
/// diffs are allowed to exclude (`cut` speaks 1-based field numbers).
pub const WALL_MS_FIELD: usize = 12;

/// Full run history.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub rounds: Vec<RoundRecord>,
}

impl RunLog {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Last evaluated test accuracy (the headline number).
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .unwrap_or(f64::NAN)
    }

    /// Best test accuracy over the run.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Total mid-round dropouts over the run (scenario-engine view).
    pub fn total_dropped(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped).sum()
    }

    /// Total catch-up downlink over the run (`ckpt` subsystem view).
    pub fn total_catch_up_down(&self) -> u64 {
        self.rounds.iter().map(|r| r.catch_up_down).sum()
    }

    /// Total probes issued over the run (adaptive-S accounting view).
    pub fn total_seeds_issued(&self) -> usize {
        self.rounds.iter().map(|r| r.seeds_issued).sum()
    }

    /// Mean effective variance over the ZO rounds that measured one
    /// (skips warm/empty rounds; 0.0 when none did).
    pub fn mean_eff_var(&self) -> f64 {
        let vals: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Zo && r.eff_var > 0.0)
            .map(|r| r.eff_var)
            .collect();
        crate::util::stats::mean(&vals)
    }

    /// Mean fold staleness over the ZO rounds (async-engine view; 0.0
    /// for sync runs, whose folds are fresh by construction).
    pub fn mean_staleness(&self) -> f64 {
        let vals: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Zo)
            .map(|r| r.staleness)
            .collect();
        crate::util::stats::mean(&vals)
    }

    /// Total simulated wall-clock makespan of the run in scenario ms —
    /// the systems metric the async engine trades staleness against.
    pub fn total_makespan_ms(&self) -> f64 {
        self.rounds.iter().map(|r| r.makespan_ms).sum()
    }

    /// Total sampled clients lost to down edge aggregators over the run
    /// (two-tier topology view; 0 unless the scenario models edges).
    pub fn total_edge_drops(&self) -> usize {
        self.rounds.iter().map(|r| r.edge_drops).sum()
    }

    pub fn total_bytes(&self) -> (u64, u64) {
        (
            self.rounds.iter().map(|r| r.bytes_up).sum(),
            self.rounds.iter().map(|r| r.bytes_down).sum(),
        )
    }

    /// Accuracy series (round, acc) at evaluated rounds — figure data.
    pub fn accuracy_curve(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| (r.round, r.test_acc))
            .collect()
    }

    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(path, &CSV_COLUMNS)?;
        for r in &self.rounds {
            w.row(&[
                r.round.to_string(),
                r.phase.as_str().to_string(),
                format!("{:.6}", r.train_loss),
                format!("{:.6}", r.test_acc),
                format!("{:.6}", r.test_loss),
                r.bytes_up.to_string(),
                r.bytes_down.to_string(),
                r.dropped.to_string(),
                r.catch_up_down.to_string(),
                r.seeds_issued.to_string(),
                format!("{:.6e}", r.eff_var),
                format!("{:.3}", r.wall_ms),
                format!("{:.3}", r.staleness),
                r.model_version.to_string(),
                format!("{:.3}", r.makespan_ms),
                r.edge_drops.to_string(),
            ])?;
        }
        w.flush()
    }
}

/// Multi-seed cell: the paper's "mean(std)" aggregation (accuracies in %).
pub fn summarize_accuracies(accs_frac: &[f64]) -> String {
    let pct: Vec<f64> = accs_frac.iter().map(|a| a * 100.0).collect();
    stats::mean_std_cell(&pct)
}

/// Markdown table builder shared by all exp runners.
pub struct MdTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            phase: Phase::Warm,
            train_loss: 1.0,
            test_acc: acc,
            test_loss: 1.0,
            bytes_up: 10,
            bytes_down: 20,
            dropped: 0,
            catch_up_down: 0,
            seeds_issued: 0,
            eff_var: 0.0,
            wall_ms: 1.0,
            staleness: 0.0,
            model_version: 0,
            makespan_ms: 2.5,
            edge_drops: 0,
        }
    }

    #[test]
    fn final_and_best_accuracy_skip_nan() {
        let mut log = RunLog::default();
        log.push(rec(0, 0.3));
        log.push(rec(1, f64::NAN));
        log.push(rec(2, 0.5));
        log.push(rec(3, f64::NAN));
        assert_eq!(log.final_accuracy(), 0.5);
        assert_eq!(log.best_accuracy(), 0.5);
        assert_eq!(log.accuracy_curve(), vec![(0, 0.3), (2, 0.5)]);
        assert_eq!(log.total_bytes(), (40, 80));
    }

    #[test]
    fn empty_log_is_nan() {
        assert!(RunLog::default().final_accuracy().is_nan());
    }

    #[test]
    fn csv_round_trip() {
        let mut log = RunLog::default();
        log.push(rec(0, 0.25));
        let path = std::env::temp_dir().join("zow_metrics_test.csv");
        log.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,phase,"));
        assert!(text.contains(
            ",seeds_issued,eff_var,wall_ms,staleness,model_version,makespan_ms,edge_drops"
        ));
        assert!(text.contains("0,warm,1.000000,0.250000"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_header_and_rows_agree_on_field_count() {
        // satellite: the header list and the per-row field pushes are
        // hand-synced in write_csv (widened three times across PRs 3–6);
        // parse the emitted file so a drifting column count fails loudly
        let mut log = RunLog::default();
        log.push(rec(0, 0.25));
        log.push(rec(1, f64::NAN));
        let path = std::env::temp_dir().join("zow_metrics_arity_test.csv");
        log.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header_cols = lines.next().unwrap().split(',').count();
        let mut rows = 0;
        for line in lines {
            assert_eq!(
                line.split(',').count(),
                header_cols,
                "row field count drifted from the {header_cols}-column header: {line}"
            );
            rows += 1;
        }
        assert_eq!(rows, 2);
        // the layout contract the CI diff steps rely on: wall_ms is f12,
        // the async columns sit strictly after it
        let header: Vec<&str> = text.lines().next().unwrap().split(',').collect();
        assert_eq!(header, CSV_COLUMNS);
        assert_eq!(header[11], "wall_ms");
        assert_eq!(
            &header[12..],
            ["staleness", "model_version", "makespan_ms", "edge_drops"]
        );
        // WALL_MS_FIELD is the 1-based `cut` field number of wall_ms
        assert_eq!(CSV_COLUMNS[WALL_MS_FIELD - 1], "wall_ms");
    }

    #[test]
    fn summary_format() {
        assert_eq!(summarize_accuracies(&[0.543, 0.543]), "54.3(0.0)");
    }

    #[test]
    fn md_table() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
