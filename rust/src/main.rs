//! `zowarmup` — the leader entrypoint / CLI launcher.
//!
//! Subcommands:
//!   train   — run one federated training job (ZOWarmUp by default)
//!   exp     — regenerate a paper table/figure (see DESIGN.md §4)
//!   comm    — print the Table 1 cost model
//!   check   — validate artifacts/manifest.json and compile every artifact

use zowarmup::config::{DataConfig, FedConfig, Scale};
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp;
use zowarmup::exp::common::{image_setup, linear_lrs, run_path};
use zowarmup::fed::server::Federation;
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::manifest::Manifest;
use zowarmup::model::params::ParamVec;
use zowarmup::runtime::Engine;
use zowarmup::sim::Scenario;
use zowarmup::util::cli::Args;
use zowarmup::util::json::Json;

const USAGE: &str = "\
zowarmup — zeroth-order federated pre-training (paper reproduction)

USAGE: zowarmup <subcommand> [flags]

SUBCOMMANDS
  train   run one federated training job
            --backend linear|xla       (default linear)
            --model cnn10|vit10|...    (xla backend; default cnn10)
            --dataset synth10|synth100 --n-train N --n-test N --alpha A
            --clients K --hi-frac F --rounds R --pivot P
            --seeds-s S --tau T --eps E --dist rademacher|gaussian
            --server-opt sgd|adam --config file.json --out runs/train.csv
            --threads N                (parallel round engine; 0 = auto,
                                        results identical for every N)
            --scenario NAME|FILE       (device-capability fleet: binary|
                                        uniform-high|edge-spectrum|
                                        stragglers|flaky|churn|fleet, a
                                        JSON spec file, or an inline {...}
                                        spec — schema in README.md and
                                        rust/src/exp/README.md)
            --population MODE          (auto|materialized|lazy: how
                                        per-client state is backed. auto
                                        (default) materializes small
                                        populations byte-identically to
                                        before and derives lazily past
                                        2^17 clients, so
                                        --clients 10000000 costs
                                        O(sampled) per round)
            --ckpt-every N             (server checkpoint cadence: snapshot
                                        + seed-log compaction every N ZO
                                        rounds; stale/late-joining clients
                                        pay min(snapshot, tail) catch-up
                                        downlink. 0 = off, the seed-
                                        compatible default)
            --adaptive-s true|false    (capability-adaptive probe budgets:
                                        each ZO client gets the largest
                                        S in [--s-min, --s-max] whose
                                        simulated timeline fits the round
                                        budget — the scenario deadline,
                                        else the slowest sampled client's
                                        uniform-S time. default false =
                                        uniform --seeds-s, bit-identical
                                        to before)
            --s-min N --s-max N        (adaptive-S range; default 1..16)
            --guard off|invvar|clip    (aggregation variance guard:
                                        inverse-variance reweighting or
                                        |dL|-quantile clipping folded into
                                        the fused update; default off)
            --kernel scalar|lanes      (ZOUPDATE perturbation kernel.
                                        scalar (default) = the historical
                                        one-stream-per-seed sweep, byte-
                                        identical to every existing trace;
                                        lanes = 4-lane split streams fused
                                        across the round's seeds — its own
                                        seed schedule, bit-identical at any
                                        --threads. requires rademacher)
            --engine sync|async        (ZO round engine. sync (default) =
                                        the paper's barrier, bit-identical
                                        to before; async = buffered
                                        event-driven aggregation with
                                        staleness-decayed weights,
                                        deterministic at every --threads)
            --buffer-k N               (async: fold after N arrivals;
                                        0 = --sample-zo, the default)
            --staleness-decay D        (async: stale contributions weigh
                                        (1+s)^-D; default 0.5)
            --concurrency N            (async: max dispatches in flight;
                                        0 = 2*buffer-k, the default)
            --arrival-rate R           (async: Poisson arrival jitter in
                                        events/ms; 0 = off, the default)
            --edges E                  (two-tier topology: partition the
                                        population across E edge
                                        aggregators; each edge folds its
                                        cohort's survivors into a partial
                                        fused artifact and the root folds
                                        the partials in edge order —
                                        bit-identical to the flat fold.
                                        1 (default) = the flat historical
                                        path, byte-identical to before.
                                        Per-edge links/deadlines/failures
                                        come from the scenario's \"edges\"
                                        list (geo-iot|geo-phones presets);
                                        without one, E > 1 is pure
                                        per-edge ledger attribution)
  exp     regenerate a paper table/figure
            zowarmup exp <table1..table7|fig3..fig7|ckpt|adaptive|async|fleet|topo|all> [--scale smoke|default|paper]
            [--threads N]              (worker threads for every run in
                                        the sweep; 0 = auto)
            [--scenario NAME|FILE]     (capability fleet for every run in
                                        the sweep; default binary)
  comm    print the Table 1 communication/memory cost model
  check   validate the artifact manifest and compile all artifacts
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("exp") => cmd_exp(&args),
        Some("comm") => cmd_comm(&args),
        Some("check") => cmd_check(&args),
        Some(other) => {
            eprintln!("{USAGE}");
            anyhow::bail!("unknown subcommand {other:?}")
        }
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn load_cfg(args: &Args) -> anyhow::Result<(FedConfig, DataConfig)> {
    let mut cfg = match Scale::parse(&args.str_or("scale", "default")) {
        Some(s) => s.fed(),
        None => anyhow::bail!("bad --scale"),
    };
    let mut data = DataConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        cfg.apply_json(&json)?;
    }
    cfg.apply_args(args)?;
    data.apply_args(args)?;
    Ok((cfg, data))
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let backend_kind = args.str_or("backend", "linear");
    let (mut cfg, data) = load_cfg(args)?;
    let out = args.str_or("out", &run_path("train.csv"));
    let artifacts = args.str_or("artifacts", "artifacts");
    let model = args.str_or("model", "cnn10");
    args.reject_unknown()?;

    let kind = SynthKind::parse(&data.dataset)
        .ok_or_else(|| anyhow::anyhow!("bad --dataset {:?}", data.dataset))?;

    match backend_kind.as_str() {
        "linear" => {
            linear_lrs(&mut cfg);
            // re-apply CLI lr overrides on top of the preset
            cfg.apply_args(args)?;
            if cfg.lazy_population() {
                // fleet-scale path: no per-client materialization — the
                // population derives profiles/shards on demand, so setup
                // stays O(1) at --clients 10000000
                warn_lazy_semantics(&cfg, args);
                let (train, test) = zowarmup::data::synthetic::train_test(
                    kind,
                    data.n_train,
                    data.n_test,
                    cfg.seed,
                );
                let backend = zowarmup::exp::common::probe_backend(kind.classes());
                let init = ParamVec::zeros(backend.dim());
                let mut fed = Federation::new_lazy(
                    cfg,
                    &backend,
                    zowarmup::data::loader::Source::Image(std::sync::Arc::new(train)),
                    zowarmup::data::loader::Source::Image(std::sync::Arc::new(test)),
                    init,
                )?;
                return run_and_report(&mut fed, &out);
            }
            let s = image_setup(kind, &data, &cfg);
            let init = ParamVec::zeros(s.backend.dim());
            let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
            run_and_report(&mut fed, &out)
        }
        "xla" => {
            let manifest = Manifest::load(&artifacts)?;
            let engine = Engine::cpu()?;
            let backend = engine.backend(&manifest, &model)?;
            let entry = manifest.model(&model)?;
            anyhow::ensure!(
                entry.classes == kind.classes(),
                "model {model} has {} classes but dataset {} has {}",
                entry.classes,
                data.dataset,
                kind.classes()
            );
            cfg.batch = entry.batch;
            let init = ParamVec::he_init(entry, cfg.seed);
            if cfg.lazy_population() {
                warn_lazy_semantics(&cfg, args);
                let (train, test) = zowarmup::data::synthetic::train_test(
                    kind,
                    data.n_train,
                    data.n_test,
                    cfg.seed,
                );
                let mut fed = Federation::new_lazy(
                    cfg,
                    &backend,
                    zowarmup::data::loader::Source::Image(std::sync::Arc::new(train)),
                    zowarmup::data::loader::Source::Image(std::sync::Arc::new(test)),
                    init,
                )?;
                return run_and_report(&mut fed, &out);
            }
            let s = image_setup(kind, &data, &cfg);
            let mut fed = Federation::new(cfg, &backend, s.shards, s.test, init)?;
            run_and_report(&mut fed, &out)
        }
        other => anyhow::bail!("bad --backend {other:?} (linear|xla)"),
    }
}

/// A lazy population is a different *statistical* model, not just a
/// memory optimization: shards are fixed-size IID keyed draws (the
/// Dirichlet `--alpha` split does not apply) and tier occupancy is
/// binomial rather than exact-count. Say so out loud — especially when
/// `--population auto` flipped the mode on by client count alone.
fn warn_lazy_semantics(cfg: &FedConfig, args: &Args) {
    let why = match cfg.population {
        zowarmup::config::PopulationMode::Lazy => "explicit --population lazy".to_string(),
        _ => format!(
            "auto: {} clients exceeds the {} materialization threshold",
            cfg.clients,
            zowarmup::config::LAZY_AUTO_THRESHOLD
        ),
    };
    eprintln!(
        "[population] lazy mode ({why}): per-client shards are fixed-size \
         keyed draws and tier occupancy is binomial (DESIGN.md \u{a7}10)"
    );
    if args.get("alpha").is_some() {
        eprintln!(
            "[population] warning: --alpha (Dirichlet non-IID split) does not \
             apply to lazy populations and is ignored"
        );
    }
}

fn run_and_report<B: ModelBackend>(
    fed: &mut Federation<'_, B>,
    out: &str,
) -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    println!(
        "training: {} clients ({} high-res), {} rounds (pivot {}), d={}",
        fed.cfg.clients,
        fed.cfg.hi_count(),
        fed.cfg.rounds_total,
        fed.cfg.pivot,
        fed.backend.dim()
    );
    while fed.round < fed.cfg.rounds_total {
        fed.step()?;
        let r = fed.log.rounds.last().unwrap();
        if !r.test_acc.is_nan() {
            println!(
                "round {:4} [{}] train {:8.4}  test acc {:5.1}%  loss {:.4}",
                r.round,
                r.phase.as_str(),
                r.train_loss,
                r.test_acc * 100.0,
                r.test_loss
            );
        }
    }
    fed.log.write_csv(out)?;
    let (up, down) = fed.log.total_bytes();
    println!(
        "done in {:.1}s: final acc {:.2}% best {:.2}% | comm up {:.3} MB down {:.3} MB | log {out}",
        t0.elapsed().as_secs_f64(),
        fed.log.final_accuracy() * 100.0,
        fed.log.best_accuracy() * 100.0,
        up as f64 / 1e6,
        down as f64 / 1e6,
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let scale = Scale::parse(&args.str_or("scale", "smoke"))
        .ok_or_else(|| anyhow::anyhow!("bad --scale"))?;
    let artifacts = args.str_or("artifacts", "artifacts");
    // exp runners build their configs internally with threads = 0 (auto),
    // which resolves through ZOWARMUP_THREADS — so the flag plumbs through
    // the env. Determinism is unaffected (see fed::server docs).
    let threads = args.usize_or("threads", 0)?;
    if threads > 0 {
        std::env::set_var("ZOWARMUP_THREADS", threads.to_string());
    }
    let scenario = match args.get("scenario") {
        Some(s) => Scenario::load(s)?,
        None => Scenario::default(),
    };
    args.reject_unknown()?;
    let report = exp::run(&id, scale, &artifacts, &scenario)?;
    println!("{report}");
    let path = run_path(&format!("report_{id}.md"));
    std::fs::write(&path, &report)?;
    eprintln!("[exp] report written to {path}");
    Ok(())
}

fn cmd_comm(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;
    let report = exp::table1::run(Scale::Smoke, &artifacts, &Scenario::default())?;
    println!("{report}");
    Ok(())
}

fn cmd_check(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;
    let manifest = Manifest::load(&artifacts)?;
    manifest.validate()?;
    println!("manifest: {} models, layouts consistent", manifest.models.len());
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    for (name, entry) in &manifest.models {
        for ep in entry.artifacts.keys() {
            let path = entry.artifact_path(&manifest.dir, ep)?;
            let t0 = std::time::Instant::now();
            engine.compile(&path)?;
            println!(
                "  compiled {name}/{ep} ({:.2}s)",
                t0.elapsed().as_secs_f64()
            );
        }
    }
    println!("all artifacts compile: OK");
    Ok(())
}
