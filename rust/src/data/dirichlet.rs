//! Dirichlet(α) non-IID partitioner (the paper's split: α = 0.1 over 50
//! clients, equal sizes).
//!
//! Standard label-skew recipe: for each class, draw client proportions
//! from Dirichlet(α·1_K) and deal that class's samples accordingly, then
//! rebalance so every client ends up with (approximately) `n/K` samples —
//! the paper partitions "equally between 50 clients".

use crate::data::synthetic::Dataset;
use crate::util::rng::Xoshiro256;

/// Index-based partition of a dataset across clients.
#[derive(Debug, Clone)]
pub struct Partition {
    /// per-client sample indices into the parent dataset
    pub clients: Vec<Vec<usize>>,
}

impl Partition {
    pub fn client_sizes(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.len()).collect()
    }

    pub fn total(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }
}

/// Dirichlet label-skew split with equal client sizes.
pub fn dirichlet_split(data: &Dataset, k: usize, alpha: f64, seed: u64) -> Partition {
    assert!(k > 0);
    let n = data.len();
    let per_client = n / k; // equal sizes (paper); remainder dropped
    let mut rng = Xoshiro256::seed_from(seed ^ 0xD112_1C11);

    // indices by class, shuffled
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.classes];
    for (i, &y) in data.y.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    for c in &mut by_class {
        rng.shuffle(c);
    }

    // per-client class preference vectors
    let prefs: Vec<Vec<f64>> = (0..k).map(|_| rng.dirichlet(alpha, data.classes)).collect();

    // deal samples: each client fills its quota by drawing classes from its
    // preference distribution, falling back to whatever is left.
    let mut clients: Vec<Vec<usize>> = vec![Vec::with_capacity(per_client); k];
    let mut order: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut order);
    for &ci in &order {
        let pref = &prefs[ci];
        while clients[ci].len() < per_client {
            // sample a class from pref restricted to non-empty classes
            let mut mass: f64 = by_class
                .iter()
                .enumerate()
                .filter(|(_, v)| !v.is_empty())
                .map(|(c, _)| pref[c])
                .sum();
            if mass <= 0.0 {
                // preference mass exhausted on empty classes: uniform fallback
                mass = by_class.iter().filter(|v| !v.is_empty()).count() as f64;
                if mass == 0.0 {
                    break;
                }
                let mut r = rng.next_f64() * mass;
                for v in by_class.iter_mut() {
                    if v.is_empty() {
                        continue;
                    }
                    r -= 1.0;
                    if r <= 0.0 {
                        clients[ci].push(v.pop().unwrap());
                        break;
                    }
                }
                continue;
            }
            let mut r = rng.next_f64() * mass;
            for (c, v) in by_class.iter_mut().enumerate() {
                if v.is_empty() {
                    continue;
                }
                r -= pref[c];
                if r <= 0.0 {
                    clients[ci].push(v.pop().unwrap());
                    break;
                }
            }
        }
    }
    Partition { clients }
}

/// Label histogram for one client (diagnostics + skew tests).
pub fn label_histogram(data: &Dataset, indices: &[usize]) -> Vec<usize> {
    let mut h = vec![0usize; data.classes];
    for &i in indices {
        h[data.y[i] as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, GenConfig, SynthKind};

    fn data() -> Dataset {
        generate(SynthKind::Synth10, 1000, GenConfig::default())
    }

    #[test]
    fn equal_sizes_and_disjoint() {
        let d = data();
        let p = dirichlet_split(&d, 10, 0.1, 0);
        assert_eq!(p.clients.len(), 10);
        for c in &p.clients {
            assert_eq!(c.len(), 100);
        }
        let mut all: Vec<usize> = p.clients.iter().flatten().cloned().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 1000, "samples must not be shared");
    }

    #[test]
    fn low_alpha_is_skewed_high_alpha_flat() {
        let d = data();
        let skew_of = |alpha: f64| -> f64 {
            let p = dirichlet_split(&d, 10, alpha, 1);
            // mean over clients of (max class share)
            p.clients
                .iter()
                .map(|c| {
                    let h = label_histogram(&d, c);
                    *h.iter().max().unwrap() as f64 / c.len() as f64
                })
                .sum::<f64>()
                / 10.0
        };
        let low = skew_of(0.1);
        let high = skew_of(100.0);
        assert!(low > 0.45, "alpha=0.1 skew {low}");
        assert!(high < 0.25, "alpha=100 skew {high}");
        assert!(low > high + 0.2);
    }

    #[test]
    fn deterministic_per_seed() {
        let d = data();
        let a = dirichlet_split(&d, 7, 0.1, 5);
        let b = dirichlet_split(&d, 7, 0.1, 5);
        let c = dirichlet_split(&d, 7, 0.1, 6);
        assert_eq!(a.clients, b.clients);
        assert_ne!(a.clients, c.clients);
    }

    #[test]
    fn handles_more_clients_than_classes() {
        let d = data();
        let p = dirichlet_split(&d, 50, 0.1, 2);
        assert_eq!(p.clients.len(), 50);
        assert_eq!(p.total(), 1000);
    }

    #[test]
    fn single_client_gets_everything() {
        let d = data();
        let p = dirichlet_split(&d, 1, 0.1, 3);
        assert_eq!(p.clients[0].len(), 1000);
    }
}
