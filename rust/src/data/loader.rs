//! Client-side data views and padded-batch assembly for `ModelBackend`s.

use std::sync::Arc;

use crate::data::lm::LmData;
use crate::data::synthetic::{Dataset, SAMPLE_LEN};
use crate::model::backend::{Batch, BatchX};
use crate::util::rng::Xoshiro256;

/// Cheap-to-clone handle on the underlying task data.
#[derive(Clone)]
pub enum Source {
    Image(Arc<Dataset>),
    Lm(Arc<LmData>),
}

impl Source {
    pub fn len(&self) -> usize {
        match self {
            Source::Image(d) => d.len(),
            Source::Lm(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble one batch from sample indices, padded to `bsize` rows with
    /// zero mask. Image masks are per-sample; LM masks per-token.
    pub fn batch(&self, indices: &[usize], bsize: usize) -> Batch {
        assert!(indices.len() <= bsize, "{} > batch {}", indices.len(), bsize);
        match self {
            Source::Image(d) => {
                let mut x = vec![0.0f32; bsize * SAMPLE_LEN];
                let mut y = vec![0i32; bsize];
                let mut mask = vec![0.0f32; bsize];
                for (row, &i) in indices.iter().enumerate() {
                    x[row * SAMPLE_LEN..(row + 1) * SAMPLE_LEN].copy_from_slice(d.sample(i));
                    y[row] = d.y[i];
                    mask[row] = 1.0;
                }
                Batch {
                    x: BatchX::F32(x),
                    y,
                    mask,
                }
            }
            Source::Lm(d) => {
                let t = d.seq;
                let mut x = vec![0i32; bsize * t];
                let mut y = vec![0i32; bsize * t];
                let mut mask = vec![0.0f32; bsize * t];
                for (row, &i) in indices.iter().enumerate() {
                    x[row * t..(row + 1) * t].copy_from_slice(d.seq_x(i));
                    y[row * t..(row + 1) * t].copy_from_slice(d.seq_y(i));
                    mask[row * t..(row + 1) * t].fill(1.0);
                }
                Batch {
                    x: BatchX::I32(x),
                    y,
                    mask,
                }
            }
        }
    }
}

/// One client's shard: a view (index list) over the shared source.
#[derive(Clone)]
pub struct ClientData {
    pub source: Source,
    pub indices: Vec<usize>,
}

impl ClientData {
    pub fn n(&self) -> usize {
        self.indices.len()
    }

    /// Shuffled minibatches for one local epoch (warm phase). The final
    /// partial batch is padded and mask-corrected.
    pub fn epoch_batches(&self, bsize: usize, rng: &mut Xoshiro256) -> Vec<Batch> {
        let mut idx = self.indices.clone();
        rng.shuffle(&mut idx);
        idx.chunks(bsize)
            .map(|chunk| self.source.batch(chunk, bsize))
            .collect()
    }

    /// Deterministic full-dataset chunks (ZO phase: one gradient step on
    /// the client's entire dataset, chunked exactly through the fixed-batch
    /// backend via loss-sum accumulation).
    pub fn chunks(&self, bsize: usize) -> Vec<Batch> {
        self.indices
            .chunks(bsize)
            .map(|chunk| self.source.batch(chunk, bsize))
            .collect()
    }

    /// A single random minibatch of `take` real samples padded into a
    /// `bsize`-row batch (FedKSeed local steps; `bsize` must match the
    /// backend's fixed batch).
    pub fn minibatch(&self, take: usize, bsize: usize, rng: &mut Xoshiro256) -> Batch {
        let take = take.min(self.n()).min(bsize);
        let picks = rng.choose(self.n(), take);
        let idx: Vec<usize> = picks.into_iter().map(|p| self.indices[p]).collect();
        self.source.batch(&idx, bsize)
    }
}

/// Whole-dataset evaluation view (server-side test set).
pub fn eval_chunks(source: &Source, bsize: usize) -> Vec<Batch> {
    let all: Vec<usize> = (0..source.len()).collect();
    all.chunks(bsize)
        .map(|chunk| source.batch(chunk, bsize))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lm;
    use crate::data::synthetic::{generate, GenConfig, SynthKind};

    fn image_source(n: usize) -> Source {
        Source::Image(Arc::new(generate(SynthKind::Synth10, n, GenConfig::default())))
    }

    #[test]
    fn image_batch_padding_and_mask() {
        let s = image_source(10);
        let b = s.batch(&[0, 3, 7], 8);
        assert_eq!(b.mask, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(b.real_count(), 3.0);
        if let BatchX::F32(x) = &b.x {
            assert_eq!(x.len(), 8 * SAMPLE_LEN);
            assert!(x[3 * SAMPLE_LEN..].iter().all(|&v| v == 0.0));
        } else {
            panic!("wrong x type");
        }
    }

    #[test]
    fn lm_batch_layout() {
        let s = Source::Lm(Arc::new(lm::generate(64, 8, 4, 0)));
        let b = s.batch(&[1, 2], 4);
        if let BatchX::I32(x) = &b.x {
            assert_eq!(x.len(), 32);
        } else {
            panic!("wrong x type");
        }
        assert_eq!(b.mask[..16], vec![1.0; 16][..]);
        assert_eq!(b.mask[16..], vec![0.0; 16][..]);
        assert_eq!(b.real_count(), 16.0); // per-token mask
    }

    #[test]
    fn epoch_batches_cover_all_once() {
        let s = image_source(25);
        let cd = ClientData {
            source: s,
            indices: (0..25).collect(),
        };
        let mut rng = Xoshiro256::seed_from(0);
        let batches = cd.epoch_batches(8, &mut rng);
        assert_eq!(batches.len(), 4); // 8+8+8+1
        let total: f64 = batches.iter().map(|b| b.real_count()).sum();
        assert_eq!(total, 25.0);
    }

    #[test]
    fn chunks_deterministic() {
        let s = image_source(20);
        let cd = ClientData {
            source: s,
            indices: (5..20).collect(),
        };
        let a = cd.chunks(4);
        let b = cd.chunks(4);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.y, y.y);
        }
    }

    #[test]
    fn minibatch_has_no_duplicates() {
        let s = image_source(30);
        let cd = ClientData {
            source: s,
            indices: (0..30).collect(),
        };
        let mut rng = Xoshiro256::seed_from(1);
        let b = cd.minibatch(16, 16, &mut rng);
        assert_eq!(b.real_count(), 16.0);
    }

    #[test]
    fn minibatch_smaller_shard_pads() {
        let s = image_source(30);
        let cd = ClientData {
            source: s,
            indices: vec![2, 4, 6],
        };
        let mut rng = Xoshiro256::seed_from(2);
        let b = cd.minibatch(8, 8, &mut rng);
        assert_eq!(b.real_count(), 3.0);
        // take < bsize pads the rest
        let b2 = cd.minibatch(2, 8, &mut rng);
        assert_eq!(b2.real_count(), 2.0);
        assert_eq!(b2.mask.len(), 8);
    }

    #[test]
    fn eval_chunks_cover_source() {
        let s = image_source(17);
        let chunks = eval_chunks(&s, 8);
        assert_eq!(chunks.len(), 3);
        let total: f64 = chunks.iter().map(|b| b.real_count()).sum();
        assert_eq!(total, 17.0);
    }
}
