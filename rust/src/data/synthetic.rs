//! Procedural class-structured image datasets (the CIFAR-10 / ImageNet32
//! substitutes; DESIGN.md §2).
//!
//! Each class owns a deterministic low-frequency prototype (a random 8×8
//! pattern bilinearly upsampled to 32×32, plus a per-channel color bias).
//! A sample is its class prototype under a random translation, contrast
//! jitter and pixel noise. The result is learnable but not trivially so —
//! enough structure for the paper's phenomena (non-IID splits, ZO variance,
//! warm-up benefit) to reproduce, with zero external data dependencies.

use crate::util::rng::Xoshiro256;

/// Dataset kinds selectable from configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// 10 classes (CIFAR-10 regime).
    Synth10,
    /// 100 classes, fewer samples per class (ImageNet32 regime).
    Synth100,
}

impl SynthKind {
    pub fn classes(self) -> usize {
        match self {
            SynthKind::Synth10 => 10,
            SynthKind::Synth100 => 100,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "synth10" => Some(SynthKind::Synth10),
            "synth100" => Some(SynthKind::Synth100),
            _ => None,
        }
    }
}

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const SAMPLE_LEN: usize = IMG * IMG * CHANNELS;

/// A fully materialized labelled dataset (features NHWC-flattened f32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>, // n * SAMPLE_LEN
    pub y: Vec<i32>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * SAMPLE_LEN..(i + 1) * SAMPLE_LEN]
    }
}

/// Per-class prototype bank, deterministic in (kind, seed).
struct Prototypes {
    /// classes × 8×8×3 coarse patterns
    coarse: Vec<f32>,
    classes: usize,
}

const COARSE: usize = 8;

impl Prototypes {
    fn new(kind: SynthKind, seed: u64) -> Self {
        let classes = kind.classes();
        let mut rng = Xoshiro256::seed_from(seed ^ 0x9237_0ABC);
        // a shared background plus a scaled class-specific component: the
        // class signal is deliberately a fraction of the total energy so
        // the task has CIFAR-like headroom (no 100% ceilings masking
        // method ordering).
        const CLASS_SEP: f32 = 0.45;
        let plen = COARSE * COARSE * CHANNELS;
        let shared: Vec<f32> = (0..plen).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let mut coarse = vec![0.0f32; classes * plen];
        for c in 0..classes {
            for i in 0..plen {
                coarse[c * plen + i] =
                    (1.0 - CLASS_SEP) * shared[i] + CLASS_SEP * (rng.next_f32() * 2.0 - 1.0);
            }
        }
        Self { coarse, classes }
    }

    /// Bilinear upsample of class `c`'s coarse pattern at a fractional
    /// translation (dx, dy) ∈ [0, 1) coarse-cells.
    fn render(&self, c: usize, dx: f32, dy: f32, out: &mut [f32]) {
        debug_assert!(c < self.classes);
        let base = c * COARSE * COARSE * CHANNELS;
        let scale = COARSE as f32 / IMG as f32;
        for py in 0..IMG {
            for px in 0..IMG {
                let fy = py as f32 * scale + dy;
                let fx = px as f32 * scale + dx;
                let y0 = fy.floor() as isize;
                let x0 = fx.floor() as isize;
                let wy = fy - y0 as f32;
                let wx = fx - x0 as f32;
                for ch in 0..CHANNELS {
                    let at = |yy: isize, xx: isize| -> f32 {
                        let yy = yy.rem_euclid(COARSE as isize) as usize;
                        let xx = xx.rem_euclid(COARSE as isize) as usize;
                        self.coarse[base + (yy * COARSE + xx) * CHANNELS + ch]
                    };
                    let v = at(y0, x0) * (1.0 - wy) * (1.0 - wx)
                        + at(y0, x0 + 1) * (1.0 - wy) * wx
                        + at(y0 + 1, x0) * wy * (1.0 - wx)
                        + at(y0 + 1, x0 + 1) * wy * wx;
                    out[(py * IMG + px) * CHANNELS + ch] = v;
                }
            }
        }
    }
}

/// Generation knobs (defaults mirror the difficulty we validated against
/// the CNN in tests: ~90%+ centralized accuracy, far from trivial for a
/// linear probe under label skew).
#[derive(Debug, Clone, Copy)]
pub struct GenConfig {
    pub noise: f32,
    pub contrast_jitter: f32,
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            noise: 1.1,
            contrast_jitter: 0.5,
            seed: 0,
        }
    }
}

/// Generate `n` samples with balanced labels.
pub fn generate(kind: SynthKind, n: usize, cfg: GenConfig) -> Dataset {
    let protos = Prototypes::new(kind, cfg.seed);
    let classes = kind.classes();
    let mut rng = Xoshiro256::seed_from(cfg.seed ^ 0xDA7A_5E7);
    let mut x = vec![0.0f32; n * SAMPLE_LEN];
    let mut y = Vec::with_capacity(n);
    let mut buf = vec![0.0f32; SAMPLE_LEN];
    for i in 0..n {
        let c = i % classes; // balanced
        y.push(c as i32);
        let dx = rng.next_f32() * 1.5;
        let dy = rng.next_f32() * 1.5;
        protos.render(c, dx, dy, &mut buf);
        let contrast = 1.0 + (rng.next_f32() - 0.5) * 2.0 * cfg.contrast_jitter;
        let out = &mut x[i * SAMPLE_LEN..(i + 1) * SAMPLE_LEN];
        for (o, &p) in out.iter_mut().zip(buf.iter()) {
            *o = contrast * p + cfg.noise * rng.normal() as f32;
        }
    }
    // shuffle so class order is not positional
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut xs = vec![0.0f32; n * SAMPLE_LEN];
    let mut ys = vec![0i32; n];
    for (new_i, &old_i) in idx.iter().enumerate() {
        xs[new_i * SAMPLE_LEN..(new_i + 1) * SAMPLE_LEN]
            .copy_from_slice(&x[old_i * SAMPLE_LEN..(old_i + 1) * SAMPLE_LEN]);
        ys[new_i] = y[old_i];
    }
    Dataset {
        x: xs,
        y: ys,
        classes,
    }
}

/// Train/test pair with disjoint sample RNG but shared prototypes — the
/// test set measures generalization over nuisances, not memorization.
pub fn train_test(kind: SynthKind, n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    train_test_cfg(
        kind,
        n_train,
        n_test,
        GenConfig {
            seed,
            ..Default::default()
        },
    )
}

/// `train_test` with explicit generation knobs (the e2e example lowers the
/// noise so the small CNN learns within its round budget; the probe sweeps
/// keep the harder defaults).
pub fn train_test_cfg(
    kind: SynthKind,
    n_train: usize,
    n_test: usize,
    cfg: GenConfig,
) -> (Dataset, Dataset) {
    let train = generate(kind, n_train, cfg);
    // same prototypes (cfg.seed drives Prototypes), different sample stream
    let mut test = generate_with_stream(kind, n_test, cfg, cfg.seed ^ 0x7E57_7E57);
    test.classes = train.classes;
    (train, test)
}

fn generate_with_stream(kind: SynthKind, n: usize, cfg: GenConfig, stream_seed: u64) -> Dataset {
    let protos = Prototypes::new(kind, cfg.seed);
    let classes = kind.classes();
    let mut rng = Xoshiro256::seed_from(stream_seed);
    let mut x = vec![0.0f32; n * SAMPLE_LEN];
    let mut y = Vec::with_capacity(n);
    let mut buf = vec![0.0f32; SAMPLE_LEN];
    for i in 0..n {
        let c = i % classes;
        y.push(c as i32);
        let dx = rng.next_f32() * 1.5;
        let dy = rng.next_f32() * 1.5;
        protos.render(c, dx, dy, &mut buf);
        let contrast = 1.0 + (rng.next_f32() - 0.5) * 2.0 * cfg.contrast_jitter;
        let out = &mut x[i * SAMPLE_LEN..(i + 1) * SAMPLE_LEN];
        for (o, &p) in out.iter_mut().zip(buf.iter()) {
            *o = contrast * p + cfg.noise * rng.normal() as f32;
        }
    }
    Dataset { x, y, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(SynthKind::Synth10, 50, GenConfig::default());
        let b = generate(SynthKind::Synth10, 50, GenConfig::default());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(
            SynthKind::Synth10,
            50,
            GenConfig {
                seed: 1,
                ..Default::default()
            },
        );
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn labels_balanced_and_in_range() {
        let d = generate(SynthKind::Synth10, 1000, GenConfig::default());
        let mut counts = [0usize; 10];
        for &y in &d.y {
            assert!((0..10).contains(&y));
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn synth100_has_100_classes() {
        let d = generate(SynthKind::Synth100, 500, GenConfig::default());
        assert_eq!(d.classes, 100);
        let distinct: std::collections::BTreeSet<i32> = d.y.iter().cloned().collect();
        assert_eq!(distinct.len(), 100);
    }

    #[test]
    fn same_class_more_similar_than_cross_class() {
        // the learnability invariant: intra-class distance < inter-class
        let d = generate(
            SynthKind::Synth10,
            400,
            GenConfig {
                noise: 0.2,
                ..Default::default()
            },
        );
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        let mut intra = Vec::new();
        let mut inter = Vec::new();
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(d.sample(i), d.sample(j));
                if d.y[i] == d.y[j] {
                    intra.push(dd);
                } else {
                    inter.push(dd);
                }
            }
        }
        let mi = intra.iter().sum::<f64>() / intra.len() as f64;
        let me = inter.iter().sum::<f64>() / inter.len() as f64;
        // the class signal is deliberately a minority of total energy
        // (CLASS_SEP + noise + nuisances), so require a clear but modest gap
        assert!(mi < 0.95 * me, "intra {mi} vs inter {me}");
    }

    #[test]
    fn train_test_share_prototypes_but_not_samples() {
        let (tr, te) = train_test(SynthKind::Synth10, 200, 100, 3);
        assert_eq!(tr.len(), 200);
        assert_eq!(te.len(), 100);
        assert_ne!(&tr.x[..SAMPLE_LEN], &te.x[..SAMPLE_LEN]);
        // prototype sharing: nearest-train-neighbour of a test point tends
        // to share its label (weak check)
        let mut hits = 0;
        for i in 0..20 {
            let mut best = (f64::INFINITY, 0usize);
            for j in 0..tr.len() {
                let dd: f64 = te
                    .sample(i)
                    .iter()
                    .zip(tr.sample(j))
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if dd < best.0 {
                    best = (dd, j);
                }
            }
            if tr.y[best.1] == te.y[i] {
                hits += 1;
            }
        }
        // chance is 2/20; the task is hard by design (noise dominates
        // pixel distance) so require well-above-chance, not dominance
        assert!(hits >= 5, "nearest-neighbour label agreement {hits}/20");
    }

    #[test]
    fn values_are_bounded_sane() {
        let d = generate(SynthKind::Synth10, 100, GenConfig::default());
        assert!(d.x.iter().all(|v| v.is_finite()));
        let maxabs = d.x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(maxabs < 10.0, "max |x| = {maxabs}");
    }
}
