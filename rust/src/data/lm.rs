//! Synthetic Markov-grammar corpus for the LM experiments (Figure 5
//! stand-in; DESIGN.md §2).
//!
//! A seeded sparse Markov chain over a small vocabulary: every token has a
//! few preferred successors (high probability) plus uniform leakage. The
//! resulting sequences have ~2 bits/token of structure a tiny transformer
//! can learn, so loss curves separate cleanly between optimizers.

use crate::util::rng::Xoshiro256;

/// Tokenized dataset of fixed-length sequences.
#[derive(Debug, Clone)]
pub struct LmData {
    pub vocab: usize,
    pub seq: usize,
    /// n * seq input tokens
    pub x: Vec<i32>,
    /// n * seq next-token targets
    pub y: Vec<i32>,
}

impl LmData {
    pub fn len(&self) -> usize {
        if self.seq == 0 {
            0
        } else {
            self.x.len() / self.seq
        }
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    pub fn seq_x(&self, i: usize) -> &[i32] {
        &self.x[i * self.seq..(i + 1) * self.seq]
    }

    pub fn seq_y(&self, i: usize) -> &[i32] {
        &self.y[i * self.seq..(i + 1) * self.seq]
    }
}

/// Sparse Markov transition table, deterministic in `seed`.
pub struct Grammar {
    vocab: usize,
    /// per token: preferred successors
    succ: Vec<[usize; 4]>,
    /// probability mass on preferred successors (rest uniform)
    focus: f64,
}

impl Grammar {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from(seed ^ 0x6_1A44);
        let succ = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab),
                    rng.below(vocab),
                    rng.below(vocab),
                    rng.below(vocab),
                ]
            })
            .collect();
        Self {
            vocab,
            succ,
            focus: 0.9,
        }
    }

    fn next(&self, cur: usize, rng: &mut Xoshiro256) -> usize {
        if rng.next_f64() < self.focus {
            self.succ[cur][rng.below(4)]
        } else {
            rng.below(self.vocab)
        }
    }

    /// Per-token Bayes-optimal cross entropy lower bound is well below
    /// ln(vocab); expose the uniform entropy for test assertions.
    pub fn uniform_nats(&self) -> f64 {
        (self.vocab as f64).ln()
    }
}

/// Generate `n` sequences of length `seq` (+1 hidden token for the final
/// target) from the grammar.
pub fn generate(vocab: usize, seq: usize, n: usize, seed: u64) -> LmData {
    let grammar = Grammar::new(vocab, seed);
    let mut rng = Xoshiro256::seed_from(seed ^ 0x11_FEED);
    let mut x = Vec::with_capacity(n * seq);
    let mut y = Vec::with_capacity(n * seq);
    for _ in 0..n {
        let mut cur = rng.below(vocab);
        let mut toks = Vec::with_capacity(seq + 1);
        toks.push(cur);
        for _ in 0..seq {
            cur = grammar.next(cur, &mut rng);
            toks.push(cur);
        }
        for t in 0..seq {
            x.push(toks[t] as i32);
            y.push(toks[t + 1] as i32);
        }
    }
    LmData { vocab, seq, x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = generate(64, 16, 10, 0);
        assert_eq!(d.len(), 10);
        assert_eq!(d.x.len(), 160);
        assert!(d.x.iter().all(|&t| (0..64).contains(&t)));
        assert!(d.y.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let d = generate(64, 16, 5, 1);
        for i in 0..5 {
            let xs = d.seq_x(i);
            let ys = d.seq_y(i);
            // y[t] == x[t+1] within the visible window
            for t in 0..15 {
                assert_eq!(ys[t], xs[t + 1]);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(generate(64, 8, 4, 7).x, generate(64, 8, 4, 7).x);
        assert_ne!(generate(64, 8, 4, 7).x, generate(64, 8, 4, 8).x);
    }

    #[test]
    fn grammar_is_predictable() {
        // empirical conditional entropy must be far below uniform
        let d = generate(64, 64, 200, 2);
        let mut counts = vec![vec![0usize; 64]; 64];
        for i in 0..d.x.len() {
            counts[d.x[i] as usize][d.y[i] as usize] += 1;
        }
        let mut h = 0.0f64;
        let mut total = 0usize;
        for row in &counts {
            let n: usize = row.iter().sum();
            total += n;
            for &c in row {
                if c > 0 {
                    let p = c as f64 / n as f64;
                    h -= (n as f64) * p.ln() * p / n as f64 * n as f64 / 1.0;
                }
            }
        }
        // normalize: average per-symbol entropy weighted by occupancy
        let mut hsum = 0.0;
        for row in &counts {
            let n: usize = row.iter().sum();
            if n == 0 {
                continue;
            }
            let mut hrow = 0.0;
            for &c in row {
                if c > 0 {
                    let p = c as f64 / n as f64;
                    hrow -= p * p.ln();
                }
            }
            hsum += hrow * n as f64;
        }
        let h_cond = hsum / total as f64;
        let _ = h;
        assert!(
            h_cond < 0.75 * (64f64).ln(),
            "conditional entropy {h_cond} too close to uniform {}",
            (64f64).ln()
        );
    }
}
