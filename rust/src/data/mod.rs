//! Data substrate: procedural datasets (CIFAR-10/ImageNet32 substitutes,
//! Markov LM corpus), the Dirichlet non-IID partitioner, and padded-batch
//! assembly.

pub mod dirichlet;
pub mod lm;
pub mod loader;
pub mod synthetic;

pub use dirichlet::{dirichlet_split, label_histogram, Partition};
pub use loader::{eval_chunks, ClientData, Source};
pub use synthetic::{Dataset, GenConfig, SynthKind};
