//! Figure 5: FedKSeed with many local ZO steps vs the 1-step modification
//! at equal data per round — on the LM task, over the full XLA/PJRT path.
//!
//! Substitution (DESIGN.md §2): DataJuicer-1.3B + Natural Instructions →
//! the `lm` artifact (tiny causal transformer) on the synthetic Markov
//! corpus; Rouge-L → next-token accuracy. The claim under test is the
//! optimizer-dynamics one: at equal per-round data, one aggregated step
//! converges faster and lower than many noisy local steps.

use std::sync::Arc;

use crate::baselines::{FedKSeedRun, KSeedConfig};
use crate::config::Scale;
use crate::data::lm;
use crate::data::loader::{ClientData, Source};
use crate::exp::common::run_path;
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::backend::ModelBackend;
use crate::model::manifest::Manifest;
use crate::model::params::ParamVec;
use crate::runtime::Engine;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// Schema of `runs/fig5.csv`, split out with [`fig5_row`] so the arity
/// contract is unit-testable without the XLA runtime the full runner
/// needs (the only exp runner whose smoke path cannot execute in tests).
const FIG5_CSV_HEADER: [&str; 4] = ["variant", "round", "test_loss", "test_acc"];

fn fig5_row(label: &str, round: usize, test_loss: f64, test_acc: f64) -> Vec<String> {
    vec![
        label.to_string(),
        round.to_string(),
        format!("{test_loss:.4}"),
        format!("{test_acc:.4}"),
    ]
}

struct LmScale {
    clients: usize,
    seqs_per_client: usize,
    pretrain_rounds: usize,
    kseed_rounds: usize,
    multi_steps: usize,
    step_batch: usize,
}

fn lm_scale(scale: Scale) -> LmScale {
    match scale {
        Scale::Smoke => LmScale {
            clients: 3,
            seqs_per_client: 12,
            pretrain_rounds: 3,
            kseed_rounds: 4,
            multi_steps: 4,
            step_batch: 3,
        },
        Scale::Default => LmScale {
            clients: 4,
            seqs_per_client: 32,
            pretrain_rounds: 10,
            kseed_rounds: 20,
            multi_steps: 8,
            step_batch: 4,
        },
        Scale::Paper => LmScale {
            clients: 8,
            seqs_per_client: 64,
            pretrain_rounds: 30,
            kseed_rounds: 40, // the paper's forty rounds
            multi_steps: 200, // the paper's 200 local steps
            step_batch: 2,
        },
    }
}

pub fn run(scale: Scale, artifacts_dir: &str, scenario: &Scenario) -> anyhow::Result<String> {
    let sc = lm_scale(scale);
    let manifest = Manifest::load(artifacts_dir)?;
    let engine = Engine::cpu()?;
    let backend = engine.backend(&manifest, "lm")?;
    let entry = manifest.model("lm")?;

    // data: per-client shards + a test set, same grammar
    let n_total = sc.clients * sc.seqs_per_client;
    let train = Arc::new(lm::generate(64, 64, n_total, 7));
    let test = Source::Lm(Arc::new(lm::generate(64, 64, 24, 7 ^ 0xAB)));
    let src = Source::Lm(train);
    let shards: Vec<ClientData> = (0..sc.clients)
        .map(|c| ClientData {
            source: src.clone(),
            indices: (c * sc.seqs_per_client..(c + 1) * sc.seqs_per_client).collect(),
        })
        .collect();

    // "pretrained model": a short warm federation over all clients
    let mut cfg = Scale::Smoke.fed();
    cfg.scenario = scenario.clone();
    cfg.clients = sc.clients;
    cfg.hi_frac = 1.0;
    cfg.rounds_total = sc.pretrain_rounds;
    cfg.pivot = sc.pretrain_rounds;
    cfg.sample_warm = sc.clients;
    cfg.local_epochs = 1;
    cfg.batch = entry.batch;
    cfg.lr_client_warm = 0.1;
    cfg.eval_every = 1;
    let init = ParamVec::he_init(entry, 7);
    let mut pre = Federation::new(cfg.clone(), &backend, shards.clone(), test.clone(), init)?;
    pre.run()?;
    let pretrained = pre.global.clone();
    let pre_loss = pre.eval()?;

    // the two FedKSeed variants from the same checkpoint, equal data/round
    let mut csv = CsvWriter::create(run_path("fig5.csv"), &FIG5_CSV_HEADER)?;
    let mut results = Vec::new();
    for (label, steps, step_batch) in [
        (
            format!("FedKSeed ({} steps)", sc.multi_steps),
            sc.multi_steps,
            sc.step_batch,
        ),
        (
            "FedKSeed (1 step)".to_string(),
            1usize,
            sc.multi_steps * sc.step_batch, // same samples, one step
        ),
    ] {
        let mut kcfg = cfg.clone();
        kcfg.pivot = 0;
        kcfg.rounds_total = sc.kseed_rounds;
        kcfg.sample_zo = sc.clients;
        kcfg.eval_every = 1;
        kcfg.lr_client_zo = 1.0;
        kcfg.lr_server_zo = 0.05;
        kcfg.zo.eps = 1e-3;
        let ks = KSeedConfig {
            pool_size: 512,
            local_steps: steps,
            step_batch,
        };
        let mut run = FedKSeedRun::new(
            kcfg,
            ks,
            &backend,
            shards.clone(),
            test.clone(),
            pretrained.clone(),
        )?;
        run.run()?;
        for r in &run.log.rounds {
            if !r.test_loss.is_nan() {
                csv.row(&fig5_row(&label, r.round, r.test_loss, r.test_acc))?;
            }
        }
        let final_eval = run.eval()?;
        results.push((label, final_eval.mean_loss(), final_eval.accuracy()));
    }
    csv.flush()?;

    let mut out = String::from(
        "## Figure 5 — FedKSeed local steps vs 1-step (LM over XLA/PJRT)\n\n",
    );
    out.push_str(&format!(
        "Pretrained checkpoint: test loss {:.3}, token acc {:.3}\n\n",
        pre_loss.mean_loss(),
        pre_loss.accuracy()
    ));
    let mut t = MdTable::new(&["Variant", "final test loss", "token acc (Rouge-L proxy)"]);
    for (label, loss, acc) in &results {
        t.row(vec![
            label.clone(),
            format!("{loss:.4}"),
            format!("{acc:.4}"),
        ]);
    }
    out.push_str(&t.render());
    let (multi, one) = (&results[0], &results[1]);
    out.push_str(&format!(
        "\n1-step vs multi-step loss: {:.4} vs {:.4} ({}; paper: 1-step wins, 0.2015 vs 0.1723 Rouge-L)\nCurves: runs/fig5.csv\n",
        one.1,
        multi.1,
        if one.1 <= multi.1 { "1-step wins" } else { "multi-step wins here" },
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_csv_row_matches_header_arity() {
        // the runner itself needs XLA artifacts, so the schema contract
        // is pinned statically: a representative row (labels never embed
        // commas, so the csv splits back to the same arity)
        let row = fig5_row("FedKSeed (4 steps)", 3, 1.2345, 0.5);
        assert_eq!(row.len(), FIG5_CSV_HEADER.len());
        assert!(row.iter().all(|f| !f.contains(',')), "{row:?}");
    }
}
