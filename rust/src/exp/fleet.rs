//! Population-scaling run (`zowarmup exp fleet`): sweep the client count
//! across N ∈ {10³, 10⁵, 10⁷} and measure what the population layer
//! actually costs — per-client state bytes (the peak-RSS proxy) and
//! round wall-time — for the lazy fleet path vs the materialized
//! seed-era path (DESIGN.md §10).
//!
//! Expected shape: lazy rows hold a ~constant few hundred bytes of
//! population state and ~flat round time at every N (rounds cost
//! O(sampled)); materialized rows grow linearly in N and are therefore
//! only run up to 10⁵. The crossover is the whole point of the layer —
//! the 10⁷ row simply does not exist for the materialized mode on
//! reasonable hardware.

use std::sync::Arc;

use crate::config::{PopulationMode, Scale};
use crate::data::loader::Source;
use crate::data::synthetic::{train_test, SynthKind};
use crate::exp::common::{linear_lrs, probe_backend, run_path};
use crate::fed::population::Population;
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::backend::ModelBackend;
use crate::model::params::ParamVec;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// Population sizes swept (N ∈ {1e3, 1e5, 1e7}).
pub const FLEET_NS: [usize; 3] = [1_000, 100_000, 10_000_000];

/// Materialized reference rows stop here: beyond it the O(N) setup is
/// exactly the cost the lazy layer exists to remove.
pub const MATERIALIZED_CAP: usize = 100_000;

/// ZO participants per round in the sweep (the bench rows' K).
pub const FLEET_K: usize = 64;

/// Rounds measured per cell (pure ZO; wall time is the per-round mean).
const FLEET_ROUNDS: usize = 3;

pub fn run(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    run_sweep(scale, scenario, &FLEET_NS, MATERIALIZED_CAP)
}

/// The sweep body, parameterized over the population sizes and the
/// materialized cap so the smoke test can run a genuinely reduced sweep
/// through the identical code path.
fn run_sweep(
    scale: Scale,
    scenario: &Scenario,
    ns: &[usize],
    materialized_cap: usize,
) -> anyhow::Result<String> {
    // the scaling run needs the fleet composition (thin FO backbone over
    // a ZO edge); an unset/binary --scenario substitutes the preset, out
    // loud, like exp ckpt does for churn
    let scenario = if *scenario == Scenario::Binary {
        eprintln!(
            "[exp fleet] binary fleet is the materialized-compat scenario — \
             substituting the `fleet` preset (pass a custom --scenario to override)"
        );
        Scenario::preset("fleet").expect("bundled preset")
    } else {
        scenario.clone()
    };
    let data_cfg = scale.data();
    let backend = probe_backend(SynthKind::Synth10.classes());
    let mut out = format!(
        "## Fleet scaling — population-layer cost vs N (fleet: {})\n\n",
        scenario.name()
    );
    let mut t = MdTable::new(&[
        "clients",
        "mode",
        "setup ms",
        "round ms (mean)",
        "pop state bytes",
        "dropped",
    ]);
    let mut csv = CsvWriter::create(
        run_path("fleet_scaling.csv"),
        &[
            "clients", "mode", "setup_ms", "round_ms_mean", "pop_state_bytes",
            "sampled_per_round", "dropped",
        ],
    )?;
    for &n in ns {
        for mode in [PopulationMode::Lazy, PopulationMode::Materialized] {
            if mode == PopulationMode::Materialized && n > materialized_cap {
                eprintln!(
                    "[exp fleet] skipping materialized N={n}: O(N) setup is the \
                     cost this layer removes (cap {materialized_cap})"
                );
                continue;
            }
            let mut cfg = scale.fed();
            linear_lrs(&mut cfg);
            cfg.clients = n;
            cfg.scenario = scenario.clone();
            cfg.population = mode;
            cfg.pivot = 0; // pure ZO: the O(sampled) round is the subject
            cfg.rounds_total = FLEET_ROUNDS;
            cfg.sample_zo = FLEET_K.min(n);
            cfg.eval_every = FLEET_ROUNDS + 1; // eval only at round 0
            let (train, test) = train_test(
                SynthKind::Synth10,
                data_cfg.n_train,
                data_cfg.n_test,
                cfg.seed,
            );
            let train_src = Source::Image(Arc::new(train));
            let test_src = Source::Image(Arc::new(test));
            let t0 = std::time::Instant::now();
            let init = ParamVec::zeros(backend.dim());
            let mut fed = match mode {
                PopulationMode::Materialized => {
                    // the reference rows hold the SAME per-client data
                    // the lazy rows derive on demand — materialize the
                    // keyed shard draws so the round-time columns
                    // compare identical compute, and only the
                    // population-layer cost differs. (A Dirichlet split
                    // would leave every shard empty once N exceeds the
                    // sample count, turning the reference rounds into
                    // no-ops.)
                    let shards = materialize_lazy_shards(&cfg, &backend, train_src.clone())?;
                    Federation::new(cfg, &backend, shards, test_src, init)?
                }
                _ => Federation::new_lazy(cfg, &backend, train_src, test_src, init)?,
            };
            let setup_ms = t0.elapsed().as_secs_f64() * 1e3;
            fed.run()?;
            let round_ms: f64 = fed.log.rounds.iter().map(|r| r.wall_ms).sum::<f64>()
                / fed.log.rounds.len().max(1) as f64;
            let state_bytes = fed.pop.approx_state_bytes();
            let dropped = fed.log.total_dropped();
            t.row(vec![
                n.to_string(),
                mode.as_str().to_string(),
                format!("{setup_ms:.1}"),
                format!("{round_ms:.1}"),
                state_bytes.to_string(),
                dropped.to_string(),
            ]);
            csv.row(&[
                n.to_string(),
                mode.as_str().to_string(),
                format!("{setup_ms:.3}"),
                format!("{round_ms:.3}"),
                state_bytes.to_string(),
                fed.cfg.sample_zo.to_string(),
                dropped.to_string(),
            ])?;
            eprintln!(
                "[exp fleet] N={n} {}: setup {setup_ms:.1} ms, round {round_ms:.1} ms, \
                 state {state_bytes} B",
                mode.as_str()
            );
        }
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: lazy population state is O(1) and round time is \
         O(sampled) at every N; the materialized rows grow with N and stop \
         at 10^5 by design. CSV: runs/fleet_scaling.csv.\n",
    );
    Ok(out)
}

/// Materialize the exact per-client shards the lazy population would
/// derive — the O(N) build the lazy layer avoids, measured here as the
/// reference cost with byte-identical per-client data.
fn materialize_lazy_shards<B: ModelBackend>(
    cfg: &crate::config::FedConfig,
    backend: &B,
    source: Source,
) -> anyhow::Result<Vec<crate::data::loader::ClientData>> {
    let pop = Population::lazy(
        cfg.clients,
        cfg.hi_count(),
        cfg.seed,
        cfg.scenario.clone(),
        backend.cost_model(),
        source,
    )?;
    Ok((0..cfg.clients).map(|cid| pop.data(cid)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_scaling_smoke_covers_lazy_and_materialized_rows() {
        // a genuinely reduced sweep through the production code path:
        // the 1e5 materialized cell (the slow one) is skipped by capping
        // materialized rows at 1e3, while the tentpole 1e7 lazy cell and
        // the materialized reference both still run
        let md = run_sweep(
            Scale::Smoke,
            &Scenario::default(),
            &[1_000, 10_000_000],
            1_000,
        )
        .unwrap();
        assert!(md.contains("| 1000 | lazy |"));
        assert!(md.contains("| 1000 | materialized |"));
        assert!(md.contains("| 10000000 | lazy |"));
        assert!(
            !md.contains("| 10000000 | materialized |"),
            "the 1e7 materialized row must not exist"
        );
        // schema drift: the csv's rows match its header arity
        let rows =
            crate::exp::common::check_csv_arity("runs/fleet_scaling.csv").unwrap();
        assert!(rows > 0, "fleet_scaling.csv has no data rows");
        let csv = std::fs::read_to_string("runs/fleet_scaling.csv").unwrap();
        assert!(csv.starts_with("clients,mode,setup_ms,round_ms_mean,pop_state_bytes"));
        assert!(csv.contains("10000000,lazy,"));
        // the lazy 1e7 row's population state stays O(1)-small
        for line in csv.lines().filter(|l| l.starts_with("10000000,lazy,")) {
            let bytes: usize = line.split(',').nth(4).unwrap().parse().unwrap();
            assert!(bytes < 4096, "lazy pop state {bytes} B at N=1e7");
        }
    }
}
