//! Buffered-async staleness ablation (`zowarmup exp async`): the sync
//! barrier vs the event-driven engine (`fed::engine`) across a sweep of
//! staleness-decay exponents, under a heterogeneous fleet.
//!
//! The trade the table surfaces: the barrier waits for its slowest
//! sampled client every round (simulated makespan grows with the
//! straggler tail), while the buffered engine folds the first `k`
//! arrivals and pays instead in staleness — contributions computed
//! against old model versions, discounted by `(1 + s)^(-decay)`. Decay 0
//! folds stale updates at full weight; larger exponents converge toward
//! fresh-only aggregation.

use crate::config::{EngineKind, Scale};
use crate::data::synthetic::SynthKind;
use crate::exp::common::{image_setup, linear_lrs, run_path};
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::backend::ModelBackend;
use crate::model::params::ParamVec;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// The swept staleness-decay exponents for the async rows.
pub const DECAYS: [f64; 4] = [0.0, 0.5, 1.0, 2.0];

pub fn run(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    // staleness only exists under a capability spread — the binary
    // fleet's tiers are too uniform for dispatches to straddle rounds,
    // so substitute the edge-spectrum preset (and say so; the CLI cannot
    // distinguish an explicit `--scenario binary` from the default).
    let scenario = if *scenario == Scenario::Binary {
        eprintln!(
            "[exp async] binary fleet has no capability spread — \
             substituting the `edge-spectrum` preset (pass a custom \
             --scenario to override)"
        );
        Scenario::preset("edge-spectrum").expect("bundled preset")
    } else {
        scenario.clone()
    };
    let mut out = format!(
        "## Buffered-async staleness ablation — makespan vs staleness \
         (fleet: {})\n\n",
        scenario.name()
    );
    let mut t = MdTable::new(&[
        "mode",
        "final acc %",
        "mean staleness",
        "sim makespan s",
        "dropped",
        "up-link KB",
        "wall s",
    ]);
    let mut csv = CsvWriter::create(
        run_path("async_ablation.csv"),
        &[
            "mode", "final_acc", "mean_staleness", "makespan_ms", "dropped",
            "up_bytes", "down_bytes", "wall_s",
        ],
    )?;
    let sync_row = ("sync", None);
    let async_rows = DECAYS.map(|d| ("async", Some(d)));
    for (kind, decay) in std::iter::once(sync_row).chain(async_rows) {
        let label = match decay {
            None => "sync".to_string(),
            Some(d) => format!("async d={d}"),
        };
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = scenario.clone();
        if kind == "async" {
            cfg.engine = EngineKind::Async;
            cfg.async_zo.staleness_decay = decay.unwrap();
        }
        let data = scale.data();
        let s = image_setup(SynthKind::Synth10, &data, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        let wall = t0.elapsed().as_secs_f64();
        t.row(vec![
            label.clone(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            format!("{:.2}", fed.log.mean_staleness()),
            format!("{:.2}", fed.log.total_makespan_ms() / 1e3),
            fed.log.total_dropped().to_string(),
            format!("{:.3}", fed.ledger.up_total as f64 / 1e3),
            format!("{wall:.2}"),
        ]);
        csv.row(&[
            label,
            format!("{:.4}", fed.log.final_accuracy()),
            format!("{:.4}", fed.log.mean_staleness()),
            format!("{:.3}", fed.log.total_makespan_ms()),
            fed.log.total_dropped().to_string(),
            fed.ledger.up_total.to_string(),
            fed.ledger.down_total.to_string(),
            format!("{wall:.3}"),
        ])?;
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: the sync row's simulated makespan carries the \
         full straggler tail (every round waits for its slowest sampled \
         client); the async rows fold the first k arrivals instead and \
         report nonzero mean staleness. Decay 0 folds stale contributions \
         at full weight (fastest clock, noisiest steps); larger exponents \
         discount them toward fresh-only aggregation — FedBuff-style \
         buffered updates with polynomial staleness weighting.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_ablation_smoke() {
        let md = run(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("| sync |"));
        for d in DECAYS {
            assert!(md.contains(&format!("| async d={d} |")), "{md}");
        }
        // the sync barrier reports zero staleness by construction; the
        // async sweep under the substituted edge-spectrum fleet must
        // report a nonzero mean for at least one decay setting
        let cell = |line: &str, i: usize| -> f64 {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            cells[i].parse().unwrap()
        };
        let sync_stale = md
            .lines()
            .find(|l| l.starts_with("| sync |"))
            .map(|l| cell(l, 3))
            .unwrap();
        assert_eq!(sync_stale, 0.0, "barrier folds are fresh by construction");
        let async_stales: Vec<f64> = md
            .lines()
            .filter(|l| l.starts_with("| async d="))
            .map(|l| cell(l, 3))
            .collect();
        assert_eq!(async_stales.len(), DECAYS.len());
        assert!(
            async_stales.iter().any(|&s| s > 0.0),
            "the edge-spectrum fleet must produce stale folds: {async_stales:?}"
        );
        // schema drift: the csv's rows match its header arity
        let rows =
            crate::exp::common::check_csv_arity("runs/async_ablation.csv").unwrap();
        assert!(rows > 0, "async_ablation.csv has no data rows");
    }
}
