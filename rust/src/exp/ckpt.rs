//! Checkpoint-cadence ablation (`zowarmup exp ckpt`): sweep
//! `FedConfig::ckpt_every` under a churn fleet and report the catch-up
//! downlink / replay-length / wall-time trade-off (DESIGN.md §7).
//!
//! Small `ckpt_every` → frequent snapshots, short tails: stale clients
//! mostly pay the `4·d` snapshot download. Large `ckpt_every` → rare
//! snapshots, long tails: cheap per-round seed replay but the replay
//! spans grow with staleness. `0` disables the subsystem entirely (the
//! seed repo's free-rejoin accounting) as the baseline row.

use crate::config::Scale;
use crate::data::synthetic::SynthKind;
use crate::exp::common::{image_setup, linear_lrs, run_path};
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::backend::ModelBackend;
use crate::model::params::ParamVec;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// Cadences swept (0 = checkpointing disabled, the baseline).
pub const CADENCES: [usize; 5] = [0, 1, 2, 5, 10];

pub fn run(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    // the ablation needs stale clients to exist; with the binary fleet
    // nothing ever goes stale, so substitute the churn preset. The CLI
    // cannot distinguish an explicit `--scenario binary` from the
    // default, so say so out loud rather than silently sweeping a
    // different fleet than asked for.
    let scenario = if *scenario == Scenario::Binary {
        eprintln!(
            "[exp ckpt] binary fleet has no churn — substituting the `churn` \
             preset (pass a custom --scenario to override)"
        );
        Scenario::preset("churn").expect("bundled preset")
    } else {
        scenario.clone()
    };
    let mut out = format!(
        "## Checkpoint-cadence ablation — catch-up downlink vs `--ckpt-every` \
         (fleet: {})\n\n",
        scenario.name()
    );
    let mut t = MdTable::new(&[
        "ckpt_every",
        "final acc %",
        "catch-up MB",
        "down-link MB",
        "snapshots",
        "max tail (rounds)",
        "dropped/absent",
        "wall s",
    ]);
    let mut csv = CsvWriter::create(
        run_path("ckpt_ablation.csv"),
        &[
            "ckpt_every", "final_acc", "catch_up_bytes", "down_bytes", "up_bytes",
            "snapshots", "max_tail_rounds", "dropped", "wall_s",
        ],
    )?;
    for every in CADENCES {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = scenario.clone();
        cfg.ckpt_every = every;
        let data = scale.data();
        let s = image_setup(SynthKind::Synth10, &data, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        let wall = t0.elapsed().as_secs_f64();
        let label = if every == 0 { "off".to_string() } else { every.to_string() };
        t.row(vec![
            label.clone(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            format!("{:.4}", fed.ledger.catch_up_down_total as f64 / 1e6),
            format!("{:.4}", fed.ledger.down_total as f64 / 1e6),
            fed.ckpt.snapshots_taken.to_string(),
            fed.ckpt.max_tail_rounds.to_string(),
            fed.log.total_dropped().to_string(),
            format!("{wall:.2}"),
        ]);
        csv.row(&[
            every.to_string(),
            format!("{:.4}", fed.log.final_accuracy()),
            fed.ledger.catch_up_down_total.to_string(),
            fed.ledger.down_total.to_string(),
            fed.ledger.up_total.to_string(),
            fed.ckpt.snapshots_taken.to_string(),
            fed.ckpt.max_tail_rounds.to_string(),
            fed.log.total_dropped().to_string(),
            format!("{wall:.3}"),
        ])?;
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: `off` charges no catch-up (the seed repo's \
         free-rejoin assumption); small cadences pay snapshot-sized \
         downloads, large cadences trade them for longer tail replays. \
         Accuracy is cadence-independent when no deadline cuts the \
         catch-up download.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ckpt_ablation_smoke() {
        let md = run(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("ckpt_every"));
        assert!(md.contains("| off |"));
        assert!(md.contains("| 10 |"));
        // the disabled row never charges catch-up
        for line in md.lines().filter(|l| l.starts_with("| off |")) {
            let cells: Vec<&str> = line.split('|').map(str::trim).collect();
            assert_eq!(cells[3], "0.0000", "off row must charge no catch-up: {line}");
        }
        // schema drift: the csv's rows match its 9-column header
        let rows = crate::exp::common::check_csv_arity("runs/ckpt_ablation.csv").unwrap();
        assert!(rows > 0, "ckpt_ablation.csv has no data rows");
    }
}
