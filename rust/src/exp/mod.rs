//! Experiment runners — one per paper table/figure (DESIGN.md §4).
//!
//! Dispatch: `zowarmup exp <id> [--scale smoke|default|paper]`. Every
//! runner returns a Markdown report (appended to runs/report.md) and
//! writes raw CSVs under runs/.

pub mod ablations;
pub mod adaptive;
// `async` is a reserved word, so the module is `asynch` (exp id "async")
pub mod asynch;
pub mod ckpt;
pub mod common;
pub mod curves;
pub mod fig5;
pub mod fleet;
pub mod table1;
pub mod table2;
pub mod table5;
pub mod topo;

use crate::config::Scale;
use crate::data::synthetic::SynthKind;
use crate::sim::Scenario;

pub const ALL_IDS: [&str; 12] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "fig3", "fig4",
    "fig5", "fig6", "fig7",
];

/// Run one experiment by id; returns the Markdown report. `scenario`
/// selects the device-capability fleet every federated run in the sweep
/// draws its profiles from (`Scenario::default()` = the paper's binary
/// High/Low split from `hi_frac`).
pub fn run(
    id: &str,
    scale: Scale,
    artifacts_dir: &str,
    scenario: &Scenario,
) -> anyhow::Result<String> {
    let both = [SynthKind::Synth10, SynthKind::Synth100];
    let one = [SynthKind::Synth10];
    let datasets: &[SynthKind] = if scale == Scale::Smoke { &one } else { &both };
    match id {
        "table1" => table1::run(scale, artifacts_dir, scenario),
        "table2" => table2::run(scale, datasets, scenario),
        "table3" => ablations::table3(scale, scenario),
        "table4" => table2::run_table4(scale, datasets, scenario),
        "table5" => table5::run(scale, artifacts_dir, scenario),
        "table6" => ablations::table6(scale, scenario),
        "table7" => ablations::table7(scale, scenario),
        "fig3" => curves::fig3(scale, scenario),
        "fig4" => curves::fig4(scale, scenario),
        "fig5" => fig5::run(scale, artifacts_dir, scenario),
        "fig6" => ablations::fig6(scale, scenario),
        "fig7" => ablations::fig7(scale, scenario),
        // repo-native (not paper artifacts, so not in ALL_IDS): the
        // checkpoint-cadence ablation under a churn fleet, the adaptive-S
        // / variance-guard ablation under a capability spread, the
        // buffered-async staleness ablation, the population-scaling
        // sweep over the lazy fleet layer, and the two-tier topology
        // sweep over edge-aggregator counts
        "ckpt" => ckpt::run(scale, scenario),
        "adaptive" => adaptive::run(scale, scenario),
        "async" => asynch::run(scale, scenario),
        "fleet" => fleet::run(scale, scenario),
        "topo" => topo::run(scale, scenario),
        "all" => {
            let mut out = String::new();
            for id in ALL_IDS {
                eprintln!("[exp] running {id} at {scale:?} scale...");
                out.push_str(&run(id, scale, artifacts_dir, scenario)?);
                out.push('\n');
            }
            Ok(out)
        }
        _ => anyhow::bail!(
            "unknown experiment {id:?}; available: {:?}, \"ckpt\", \"adaptive\", \
             \"async\", \"fleet\", \"topo\", or \"all\"",
            ALL_IDS
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run("table99", Scale::Smoke, "artifacts", &Scenario::default()).is_err());
    }
}
