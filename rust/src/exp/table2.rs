//! Table 2 (main result) and Table 4 (FedAdam variant): method × split ×
//! dataset sweeps reporting mean(std) accuracy over seeds.

use crate::config::{DataConfig, FedConfig, Scale, ServerOpt};
use crate::data::synthetic::SynthKind;
use crate::exp::common::{nc_cell, run_method, run_path, Method, SPLITS};
use crate::metrics::{summarize_accuracies, MdTable};
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// One full sweep: every (dataset, method, split) cell, `seeds` repeats.
pub fn sweep(
    title: &str,
    csv_name: &str,
    datasets: &[SynthKind],
    methods: &[Method],
    scale: Scale,
    scenario: &Scenario,
    cfg_mod: impl Fn(&mut FedConfig),
) -> anyhow::Result<String> {
    let seeds = scale.seeds();
    let mut out = format!("## {title}\n\n");
    if *scenario != Scenario::Binary {
        // custom scenarios draw their own fleet mix, so the split columns
        // (which only set hi_frac) all run the identical fleet — say so
        // rather than printing identical numbers under different labels
        out.push_str(&format!(
            "NOTE: scenario {:?} fixes the fleet composition; the split \
             labels below do not vary the High/Low mix.\n\n",
            scenario.name()
        ));
    }
    let mut csv = CsvWriter::create(
        run_path(csv_name),
        &["dataset", "method", "split", "seed", "final_acc"],
    )?;
    for &kind in datasets {
        let mut t = MdTable::new(&["Method", "10/90", "30/70", "50/50", "70/30", "90/10"]);
        for &method in methods {
            let mut cells = vec![method.label().to_string()];
            for &(hi_frac, split_label) in &SPLITS {
                let mut accs = Vec::with_capacity(seeds);
                for seed in 0..seeds {
                    let mut cfg = scale.fed();
                    cfg.hi_frac = hi_frac;
                    cfg.seed = seed as u64;
                    cfg.scenario = scenario.clone();
                    cfg_mod(&mut cfg);
                    let data = DataConfig {
                        dataset: match kind {
                            SynthKind::Synth10 => "synth10".into(),
                            SynthKind::Synth100 => "synth100".into(),
                        },
                        ..scale.data()
                    };
                    let log = run_method(method, kind, &data, &cfg)?;
                    let acc = log.final_accuracy();
                    accs.push(acc);
                    csv.row(&[
                        data.dataset.clone(),
                        method.label().to_string(),
                        split_label.to_string(),
                        seed.to_string(),
                        format!("{acc:.4}"),
                    ])?;
                }
                let mean = accs.iter().sum::<f64>() / accs.len() as f64;
                let cell = nc_cell(mean, kind.classes())
                    .unwrap_or_else(|| summarize_accuracies(&accs));
                cells.push(cell);
            }
            t.row(cells);
        }
        out.push_str(&format!(
            "Dataset: {} ({} classes)\n\n",
            match kind {
                SynthKind::Synth10 => "synth10 (CIFAR-10 substitute)",
                SynthKind::Synth100 => "synth100 (ImageNet32 substitute)",
            },
            kind.classes()
        ));
        out.push_str(&t.render());
        out.push('\n');
    }
    csv.flush()?;
    Ok(out)
}

/// Table 2: the five-method main comparison.
pub fn run(scale: Scale, datasets: &[SynthKind], scenario: &Scenario) -> anyhow::Result<String> {
    sweep(
        "Table 2 — main comparison (final test accuracy %, mean(std))",
        "table2.csv",
        datasets,
        &[
            Method::HeteroFl,
            Method::HighResOnly,
            Method::FedKSeedCold,
            Method::ZoWarmupFedKSeed,
            Method::ZoWarmup,
        ],
        scale,
        scenario,
        |_| {},
    )
}

/// Table 4: FedAdam as the server optimizer in both phases.
pub fn run_table4(scale: Scale, datasets: &[SynthKind], scenario: &Scenario) -> anyhow::Result<String> {
    sweep(
        "Table 4 — FedAdam server optimizer (both phases)",
        "table4.csv",
        datasets,
        &[Method::HighResOnly, Method::ZoWarmup],
        scale,
        scenario,
        |cfg| {
            cfg.server_opt = ServerOpt::adam();
            // Adam server steps need a smaller lr (paper §A.5: Adam grids
            // sit 1-2 decades below the SGD grids)
            cfg.lr_server_warm = 0.003;
            cfg.lr_server_zo = 0.003;
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_smoke_has_expected_shape() {
        let md = run(Scale::Smoke, &[SynthKind::Synth10], &Scenario::default()).unwrap();
        assert!(md.contains("ZOWarmUp (ours)"));
        assert!(md.contains("High Res Only"));
        assert!(md.contains("HeteroFL"));
        assert!(md.contains("10/90"));
        // csv written, and every row matches the 5-column header (schema
        // drift between the header list and the row pushes fails loudly)
        let rows = crate::exp::common::check_csv_arity("runs/table2.csv").unwrap();
        assert!(rows > 0, "table2.csv has no data rows");
    }

    #[test]
    fn table4_smoke_runs_with_adam() {
        let md = run_table4(Scale::Smoke, &[SynthKind::Synth10], &Scenario::default()).unwrap();
        assert!(md.contains("FedAdam"));
    }
}
