//! Topology-scaling run (`zowarmup exp topo`): sweep the edge-aggregator
//! count E ∈ {1, 4, 16} across population sizes N up to 10⁷ (lazy fleet
//! path) under a geo-distributed scenario, and measure what the two-tier
//! topology costs and loses — per-round wall time, the per-edge traffic
//! split, and the cohort drops a dark edge inflicts (DESIGN.md §13).
//!
//! Expected shape: the E=1 column is the flat baseline (bit-identical to
//! the historical engine — the equivalence harness in
//! `tests/integration_matrix.rs` pins that, this sweep only reports it);
//! growing E leaves total bytes intact on plain fleets (the two-tier
//! fold is bit-identical, only attribution changes) while geo scenarios
//! diverge: edge deadline overrides cut stragglers, edge outages drop
//! whole cohorts (`edge_drops`), and the per-edge ledger shows which
//! region's backhaul carries the round. Every row asserts the per-edge
//! ledger sums back to the flat totals — the reduction invariant the
//! attribution layer guarantees.

use std::sync::Arc;

use crate::config::Scale;
use crate::data::loader::Source;
use crate::data::synthetic::{train_test, SynthKind};
use crate::exp::common::{linear_lrs, probe_backend, run_path};
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::params::ParamVec;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// Population sizes swept (N ∈ {1e3, 1e5, 1e7}; all lazy — the topology
/// layer rides on the O(sampled) fleet path).
pub const TOPO_NS: [usize; 3] = [1_000, 100_000, 10_000_000];

/// Edge-aggregator counts swept.
pub const TOPO_ES: [usize; 3] = [1, 4, 16];

/// ZO participants per round in the sweep.
const TOPO_K: usize = 64;

/// Rounds measured per cell (pure ZO; wall time is the per-round mean).
const TOPO_ROUNDS: usize = 4;

pub fn run(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    run_sweep(scale, scenario, &TOPO_NS, &TOPO_ES)
}

/// The sweep body, parameterized over the population and edge counts so
/// the smoke test can run a genuinely reduced sweep through the
/// identical code path.
fn run_sweep(
    scale: Scale,
    scenario: &Scenario,
    ns: &[usize],
    es: &[usize],
) -> anyhow::Result<String> {
    // the topology run needs per-edge links/deadlines/failures; an
    // unset/binary --scenario substitutes the geo preset, out loud, like
    // exp fleet does for its composition
    let scenario = if *scenario == Scenario::Binary {
        eprintln!(
            "[exp topo] binary fleet declares no edges — substituting the \
             `geo-iot` preset (pass a custom --scenario to override)"
        );
        Scenario::preset("geo-iot").expect("bundled preset")
    } else {
        scenario.clone()
    };
    let data_cfg = scale.data();
    let backend = probe_backend(SynthKind::Synth10.classes());
    let mut out = format!(
        "## Topology scaling — two-tier edge aggregation vs E (fleet: {})\n\n",
        scenario.name()
    );
    let mut t = MdTable::new(&[
        "clients",
        "edges",
        "round ms (mean)",
        "MB up",
        "MB down",
        "dropped",
        "edge drops",
    ]);
    let mut csv = CsvWriter::create(
        run_path("topo_scaling.csv"),
        &[
            "clients", "edges", "scenario", "round_ms_mean", "bytes_up", "bytes_down",
            "catch_up_down", "dropped", "edge_drops", "edge_up_sum", "edge_down_sum",
        ],
    )?;
    for &n in ns {
        for &e in es {
            let mut cfg = scale.fed();
            linear_lrs(&mut cfg);
            cfg.clients = n;
            cfg.scenario = scenario.clone();
            cfg.edges = e;
            cfg.population = crate::config::PopulationMode::Lazy;
            cfg.pivot = 0; // pure ZO: the two-tier fold is the subject
            cfg.rounds_total = TOPO_ROUNDS;
            cfg.sample_zo = TOPO_K.min(n);
            cfg.eval_every = TOPO_ROUNDS + 1; // eval only at round 0
            let (train, test) = train_test(
                SynthKind::Synth10,
                data_cfg.n_train,
                data_cfg.n_test,
                cfg.seed,
            );
            let init = ParamVec::zeros(backend.dim());
            let mut fed = Federation::new_lazy(
                cfg,
                &backend,
                Source::Image(Arc::new(train)),
                Source::Image(Arc::new(test)),
                init,
            )?;
            fed.run()?;
            let round_ms: f64 = fed.log.rounds.iter().map(|r| r.wall_ms).sum::<f64>()
                / fed.log.rounds.len().max(1) as f64;
            let (up, down) = fed.log.total_bytes();
            let dropped = fed.log.total_dropped();
            let edge_drops = fed.log.total_edge_drops();
            let (edge_up, edge_down, edge_catch) = fed.ledger.edge_totals();
            // the attribution invariant: per-edge ledgers are an exact
            // partition of the flat totals (empty for the E=1 flat path)
            if e > 1 {
                anyhow::ensure!(
                    edge_up == fed.ledger.up_total && edge_down == fed.ledger.down_total,
                    "per-edge ledger ({edge_up}, {edge_down}) != flat totals \
                     ({}, {}) at N={n} E={e}",
                    fed.ledger.up_total,
                    fed.ledger.down_total,
                );
                anyhow::ensure!(
                    edge_catch == fed.ledger.catch_up_down_total,
                    "per-edge catch-up {edge_catch} != flat {} at N={n} E={e}",
                    fed.ledger.catch_up_down_total,
                );
            }
            t.row(vec![
                n.to_string(),
                e.to_string(),
                format!("{round_ms:.1}"),
                format!("{:.3}", up as f64 / 1e6),
                format!("{:.3}", down as f64 / 1e6),
                dropped.to_string(),
                edge_drops.to_string(),
            ]);
            csv.row(&[
                n.to_string(),
                e.to_string(),
                scenario.name().to_string(),
                format!("{round_ms:.3}"),
                up.to_string(),
                down.to_string(),
                fed.log.total_catch_up_down().to_string(),
                dropped.to_string(),
                edge_drops.to_string(),
                edge_up.to_string(),
                edge_down.to_string(),
            ])?;
            eprintln!(
                "[exp topo] N={n} E={e}: round {round_ms:.1} ms, \
                 up {up} B, down {down} B, edge drops {edge_drops}"
            );
        }
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: E=1 is the flat baseline; under geo scenarios \
         larger E trades whole-cohort edge outages (edge drops) against \
         per-region deadlines and backhaul attribution, while the per-edge \
         ledger always sums exactly to the flat totals. \
         CSV: runs/topo_scaling.csv.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topo_scaling_smoke_covers_flat_and_two_tier_rows() {
        // a genuinely reduced sweep through the production code path:
        // the flat baseline, a two-tier cell, and the tentpole 1e7 cell
        let md = run_sweep(
            Scale::Smoke,
            &Scenario::default(),
            &[1_000, 10_000_000],
            &[1, 4],
        )
        .unwrap();
        assert!(md.contains("| 1000 | 1 |"));
        assert!(md.contains("| 1000 | 4 |"));
        assert!(md.contains("| 10000000 | 4 |"));
        let csv = std::fs::read_to_string("runs/topo_scaling.csv").unwrap();
        assert!(csv.starts_with(
            "clients,edges,scenario,round_ms_mean,bytes_up,bytes_down"
        ));
        assert!(csv.contains("10000000,4,geo-iot,"));
        // schema drift: every row carries exactly the header's arity
        let rows =
            crate::exp::common::check_csv_arity("runs/topo_scaling.csv").unwrap();
        assert_eq!(rows, 4, "2 Ns x 2 Es");
        // the E>1 rows' per-edge sums equal the flat byte columns (the
        // runner itself ensures it; re-checked here from the artifact)
        for line in csv.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            if f[1] != "1" {
                assert_eq!(f[4], f[9], "edge_up_sum != bytes_up: {line}");
                assert_eq!(f[5], f[10], "edge_down_sum != bytes_down: {line}");
            }
        }
    }
}
