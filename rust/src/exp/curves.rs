//! Figure 3 (training curves with the pivot spike) and Figure 4 (accuracy
//! as a function of the pivot point).

use crate::config::Scale;
use crate::data::synthetic::SynthKind;
use crate::exp::common::{run_method, run_path, Method};
use crate::metrics::MdTable;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// Figure 3: per-round accuracy curves for the 10/90 and 90/10 splits.
/// The signature phenomenon: a visible accuracy jump right after the pivot
/// when low-resource client data enters training — even at 90/10.
pub fn fig3(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let mut out = String::from("## Figure 3 — training curves (accuracy vs round)\n\n");
    let mut csv = CsvWriter::create(
        run_path("fig3.csv"),
        &["split", "round", "phase", "test_acc"],
    )?;
    let mut t = MdTable::new(&[
        "split",
        "acc at pivot",
        "acc post-pivot (+5 evals)",
        "final acc",
        "jump",
    ]);
    for (hi_frac, label) in [(0.1, "10/90"), (0.9, "90/10")] {
        let mut cfg = scale.fed();
        cfg.hi_frac = hi_frac;
        cfg.scenario = scenario.clone();
        cfg.eval_every = 1; // dense curve
        let data = scale.data();
        let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
        for r in &log.rounds {
            if !r.test_acc.is_nan() {
                csv.row(&[
                    label.to_string(),
                    r.round.to_string(),
                    r.phase.as_str().to_string(),
                    format!("{:.4}", r.test_acc),
                ])?;
            }
        }
        let curve = log.accuracy_curve();
        let at_pivot = curve
            .iter()
            .filter(|(r, _)| *r < cfg.pivot)
            .map(|(_, a)| *a)
            .last()
            .unwrap_or(0.0);
        let post: Vec<f64> = curve
            .iter()
            .filter(|(r, _)| *r >= cfg.pivot)
            .take(5)
            .map(|(_, a)| *a)
            .collect();
        let post_mean = if post.is_empty() {
            f64::NAN
        } else {
            post.iter().sum::<f64>() / post.len() as f64
        };
        t.row(vec![
            label.to_string(),
            format!("{:.1}", at_pivot * 100.0),
            format!("{:.1}", post_mean * 100.0),
            format!("{:.1}", log.final_accuracy() * 100.0),
            format!("{:+.1}", (log.final_accuracy() - at_pivot) * 100.0),
        ]);
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str("\nFull curves in runs/fig3.csv. Expected shape: accuracy rises when\nlow-resource clients join at the pivot, for BOTH splits.\n");
    Ok(out)
}

/// Figure 4: sweep the pivot at fixed total rounds; accuracy should rise,
/// peak at an interior pivot, then fall (critical learning periods).
pub fn fig4(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let total = scale.fed().rounds_total;
    // pivot grid: 0%, 20%, 40%, 60%, 80%, 100% of the budget
    let pivots: Vec<usize> = (0..=5).map(|i| i * total / 5).collect();
    let seeds = scale.seeds();
    let mut out = String::from("## Figure 4 — accuracy vs pivot point (fixed total rounds)\n\n");
    let mut csv = CsvWriter::create(
        run_path("fig4.csv"),
        &["split", "pivot", "seed", "final_acc"],
    )?;
    let mut t = MdTable::new(&["pivot", "10/90", "50/50"]);
    let mut rows: Vec<Vec<String>> = pivots.iter().map(|p| vec![p.to_string()]).collect();
    for (hi_frac, label) in [(0.1, "10/90"), (0.5, "50/50")] {
        for (pi, &pivot) in pivots.iter().enumerate() {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut cfg = scale.fed();
                cfg.hi_frac = hi_frac;
                cfg.seed = seed as u64;
                cfg.scenario = scenario.clone();
                cfg.pivot = pivot;
                let data = scale.data();
                let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
                accs.push(log.final_accuracy());
                csv.row(&[
                    label.to_string(),
                    pivot.to_string(),
                    seed.to_string(),
                    format!("{:.4}", accs.last().unwrap()),
                ])?;
            }
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            rows[pi].push(format!("{:.1}", mean * 100.0));
        }
    }
    for r in rows {
        t.row(r);
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str("\nExpected shape: interior maximum — too little warm-up starves ZO,\ntoo much withholds low-resource data past the critical period.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_smoke() {
        let md = fig3(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("10/90"));
        assert!(md.contains("90/10"));
        assert!(std::path::Path::new("runs/fig3.csv").exists());
    }

    #[test]
    fn fig4_smoke() {
        let md = fig4(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("pivot"));
        assert!(md.contains("50/50"));
    }
}
