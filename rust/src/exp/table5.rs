//! Table 5: ZOWarmUp with a transformer (ViT) — over the full XLA/PJRT
//! path using the `vit10` artifact.

use std::sync::Arc;

use crate::config::Scale;
use crate::data::dirichlet::dirichlet_split;
use crate::data::loader::Source;
use crate::data::synthetic::{train_test, SynthKind};
use crate::exp::common::SPLITS;
use crate::fed::server::{shards_from_partition, Federation};
use crate::metrics::MdTable;
use crate::model::manifest::Manifest;
use crate::model::params::ParamVec;
use crate::runtime::Engine;
use crate::sim::Scenario;

struct VitScale {
    n_train: usize,
    n_test: usize,
    splits: usize,
    seeds: usize,
}

fn vit_scale(scale: Scale) -> VitScale {
    match scale {
        Scale::Smoke => VitScale {
            n_train: 200,
            n_test: 64,
            splits: 2,
            seeds: 1,
        },
        Scale::Default => VitScale {
            n_train: 600,
            n_test: 128,
            splits: 3,
            seeds: 1,
        },
        Scale::Paper => VitScale {
            n_train: 2000,
            n_test: 500,
            splits: 5,
            seeds: 3,
        },
    }
}

pub fn run(scale: Scale, artifacts_dir: &str, scenario: &Scenario) -> anyhow::Result<String> {
    let vs = vit_scale(scale);
    let manifest = Manifest::load(artifacts_dir)?;
    let engine = Engine::cpu()?;
    let backend = engine.backend(&manifest, "vit10")?;
    let entry = manifest.model("vit10")?;

    let mut out = String::from("## Table 5 — ZOWarmUp on ViT (XLA/PJRT path)\n\n");
    let mut t = MdTable::new(&["Method", "split", "final acc %"]);
    // pick the first vs.splits split points spread across the range
    let chosen: Vec<(f64, &str)> = SPLITS
        .iter()
        .step_by((SPLITS.len() / vs.splits).max(1))
        .take(vs.splits)
        .cloned()
        .collect();
    for (hi_frac, label) in chosen {
        for (pivot_frac, mlabel) in [(1.0, "High Res Only"), (0.5, "ZOWarmUp (ours)")] {
            let mut accs = Vec::new();
            for seed in 0..vs.seeds {
                let mut cfg = Scale::Smoke.fed();
                cfg.clients = 8;
                cfg.hi_frac = hi_frac;
                cfg.seed = seed as u64;
                cfg.scenario = scenario.clone();
                cfg.rounds_total = match scale {
                    Scale::Smoke => 8,
                    Scale::Default => 16,
                    Scale::Paper => 60,
                };
                cfg.pivot = (cfg.rounds_total as f64 * pivot_frac) as usize;
                cfg.sample_warm = 3;
                cfg.sample_zo = 4;
                cfg.local_epochs = 1;
                cfg.batch = entry.batch;
                cfg.lr_client_warm = 0.05;
                cfg.lr_client_zo = 1.0;
                cfg.lr_server_zo = 0.02;
                cfg.zo.eps = 1e-3;
                cfg.eval_every = cfg.rounds_total; // eval at pivot+end only
                let (train, test) = train_test(SynthKind::Synth10, vs.n_train, vs.n_test, seed as u64);
                let part = dirichlet_split(&train, cfg.clients, 0.1, seed as u64);
                let src = Source::Image(Arc::new(train));
                let shards = shards_from_partition(&src, &part);
                let init = ParamVec::he_init(entry, seed as u64);
                let mut fed = Federation::new(
                    cfg,
                    &backend,
                    shards,
                    Source::Image(Arc::new(test)),
                    init,
                )?;
                fed.run()?;
                accs.push(fed.log.final_accuracy());
            }
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            t.row(vec![
                mlabel.to_string(),
                label.to_string(),
                format!("{:.1}", mean * 100.0),
            ]);
        }
    }
    out.push_str(&t.render());
    out.push_str("\nExpected shape: ZOWarmUp > High Res Only; ViT under-performs the CNN\n(as in the paper — transformers are data-hungry at this scale).\n");
    Ok(out)
}
