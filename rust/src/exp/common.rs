//! Shared experiment machinery: method dispatch, setup builders, sweep
//! helpers. Every table/figure runner composes these.
//!
//! Sweep tables default to the [`LinearBackend`] probe (host-side, exact
//! gradients) so 100+ runs fit a 1-core budget; the e2e example, fig3
//! (`--backend xla`), fig5 and table5 exercise the full XLA/PJRT path
//! (DESIGN.md §4).
//!
//! Every run here inherits the parallel round engine through
//! `FedConfig::threads` (0 = auto, overridable per-sweep via
//! `ZOWARMUP_THREADS` / `zowarmup exp --threads N`). Worker count never
//! changes results — table cells are bit-identical across thread counts
//! (`fed::server`'s threading model) — so sweeps can use every core
//! without invalidating paper-comparison numbers.

use std::sync::Arc;

use crate::baselines::{FedKSeedRun, HeteroFlRun, KSeedConfig, SliceMap};
use crate::config::{DataConfig, FedConfig};
use crate::data::dirichlet::dirichlet_split;
use crate::data::loader::{ClientData, Source};
use crate::data::synthetic::{train_test, SynthKind, SAMPLE_LEN};
use crate::fed::server::{shards_from_partition, Federation};
use crate::metrics::RunLog;
use crate::model::backend::{LinearBackend, ModelBackend};
use crate::model::params::ParamVec;

/// The methods compared across the paper's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// exclude low-resource clients entirely (warm phase for all rounds)
    HighResOnly,
    /// the paper's two-step method (Algorithm 1)
    ZoWarmup,
    /// warm start, then FedKSeed (1 local step) as the step-2 method
    ZoWarmupFedKSeed,
    /// FedKSeed from scratch (multi-step; the paper's "nc" rows)
    FedKSeedCold,
    /// HeteroFL width-sliced sub-networks
    HeteroFl,
    /// §A.4 ablation: high-res clients keep making FO updates in step 2
    ZoWarmupMixed,
}

impl Method {
    pub fn label(self) -> &'static str {
        match self {
            Method::HighResOnly => "High Res Only",
            Method::ZoWarmup => "ZOWarmUp (ours)",
            Method::ZoWarmupFedKSeed => "ZOWarmUp + FedKSeed",
            Method::FedKSeedCold => "FedKSeed",
            Method::HeteroFl => "HeteroFL",
            Method::ZoWarmupMixed => "ZOWarmUp (hi+lo)",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "highres" => Some(Method::HighResOnly),
            "zowarmup" => Some(Method::ZoWarmup),
            "zowarmup-fedkseed" => Some(Method::ZoWarmupFedKSeed),
            "fedkseed" => Some(Method::FedKSeedCold),
            "heterofl" => Some(Method::HeteroFl),
            "zowarmup-mixed" => Some(Method::ZoWarmupMixed),
            _ => None,
        }
    }
}

/// Reusable image-task setup: data, Dirichlet shards, linear backend.
pub struct ImageSetup {
    pub backend: LinearBackend,
    pub shards: Vec<ClientData>,
    pub test: Source,
    pub classes: usize,
}

/// LR preset for the linear probe (validated in tests; roughly the paper's
/// grid-search optimum transplanted to this model family).
pub fn linear_lrs(cfg: &mut FedConfig) {
    cfg.lr_client_warm = 0.06;
    cfg.lr_server_warm = 1.0;
    // SPSA's estimator norm scales ~√(d/S) above the true gradient, so the
    // ZO rate sits well below the FO rate. Grid-searched over
    // {3e-4..1e-1} at the default scale (EXPERIMENTS.md §Calibration);
    // 0.01 gives the paper's ordering at every split.
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
}

/// Probe pooling: 32×32×3 rows average-pooled 2×2 → 768 features. Keeps
/// d = C·768+C — small enough that SPSA's √d noise sits in the regime the
/// paper tuned for, and 4× faster per forward.
pub const PROBE_POOL: usize = 2;

pub fn probe_backend(classes: usize) -> LinearBackend {
    LinearBackend::pooled(SAMPLE_LEN, PROBE_POOL, classes, 32)
}

pub fn image_setup(kind: SynthKind, data_cfg: &DataConfig, cfg: &FedConfig) -> ImageSetup {
    let (train, test) = train_test(kind, data_cfg.n_train, data_cfg.n_test, cfg.seed);
    let part = dirichlet_split(&train, cfg.clients, data_cfg.alpha, cfg.seed);
    let src = Source::Image(Arc::new(train));
    let shards = shards_from_partition(&src, &part);
    ImageSetup {
        backend: probe_backend(kind.classes()),
        shards,
        test: Source::Image(Arc::new(test)),
        classes: kind.classes(),
    }
}

/// Run one (method, config, seed) cell and return its log.
pub fn run_method(
    method: Method,
    kind: SynthKind,
    data_cfg: &DataConfig,
    base: &FedConfig,
) -> anyhow::Result<RunLog> {
    let mut cfg = base.clone();
    linear_lrs(&mut cfg);
    match method {
        Method::HighResOnly => {
            cfg.pivot = cfg.rounds_total; // never leave the warm phase
            let s = image_setup(kind, data_cfg, &cfg);
            let init = ParamVec::zeros(s.backend.dim());
            let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
            fed.run()?;
            Ok(fed.log)
        }
        Method::ZoWarmup | Method::ZoWarmupMixed => {
            cfg.mixed_step2 = method == Method::ZoWarmupMixed;
            let s = image_setup(kind, data_cfg, &cfg);
            let init = ParamVec::zeros(s.backend.dim());
            let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
            fed.run()?;
            Ok(fed.log)
        }
        Method::ZoWarmupFedKSeed => {
            let s = image_setup(kind, data_cfg, &cfg);
            let init = ParamVec::zeros(s.backend.dim());
            let ks = KSeedConfig {
                pool_size: 1024,
                local_steps: 1,
                // single step on (up to) the whole shard = equal data
                step_batch: s.backend.batch,
            };
            let mut run = FedKSeedRun::new(cfg, ks, &s.backend, s.shards, s.test, init)?;
            run.run()?;
            Ok(run.log)
        }
        Method::FedKSeedCold => {
            cfg.pivot = 0; // from scratch: no warm start
            let s = image_setup(kind, data_cfg, &cfg);
            let init = ParamVec::zeros(s.backend.dim());
            let ks = KSeedConfig {
                pool_size: 1024,
                local_steps: 20, // scaled-down analogue of the paper's 200
                step_batch: 8,
            };
            let mut run = FedKSeedRun::new(cfg, ks, &s.backend, s.shards, s.test, init)?;
            run.run()?;
            Ok(run.log)
        }
        Method::HeteroFl => {
            let s = image_setup(kind, data_cfg, &cfg);
            let full = s.backend;
            let half = LinearBackend::sliced(&full, full.features / 2);
            let map = linear_slice_map(s.classes, full.features);
            // the paper gives HeteroFL a fixed communication budget equal
            // to ZOWarmUp's total spend; that yields fewer rounds as the
            // high-resource share grows.
            let budget = zowarmup_budget_bytes(&cfg, full.dim());
            let mut hcfg = cfg.clone();
            let probe = HeteroFlRun::new(
                hcfg.clone(),
                &full,
                &half,
                map.clone(),
                s.shards.clone(),
                s.test.clone(),
                ParamVec::zeros(full.dim()),
            )?;
            let per_round = probe.per_round_bytes().max(1);
            hcfg.rounds_total = ((budget / per_round) as usize).clamp(2, cfg.rounds_total);
            hcfg.pivot = hcfg.pivot.min(hcfg.rounds_total);
            let mut run = HeteroFlRun::new(
                hcfg,
                &full,
                &half,
                map,
                s.shards,
                s.test,
                ParamVec::zeros(full.dim()),
            )?;
            run.run()?;
            Ok(run.log)
        }
    }
}

/// ZOWarmUp's *nominal* total communication spend (bytes, both
/// directions) under a config — the fixed budget handed to HeteroFL.
/// Deliberately split-independent (nominal sample counts, not the
/// split-clamped ones) so every split competes under the same budget, as
/// in the paper; HeteroFL's per-round cost grows with the high-resource
/// share, so its round count shrinks.
pub fn zowarmup_budget_bytes(cfg: &FedConfig, dim: usize) -> u64 {
    let warm = cfg.pivot as u64 * cfg.sample_warm as u64 * (dim as u64 * 4) * 2;
    let (up, down) = crate::zo::zo_round_bytes(cfg.zo.s_seeds, cfg.sample_zo);
    let zo = (cfg.rounds_total - cfg.pivot) as u64 * cfg.sample_zo as u64 * (up + down);
    warm + zo
}

/// Leading-slice map for the linear probe (W row prefix + bias).
pub fn linear_slice_map(classes: usize, features: usize) -> SliceMap {
    let fh = features / 2;
    SliceMap::from_shape_pairs(
        &[
            (vec![classes, features], 0, vec![classes, fh], 0),
            (
                vec![classes],
                classes * features,
                vec![classes],
                classes * fh,
            ),
        ],
        classes * features + classes,
        classes * fh + classes,
    )
    .expect("static slice map")
}

/// The paper's split labels.
pub const SPLITS: [(f64, &str); 5] = [
    (0.1, "10/90"),
    (0.3, "30/70"),
    (0.5, "50/50"),
    (0.7, "70/30"),
    (0.9, "90/10"),
];

/// Convergence threshold for "nc" rows: below 1.5× random accuracy after a
/// full run counts as not converged.
pub fn nc_cell(acc: f64, classes: usize) -> Option<String> {
    if acc < 1.5 / classes as f64 {
        Some("nc".to_string())
    } else {
        None
    }
}

/// Ensure the runs/ output dir exists and return a path inside it.
pub fn run_path(name: &str) -> String {
    std::fs::create_dir_all("runs").ok();
    format!("runs/{name}")
}

/// Schema-drift check shared by the runner smoke tests: parse an emitted
/// CSV artifact and require every data row to carry exactly the header's
/// field count. Returns the data-row count so callers can also assert the
/// file is non-trivial. Fields are split naively on ','; the runners'
/// emitted values (names, labels, numbers) never contain embedded commas,
/// and a quoted-escape sneaking in would fail here — which is the point.
pub fn check_csv_arity(path: &str) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("{path}: empty csv"))?;
    let cols = header.split(',').count();
    anyhow::ensure!(cols >= 2, "{path}: degenerate {cols}-column header");
    let mut rows = 0;
    for line in lines {
        let got = line.split(',').count();
        anyhow::ensure!(
            got == cols,
            "{path}: row has {got} fields, header has {cols}: {line}"
        );
        rows += 1;
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scale;

    #[test]
    fn method_labels_and_parse() {
        for m in [
            Method::HighResOnly,
            Method::ZoWarmup,
            Method::ZoWarmupFedKSeed,
            Method::FedKSeedCold,
            Method::HeteroFl,
            Method::ZoWarmupMixed,
        ] {
            assert!(!m.label().is_empty());
        }
        assert_eq!(Method::parse("zowarmup"), Some(Method::ZoWarmup));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn all_methods_run_at_smoke_scale() {
        let mut cfg = Scale::Smoke.fed();
        cfg.hi_frac = 0.5;
        let data = Scale::Smoke.data();
        for m in [
            Method::HighResOnly,
            Method::ZoWarmup,
            Method::ZoWarmupFedKSeed,
            Method::FedKSeedCold,
            Method::HeteroFl,
            Method::ZoWarmupMixed,
        ] {
            let log = run_method(m, SynthKind::Synth10, &data, &cfg)
                .unwrap_or_else(|e| panic!("{m:?}: {e}"));
            let acc = log.final_accuracy();
            assert!(acc.is_finite(), "{m:?} produced NaN accuracy");
            assert!(acc >= 0.0 && acc <= 1.0);
        }
    }

    #[test]
    fn methods_run_under_straggler_scenario() {
        // every comparator handles a dropout/straggler fleet: finite
        // accuracy, and the run is reproducible
        let mut cfg = Scale::Smoke.fed();
        cfg.scenario = crate::sim::Scenario::preset("stragglers").unwrap();
        let data = Scale::Smoke.data();
        for m in [Method::ZoWarmup, Method::HeteroFl, Method::ZoWarmupFedKSeed] {
            let a = run_method(m, SynthKind::Synth10, &data, &cfg)
                .unwrap_or_else(|e| panic!("{m:?}: {e}"));
            assert!(a.final_accuracy().is_finite(), "{m:?}");
            let b = run_method(m, SynthKind::Synth10, &data, &cfg).unwrap();
            assert_eq!(
                a.final_accuracy().to_bits(),
                b.final_accuracy().to_bits(),
                "{m:?} must be deterministic under drops"
            );
        }
    }

    #[test]
    fn budget_shrinks_heterofl_rounds_at_high_hi_frac() {
        let mut lo = Scale::Smoke.fed();
        lo.hi_frac = 0.1;
        let mut hi = lo.clone();
        hi.hi_frac = 0.9;
        let b = zowarmup_budget_bytes(&lo, 1000);
        // budget is dominated by warm rounds; equal here, but HeteroFL's
        // per-round cost grows with hi_frac, so rounds shrink.
        assert!(b > 0);
        let _ = hi;
    }

    #[test]
    fn nc_detection() {
        assert_eq!(nc_cell(0.2, 10), None);
        assert_eq!(nc_cell(0.12, 100), None);
        assert!(nc_cell(0.10, 100).is_none());
        assert_eq!(nc_cell(0.012, 100), Some("nc".into()));
        assert_eq!(nc_cell(0.10, 10), Some("nc".into()));
    }
}
