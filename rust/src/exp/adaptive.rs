//! Adaptive-S / variance-guard ablation (`zowarmup exp adaptive`): sweep
//! the tentpole's two knobs — capability-adaptive per-client probe
//! budgets (`--adaptive-s`, DESIGN.md §9) and the aggregation variance
//! guard (`--guard`) — under a heterogeneous fleet and report the
//! accuracy / issued-probe / uplink / effective-variance trade-off.
//!
//! Rows: the uniform-S baseline (the paper's protocol), plain adaptive-S,
//! and adaptive-S with each guard mode. Under a no-deadline fleet the
//! planner sizes every round to the slowest sampled client's uniform-S
//! timeline, so adaptive rows spend the same simulated wall-clock while
//! issuing strictly more probes on the strong tiers — the "free variance
//! reduction" the motivation papers predict (Ling et al. 2024 tie ZO-FL
//! convergence to the per-round perturbation count; Fang et al. 2022 show
//! the uplink stays negligible as probe counts grow).

use crate::config::{Scale, VarianceGuard};
use crate::data::synthetic::SynthKind;
use crate::exp::common::{image_setup, linear_lrs, run_path};
use crate::fed::server::Federation;
use crate::metrics::MdTable;
use crate::model::backend::ModelBackend;
use crate::model::params::ParamVec;
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;

/// The swept (adaptive, guard) modes, with their row labels.
pub const MODES: [(&str, bool, VarianceGuard); 4] = [
    ("uniform", false, VarianceGuard::Off),
    ("adaptive", true, VarianceGuard::Off),
    ("adaptive+invvar", true, VarianceGuard::InvVar),
    ("adaptive+clip", true, VarianceGuard::Clip),
];

pub fn run(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    // the ablation needs capability spread to exist; the binary fleet's
    // two tiers barely differ on the ZO path, so substitute the
    // edge-spectrum preset (and say so — the CLI cannot distinguish an
    // explicit `--scenario binary` from the default).
    let scenario = if *scenario == Scenario::Binary {
        eprintln!(
            "[exp adaptive] binary fleet has no capability spread — \
             substituting the `edge-spectrum` preset (pass a custom \
             --scenario to override)"
        );
        Scenario::preset("edge-spectrum").expect("bundled preset")
    } else {
        scenario.clone()
    };
    let mut out = format!(
        "## Adaptive-S / variance-guard ablation — probes vs variance \
         (fleet: {})\n\n",
        scenario.name()
    );
    let mut t = MdTable::new(&[
        "mode",
        "final acc %",
        "probes issued",
        "probes/round (zo)",
        "up-link KB",
        "mean eff. var",
        "dropped",
        "wall s",
    ]);
    let mut csv = CsvWriter::create(
        run_path("adaptive_ablation.csv"),
        &[
            "mode", "final_acc", "seeds_total", "up_bytes", "down_bytes",
            "mean_eff_var", "dropped", "wall_s",
        ],
    )?;
    for (label, adaptive, guard) in MODES {
        let mut cfg = scale.fed();
        linear_lrs(&mut cfg);
        cfg.scenario = scenario.clone();
        cfg.zo.adaptive_s = adaptive;
        cfg.zo.guard = guard;
        let data = scale.data();
        let s = image_setup(SynthKind::Synth10, &data, &cfg);
        let init = ParamVec::zeros(s.backend.dim());
        let zo_rounds = (cfg.rounds_total - cfg.pivot).max(1);
        let mut fed = Federation::new(cfg, &s.backend, s.shards, s.test, init)?;
        let t0 = std::time::Instant::now();
        fed.run()?;
        let wall = t0.elapsed().as_secs_f64();
        t.row(vec![
            label.to_string(),
            format!("{:.1}", fed.log.final_accuracy() * 100.0),
            fed.ledger.seeds_total.to_string(),
            format!("{:.1}", fed.ledger.seeds_total as f64 / zo_rounds as f64),
            format!("{:.3}", fed.ledger.up_total as f64 / 1e3),
            format!("{:.3e}", fed.log.mean_eff_var()),
            fed.log.total_dropped().to_string(),
            format!("{wall:.2}"),
        ]);
        csv.row(&[
            label.to_string(),
            format!("{:.4}", fed.log.final_accuracy()),
            fed.ledger.seeds_total.to_string(),
            fed.ledger.up_total.to_string(),
            fed.ledger.down_total.to_string(),
            format!("{:.6e}", fed.log.mean_eff_var()),
            fed.log.total_dropped().to_string(),
            format!("{wall:.3}"),
        ])?;
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str(
        "\nExpected shape: adaptive rows issue more probes than uniform \
         at (near-)identical simulated round time — the strong tiers \
         convert idle straggler-wait into extra perturbations — and the \
         effective variance of the aggregated step drops; the guards \
         trade a little probe mass for robustness to noisy clients. \
         Up-link grows only by 4 B per extra probe (Fang et al. 2022: \
         negligible next to any weight transfer).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_ablation_smoke() {
        let md = run(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("| uniform |"));
        assert!(md.contains("| adaptive |"));
        assert!(md.contains("| adaptive+invvar |"));
        assert!(md.contains("| adaptive+clip |"));
        // the uniform and adaptive rows must report different probe
        // totals under the substituted edge-spectrum fleet — the
        // acceptance signal that per-client budgets actually vary
        let probes: Vec<u64> = md
            .lines()
            .filter(|l| l.starts_with("| uniform |") || l.starts_with("| adaptive |"))
            .map(|l| {
                let cells: Vec<&str> = l.split('|').map(str::trim).collect();
                cells[3].parse().unwrap()
            })
            .collect();
        assert_eq!(probes.len(), 2);
        assert!(
            probes[1] > probes[0],
            "adaptive must issue more probes than uniform: {probes:?}"
        );
        // schema drift: the csv's rows match its header arity
        let rows =
            crate::exp::common::check_csv_arity("runs/adaptive_ablation.csv").unwrap();
        assert!(rows > 0, "adaptive_ablation.csv has no data rows");
    }
}
