//! Ablation experiments: Table 3 (local ZO gradient steps), Table 6
//! (Gaussian vs Rademacher variance), Table 7 (mixed vs all-ZO step 2),
//! Figure 6 (τ sweep), Figure 7 (S sweep).

use crate::config::Scale;
use crate::data::synthetic::SynthKind;
use crate::exp::common::{run_method, run_path, Method};
use crate::metrics::{summarize_accuracies, MdTable};
use crate::sim::Scenario;
use crate::util::csv::CsvWriter;
use crate::util::rng::Distribution;
use crate::util::stats;

/// Table 3: more local ZO steps per round hurts; τ must shrink with steps
/// (paper pairs steps {1,2,4,6} with τ {0.75, 0.25, 0.1, 0.01}).
pub fn table3(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let pairs: [(usize, f32); 4] = [(1, 0.75), (2, 0.25), (4, 0.1), (6, 0.01)];
    let splits: [(f64, &str); 3] = [(0.1, "10/90"), (0.5, "50/50"), (0.9, "90/10")];
    let seeds = scale.seeds();
    let mut out =
        String::from("## Table 3 — local ZO gradient steps ablation (accuracy %, mean(std))\n\n");
    let mut t = MdTable::new(&["steps (τ)", "10/90", "50/50", "90/10"]);
    let mut csv = CsvWriter::create(
        run_path("table3.csv"),
        &["steps", "tau", "split", "seed", "final_acc"],
    )?;
    for (steps, tau) in pairs {
        let mut cells = vec![format!("{steps} ({tau})")];
        for (hi_frac, label) in splits {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut cfg = scale.fed();
                cfg.hi_frac = hi_frac;
                cfg.seed = seed as u64;
                cfg.scenario = scenario.clone();
                cfg.zo.grad_steps = steps;
                cfg.zo.tau = tau;
                let data = scale.data();
                let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
                accs.push(log.final_accuracy());
                csv.row(&[
                    steps.to_string(),
                    tau.to_string(),
                    label.to_string(),
                    seed.to_string(),
                    format!("{:.4}", accs.last().unwrap()),
                ])?;
            }
            cells.push(summarize_accuracies(&accs));
        }
        t.row(cells);
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str("\nExpected shape: 1 step best; more steps degrade (client drift × ZO noise).\n");
    Ok(out)
}

/// Table 6 (§A.1): Rademacher vs Gaussian — mean/std of final accuracy and
/// of δ_lo = acc(after ZO) − acc(at pivot), over many seeds.
pub fn table6(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let n_seeds = match scale {
        Scale::Smoke => 4,
        Scale::Default => 8,
        Scale::Paper => 12, // the paper's 12 seeds
    };
    let mut out = String::from("## Table 6 — perturbation distribution variance (§A.1)\n\n");
    let mut t = MdTable::new(&["Distribution", "Acc", "StdDev", "δ_lo", "StdDev(δ)"]);
    let mut csv = CsvWriter::create(
        run_path("table6.csv"),
        &["dist", "seed", "acc_final", "acc_pivot", "delta_lo"],
    )?;
    for (dist, label) in [
        (Distribution::Gaussian, "N(0,1)"),
        (Distribution::Rademacher, "Rademacher"),
    ] {
        let mut accs = Vec::new();
        let mut deltas = Vec::new();
        for seed in 0..n_seeds {
            let mut cfg = scale.fed();
            cfg.hi_frac = 0.1;
            cfg.seed = seed as u64;
            cfg.scenario = scenario.clone();
            cfg.zo.dist = dist;
            let data = scale.data();
            let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
            let curve = log.accuracy_curve();
            let at_pivot = curve
                .iter()
                .filter(|(r, _)| *r < cfg.pivot)
                .map(|(_, a)| *a)
                .last()
                .unwrap_or(0.0);
            let final_acc = log.final_accuracy();
            accs.push(final_acc * 100.0);
            deltas.push((final_acc - at_pivot) * 100.0);
            csv.row(&[
                label.to_string(),
                seed.to_string(),
                format!("{final_acc:.4}"),
                format!("{at_pivot:.4}"),
                format!("{:.4}", final_acc - at_pivot),
            ])?;
        }
        t.row(vec![
            label.to_string(),
            format!("{:.1}", stats::mean(&accs)),
            format!("{:.1}", stats::std_dev(&accs)),
            format!("{:.1}", stats::mean(&deltas)),
            format!("{:.1}", stats::std_dev(&deltas)),
        ]);
    }
    csv.flush()?;
    out.push_str(&t.render());
    out.push_str("\nExpected shape: Rademacher has lower variance and better accuracy.\n");
    Ok(out)
}

/// Table 7 (§A.4): all-ZO step 2 vs letting high-res clients continue FO.
pub fn table7(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let splits: [(f64, &str); 3] = [(0.1, "10/90"), (0.5, "50/50"), (0.9, "90/10")];
    let seeds = scale.seeds();
    let mut out = String::from("## Table 7 — combining high & low resource updates (§A.4)\n\n");
    let mut t = MdTable::new(&["Method", "10/90", "50/50", "90/10"]);
    for (method, label) in [
        (Method::ZoWarmupMixed, "ZOWarmUp (hi+lo)"),
        (Method::ZoWarmup, "ZOWarmUp (lo only)"),
    ] {
        let mut cells = vec![label.to_string()];
        for (hi_frac, _lab) in splits {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut cfg = scale.fed();
                cfg.hi_frac = hi_frac;
                cfg.seed = seed as u64;
                cfg.scenario = scenario.clone();
                let data = scale.data();
                let log = run_method(method, SynthKind::Synth10, &data, &cfg)?;
                accs.push(log.final_accuracy());
            }
            cells.push(summarize_accuracies(&accs));
        }
        t.row(cells);
    }
    out.push_str(&t.render());
    out.push_str("\nExpected shape: enforcing ZO for everyone in step 2 does better.\n");
    Ok(out)
}

/// Figure 6 (§A.2): final accuracy as a function of τ for both
/// distributions.
pub fn fig6(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let taus = [0.75f32, 0.5, 0.25, 0.1];
    let seeds = scale.seeds();
    let mut out = String::from("## Figure 6 — accuracy vs τ (§A.2)\n\n");
    let mut t = MdTable::new(&["τ", "Rademacher", "Gaussian"]);
    let mut csv = CsvWriter::create(
        run_path("fig6.csv"),
        &["tau", "dist", "seed", "final_acc"],
    )?;
    for tau in taus {
        let mut cells = vec![format!("{tau}")];
        for dist in [Distribution::Rademacher, Distribution::Gaussian] {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut cfg = scale.fed();
                cfg.hi_frac = 0.1;
                cfg.seed = seed as u64;
                cfg.scenario = scenario.clone();
                cfg.zo.tau = tau;
                cfg.zo.dist = dist;
                let data = scale.data();
                let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
                accs.push(log.final_accuracy());
                csv.row(&[
                    tau.to_string(),
                    format!("{dist:?}"),
                    seed.to_string(),
                    format!("{:.4}", accs.last().unwrap()),
                ])?;
            }
            cells.push(summarize_accuracies(&accs));
        }
        t.row(cells);
    }
    csv.flush()?;
    out.push_str(&t.render());
    Ok(out)
}

/// Figure 7 (§A.2): variance across seeds shrinks as S grows.
pub fn fig7(scale: Scale, scenario: &Scenario) -> anyhow::Result<String> {
    let s_values = [1usize, 3, 9];
    let n_seeds = scale.seeds().max(3);
    let mut out = String::from("## Figure 7 — variance vs S (§A.2)\n\n");
    let mut t = MdTable::new(&["S", "mean acc %", "std over seeds", "per-seed accs"]);
    for s in s_values {
        let mut accs = Vec::new();
        for seed in 0..n_seeds {
            let mut cfg = scale.fed();
            cfg.hi_frac = 0.1;
            cfg.seed = seed as u64;
            cfg.scenario = scenario.clone();
            cfg.zo.s_seeds = s;
            let data = scale.data();
            let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
            accs.push(log.final_accuracy() * 100.0);
        }
        t.row(vec![
            s.to_string(),
            format!("{:.1}", stats::mean(&accs)),
            format!("{:.2}", stats::std_dev(&accs)),
            format!("{accs:.1?}"),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("\nExpected shape: higher S -> higher mean, lower spread, diminishing returns.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_smoke() {
        let md = table3(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("1 (0.75)"));
        assert!(md.contains("6 (0.01)"));
    }

    #[test]
    fn table6_smoke() {
        let md = table6(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("Rademacher"));
        assert!(md.contains("N(0,1)"));
    }

    #[test]
    fn table7_smoke() {
        let md = table7(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("hi+lo"));
        assert!(md.contains("lo only"));
    }

    #[test]
    fn fig7_smoke() {
        let md = fig7(Scale::Smoke, &Scenario::default()).unwrap();
        assert!(md.contains("| 1 |"));
        assert!(md.contains("| 9 |"));
    }
}
