//! Table 1: per-client, per-round communication & memory — FedAvg vs ZO.
//!
//! Reproduced two ways: (a) the paper's analytic model at the true
//! ResNet18 sizes, (b) the same model at our manifest sizes plus bytes
//! *measured* from a live smoke federation (the ledger), proving the
//! simulator transmits what the formulas promise.

use crate::comm::{mb, CostModel};
use crate::config::Scale;
use crate::data::synthetic::SynthKind;
use crate::exp::common::{run_method, Method};
use crate::metrics::{MdTable, Phase};
use crate::model::manifest::Manifest;
use crate::sim::Scenario;

pub fn run(scale: Scale, artifacts_dir: &str, scenario: &Scenario) -> anyhow::Result<String> {
    let mut out = String::from("## Table 1 — communication & memory per client per round\n\n");

    // (a) the paper's setting: ResNet18, S=3, K=10 sampled clients
    let paper = CostModel::paper_resnet18();
    let (s, k) = (3u64, 10u64);
    let mut t = MdTable::new(&[
        "Method",
        "Up-link (MB/client)",
        "Down-link (MB/client)",
        "On-device Mem (MB/client)",
    ]);
    t.row(vec![
        "FedAvg".into(),
        format!("{:.1}", mb(paper.fedavg_uplink_bytes())),
        format!("{:.1}", mb(paper.fedavg_downlink_bytes())),
        format!("{:.1}", mb(paper.backprop_mem_bytes())),
    ]);
    t.row(vec![
        "Zeroth-order FL".into(),
        format!("{:.1e}", mb(paper.zo_uplink_bytes(s))),
        format!("{:.1e}", mb(paper.zo_downlink_bytes_paper(s, k))),
        format!("{:.1}", mb(paper.zo_mem_bytes_paper())),
    ]);
    out.push_str("Analytic, at the paper's ResNet18 (11.17M params, S=3, K=10):\n\n");
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nMemory savings ratio: {:.1}x (paper: ~6x)\n\n",
        paper.backprop_mem_bytes() as f64 / paper.zo_mem_bytes_paper() as f64
    ));

    // (b) at our model sizes, if artifacts exist
    if let Ok(manifest) = Manifest::load(artifacts_dir) {
        let mut t2 = MdTable::new(&[
            "Model",
            "FedAvg up (MB)",
            "ZO up (MB)",
            "Backprop mem (MB)",
            "ZO mem (MB)",
            "Ratio",
        ]);
        for (name, entry) in &manifest.models {
            let m = CostModel::from_manifest(entry);
            t2.row(vec![
                name.clone(),
                format!("{:.3}", mb(m.fedavg_uplink_bytes())),
                format!("{:.1e}", mb(m.zo_uplink_bytes(s))),
                format!("{:.2}", mb(m.backprop_mem_bytes())),
                format!("{:.2}", mb(m.zo_mem_bytes())),
                format!("{:.1}x", m.mem_savings_ratio()),
            ]);
        }
        out.push_str("Analytic, at this repo's manifest sizes:\n\n");
        out.push_str(&t2.render());
        out.push('\n');
    }

    // (c) measured: a live federation's ledger
    let mut cfg = scale.fed();
    cfg.scenario = scenario.clone();
    let data = scale.data();
    let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)?;
    let warm_up_max = log
        .rounds
        .iter()
        .filter(|r| r.phase == Phase::Warm)
        .map(|r| r.bytes_up)
        .max()
        .unwrap_or(0);
    let zo_up_max = log
        .rounds
        .iter()
        .filter(|r| r.phase == Phase::Zo)
        .map(|r| r.bytes_up)
        .max()
        .unwrap_or(0);
    out.push_str(&format!(
        "Measured (live run, linear probe, per round all participants): \
         warm up-link {} B vs ZO up-link {} B -> {:.0}x reduction\n",
        warm_up_max,
        zo_up_max,
        warm_up_max as f64 / zo_up_max.max(1) as f64
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_with_and_without_artifacts() {
        let md = run(Scale::Smoke, "/nonexistent", &Scenario::default()).unwrap();
        assert!(md.contains("FedAvg"));
        assert!(md.contains("Zeroth-order FL"));
        assert!(md.contains("44.7"));
        assert!(md.contains("89.4"));
        assert!(md.contains("reduction"));
        // schema drift for the CSV-less runner: every rendered markdown
        // table row carries the 4-column header's cell count
        for line in md.lines().filter(|l| l.starts_with('|') && !l.starts_with("|-")) {
            assert_eq!(
                line.matches('|').count(),
                5,
                "table row drifted from the 4-column header: {line}"
            );
        }
    }
}
