//! HeteroFL baseline (Diao et al., 2020): width-scaled sub-networks.
//!
//! High-resource clients train the full-width model; low-resource clients
//! train a half-width sub-network whose tensors are the *leading slices*
//! (first channels) of the full tensors. The server aggregates
//! position-wise: coordinates covered by both populations average over
//! all updates, full-only coordinates average over high-resource updates.
//!
//! The slice correspondence is derived mechanically from the paired
//! manifests (`cnn10` / `cnn10_half` share tensor names; every half dim ≤
//! full dim), so it works unchanged for any architecture pair.
//!
//! **Capability adaptation:** HeteroFL adapts the *model width* to device
//! capability; ZOWarmUp's `--adaptive-s` (DESIGN.md §9) adapts the
//! *probe count* instead, keeping every client on the full model. The
//! two are the natural cross-method comparison for the adaptive
//! ablation (`zowarmup exp adaptive`); this baseline runs no seed
//! protocol, so its per-round `seeds_issued` / `eff_var` columns are 0.

use crate::comm::{CommLedger, CostModel};
use crate::config::FedConfig;
use crate::data::loader::{eval_chunks, ClientData, Source};
use crate::fed::client::{clients_from_profiles, round_client_rng, warm_local_train, Resource};
use crate::fed::population::Population;
use crate::metrics::{Phase, RoundRecord, RunLog};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::manifest::ModelEntry;
use crate::model::params::ParamVec;
use crate::sim;
use crate::util::pool::{parallel_map_n, resolve_workers};
use crate::util::rng::Xoshiro256;

/// Index map from the half-width flat vector into the full flat vector.
#[derive(Debug, Clone)]
pub struct SliceMap {
    /// map[i] = full-vector position of half-vector element i
    pub map: Vec<u32>,
    pub full_dim: usize,
}

impl SliceMap {
    /// Build from paired (full, half) tensor shape lists with offsets.
    /// Each half shape must be a leading sub-block of its full shape.
    pub fn from_shape_pairs(
        pairs: &[(Vec<usize>, usize, Vec<usize>, usize)], // (full_shape, full_off, half_shape, half_off)
        full_dim: usize,
        half_dim: usize,
    ) -> anyhow::Result<Self> {
        let mut map = vec![u32::MAX; half_dim];
        for (full_shape, full_off, half_shape, half_off) in pairs {
            anyhow::ensure!(
                full_shape.len() == half_shape.len(),
                "rank mismatch {full_shape:?} vs {half_shape:?}"
            );
            for (f, h) in full_shape.iter().zip(half_shape) {
                anyhow::ensure!(h <= f, "half dim {h} > full dim {f}");
            }
            // iterate all half coordinates (row-major)
            let hsize: usize = half_shape.iter().product();
            let mut coords = vec![0usize; half_shape.len()];
            for hi in 0..hsize {
                // ravel coords into the full shape
                let mut fi = 0usize;
                for (d, &c) in coords.iter().enumerate() {
                    fi = fi * full_shape[d] + c;
                }
                let slot = half_off + hi;
                anyhow::ensure!(map[slot] == u32::MAX, "overlapping half tensors");
                map[slot] = (full_off + fi) as u32;
                // increment coords
                for d in (0..coords.len()).rev() {
                    coords[d] += 1;
                    if coords[d] < half_shape[d] {
                        break;
                    }
                    coords[d] = 0;
                }
            }
        }
        anyhow::ensure!(
            map.iter().all(|&m| m != u32::MAX),
            "unmapped half positions"
        );
        Ok(Self {
            map,
            full_dim,
        })
    }

    /// Derive from paired manifests (same tensor names, smaller shapes).
    pub fn from_manifest_pair(full: &ModelEntry, half: &ModelEntry) -> anyhow::Result<Self> {
        let mut pairs = Vec::new();
        for ht in &half.params {
            let ft = full
                .tensor(&ht.name)
                .ok_or_else(|| anyhow::anyhow!("tensor {} missing in full model", ht.name))?;
            pairs.push((ft.shape.clone(), ft.offset, ht.shape.clone(), ht.offset));
        }
        Self::from_shape_pairs(&pairs, full.dim, half.dim)
    }

    pub fn half_dim(&self) -> usize {
        self.map.len()
    }

    /// Extract the half-width parameters from the full vector.
    pub fn slice(&self, full: &ParamVec) -> ParamVec {
        assert_eq!(full.dim(), self.full_dim);
        ParamVec(self.map.iter().map(|&i| full.0[i as usize]).collect())
    }
}

/// HeteroFL position-wise aggregation.
pub fn heterofl_aggregate(
    global: &mut ParamVec,
    full_updates: &[(ParamVec, f64)],
    half_updates: &[(ParamVec, f64)],
    map: &SliceMap,
) {
    let dim = global.dim();
    let mut sum = vec![0.0f64; dim];
    let mut weight = vec![0.0f64; dim];
    for (p, w) in full_updates {
        for i in 0..dim {
            sum[i] += *w * p.0[i] as f64;
            weight[i] += *w;
        }
    }
    for (p, w) in half_updates {
        for (hi, &fi) in map.map.iter().enumerate() {
            sum[fi as usize] += *w * p.0[hi] as f64;
            weight[fi as usize] += *w;
        }
    }
    for i in 0..dim {
        if weight[i] > 0.0 {
            global.0[i] = (sum[i] / weight[i]) as f32;
        }
    }
}

/// One full HeteroFL training run.
pub struct HeteroFlRun<'a, BF: ModelBackend, BH: ModelBackend> {
    pub cfg: FedConfig,
    pub full: &'a BF,
    pub half: &'a BH,
    pub map: SliceMap,
    /// the client population (materialized or lazy — `fed::population`)
    pub pop: Population,
    pub test: Source,
    pub global: ParamVec,
    pub log: RunLog,
    pub ledger: CommLedger,
    /// the FULL model's cost profile: a client trains full-width iff its
    /// capability profile covers the full model's backprop footprint
    /// (HeteroFL's premise is that the half net fits everyone else)
    pub cost: CostModel,
    rng: Xoshiro256,
}

impl<'a, BF: ModelBackend, BH: ModelBackend> HeteroFlRun<'a, BF, BH> {
    pub fn new(
        cfg: FedConfig,
        full: &'a BF,
        half: &'a BH,
        map: SliceMap,
        shards: Vec<ClientData>,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(shards.len() == cfg.clients, "shard count != clients");
        let cost = full.cost_model();
        let profiles = cfg
            .scenario
            .sample_profiles(cfg.clients, cfg.hi_count(), cfg.seed, &cost);
        let clients = clients_from_profiles(shards, profiles, &cost);
        Self::with_population(cfg, full, half, map, Population::materialized(clients), test, init)
    }

    /// Fleet-scale constructor: lazy per-client derivation over a shared
    /// source (see `fed::population`).
    pub fn new_lazy(
        cfg: FedConfig,
        full: &'a BF,
        half: &'a BH,
        map: SliceMap,
        source: Source,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let cost = full.cost_model();
        let pop = Population::lazy(
            cfg.clients,
            cfg.hi_count(),
            cfg.seed,
            cfg.scenario.clone(),
            cost,
            source,
        )?;
        Self::with_population(cfg, full, half, map, pop, test, init)
    }

    pub fn with_population(
        cfg: FedConfig,
        full: &'a BF,
        half: &'a BH,
        map: SliceMap,
        pop: Population,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(pop.len() == cfg.clients, "population size != clients");
        anyhow::ensure!(map.full_dim == full.dim(), "map/full dim");
        anyhow::ensure!(map.half_dim() == half.dim(), "map/half dim");
        let cost = full.cost_model();
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0x8E7E_0F1);
        Ok(Self {
            cfg,
            full,
            half,
            map,
            pop,
            test,
            global: init,
            log: RunLog::default(),
            ledger: CommLedger::default(),
            cost,
            rng,
        })
    }

    pub fn eval(&self) -> anyhow::Result<LossSums> {
        let mut sums = LossSums::default();
        for b in eval_chunks(&self.test, self.full.batch_size()) {
            sums.add(self.full.fwd_loss(&self.global, &b)?);
        }
        Ok(sums)
    }

    /// One round: sample from *all* clients; clients whose capability
    /// profile covers the full model's backprop footprint train the full
    /// net, the rest train the half slice; aggregate position-wise.
    /// Clients run in parallel with pre-derived RNGs and an
    /// order-canonical fold, so results are bit-identical for every
    /// worker count (see `fed::server`'s threading model). Capability
    /// timelines are simulated first: deadline misses and availability
    /// failures drop out mid-round with partial byte charges.
    pub fn round(&mut self, round: usize) -> anyhow::Result<crate::fed::server::RoundSummary> {
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients);
        let picked = self.rng.choose(self.cfg.clients, q);

        enum Out {
            Full(ParamVec, f64, LossSums),
            Half(ParamVec, f64, LossSums),
        }
        let deadline = self.cfg.scenario.deadline_ms();
        let mut jobs: Vec<(usize, Resource, ClientData, Xoshiro256)> = Vec::with_capacity(q);
        let (mut up, mut down) = (0u64, 0u64);
        let mut dropped = 0usize;
        for &cid in &picked {
            let profile = self.pop.profile(cid);
            if !sim::is_available(&profile, self.cfg.seed, round, cid) {
                dropped += 1;
                continue;
            }
            // derive the class from the profile already in hand (the
            // lazy path would otherwise re-derive the whole profile)
            let resource = if profile.fo_capable(&self.cost) {
                Resource::High
            } else {
                Resource::Low
            };
            let (dim, params) = match resource {
                Resource::High => (self.full.dim(), self.cost.params),
                Resource::Low => (self.half.dim(), self.half.cost_model().params),
            };
            let d4 = (dim * 4) as u64;
            let plan = sim::RoundPlan {
                down_bytes: d4,
                passes: sim::fo_passes(self.pop.n_samples(cid), self.cfg.local_epochs),
                up_bytes: d4,
            };
            let mut trace = round_client_rng(self.cfg.seed, sim::SIM_SALT, round, cid);
            let o = sim::simulate_round(&profile, &plan, params, deadline, &mut trace);
            up += o.up_bytes;
            down += o.down_bytes;
            if o.survives {
                jobs.push((
                    cid,
                    resource,
                    self.pop.data(cid),
                    round_client_rng(self.cfg.seed, 0, round, cid),
                ));
            } else {
                dropped += 1;
            }
        }
        let results = {
            let full = self.full;
            let half = self.half;
            let global = &self.global;
            let map = &self.map;
            let cfg = &self.cfg;
            parallel_map_n(
                resolve_workers(self.cfg.threads),
                jobs,
                move |(_cid, resource, data, mut crng)| -> anyhow::Result<Out> {
                    match resource {
                        Resource::High => {
                            let (w, sums) =
                                warm_local_train(full, global, &data, cfg, &mut crng)?;
                            Ok(Out::Full(w, data.n() as f64, sums))
                        }
                        Resource::Low => {
                            let sub = map.slice(global);
                            let (w, sums) =
                                warm_local_train(half, &sub, &data, cfg, &mut crng)?;
                            Ok(Out::Half(w, data.n() as f64, sums))
                        }
                    }
                },
            )
        };

        let mut full_updates = Vec::new();
        let mut half_updates = Vec::new();
        let mut train = LossSums::default();
        for r in results {
            match r? {
                Out::Full(w, n, sums) => {
                    train.add(sums);
                    full_updates.push((w, n));
                }
                Out::Half(w, n, sums) => {
                    train.add(sums);
                    half_updates.push((w, n));
                }
            }
        }
        // position-wise aggregation over survivors only; an all-drop
        // round leaves every coordinate's weight at zero → global intact
        heterofl_aggregate(&mut self.global, &full_updates, &half_updates, &self.map);
        self.ledger.record_round(up, down);
        Ok(crate::fed::server::RoundSummary {
            train_signal: crate::fed::server::finite_signal(train.mean_loss()),
            dropped,
            catch_up_down: 0,
            // width slicing, not probe counts, is this baseline's
            // capability adaptation — the seeds_issued / eff_var columns
            // stay 0 (see the module docs)
            seeds_issued: 0,
            eff_var: 0.0,
            // barrier protocol, no event engine: the async columns stay 0
            staleness: 0.0,
            makespan_ms: 0.0,
            // flat topology: baselines never model edge aggregators
            edge_drops: 0,
        })
    }

    pub fn run(&mut self) -> anyhow::Result<()> {
        for round in 0..self.cfg.rounds_total {
            let t0 = std::time::Instant::now();
            let summary = self.round(round)?;
            let do_eval =
                round % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds_total;
            let (test_acc, test_loss) = if do_eval {
                let e = self.eval()?;
                (e.accuracy(), e.mean_loss())
            } else {
                (f64::NAN, f64::NAN)
            };
            let (up, down) = *self.ledger.per_round.last().unwrap();
            self.log.push(RoundRecord {
                round,
                phase: Phase::Warm,
                train_loss: summary.train_signal,
                test_acc,
                test_loss,
                bytes_up: up,
                bytes_down: down,
                dropped: summary.dropped,
                catch_up_down: summary.catch_up_down,
                seeds_issued: summary.seeds_issued,
                eff_var: summary.eff_var,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                staleness: summary.staleness,
                model_version: 0,
                makespan_ms: summary.makespan_ms,
                edge_drops: summary.edge_drops,
            });
        }
        Ok(())
    }

    /// Per-round average communication bytes (for the paper's fixed
    /// communication budget: rounds = budget / per_round).
    pub fn per_round_bytes(&self) -> u64 {
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients) as u64;
        // the full-width share is profile-derived (not cfg.hi_count():
        // custom scenarios draw their own fleet mix); lazy populations
        // use the tier draw mass instead of an O(N) scan
        let hi_share = self.pop.fo_share(&self.cost);
        let per_client = hi_share * (self.full.dim() * 4) as f64
            + (1.0 - hi_share) * (self.half.dim() * 4) as f64;
        (q as f64 * per_client * 2.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::backend::LinearBackend;

    /// Linear-probe slice pair: half keeps the first F/2 features.
    pub(crate) fn linear_slice_map(classes: usize, features: usize) -> SliceMap {
        let fh = features / 2;
        SliceMap::from_shape_pairs(
            &[
                (vec![classes, features], 0, vec![classes, fh], 0),
                (
                    vec![classes],
                    classes * features,
                    vec![classes],
                    classes * fh,
                ),
            ],
            classes * features + classes,
            classes * fh + classes,
        )
        .unwrap()
    }

    #[test]
    fn slice_map_linear_layout() {
        let m = linear_slice_map(2, 4);
        assert_eq!(m.half_dim(), 6);
        // class 0 row: full 0..2; class 1 row: full 4..6; biases full 8,9
        assert_eq!(m.map, vec![0, 1, 4, 5, 8, 9]);
        let full = ParamVec((0..10).map(|i| i as f32).collect());
        let half = m.slice(&full);
        assert_eq!(half.0, vec![0.0, 1.0, 4.0, 5.0, 8.0, 9.0]);
    }

    #[test]
    fn slice_map_conv_like() {
        // conv [2,2,3,4] -> [2,2,2,2]: kernel dims kept, channels halved
        let full_shape = vec![2, 2, 3, 4];
        let half_shape = vec![2, 2, 2, 2];
        let m = SliceMap::from_shape_pairs(
            &[(full_shape.clone(), 0, half_shape.clone(), 0)],
            48,
            16,
        )
        .unwrap();
        // half coord (1,1,1,1) -> full flat ((1*2+1)*3+1)*4+1 = 41
        assert_eq!(*m.map.last().unwrap(), 41);
    }

    #[test]
    fn aggregate_full_only_positions_keep_full_average() {
        let m = linear_slice_map(1, 4); // full dim 5, half keeps feats 0,1 + bias
        let mut global = ParamVec(vec![0.0; 5]);
        let full_up = vec![(ParamVec(vec![1.0; 5]), 1.0)];
        let half_up = vec![(ParamVec(vec![3.0, 3.0, 3.0]), 1.0)];
        heterofl_aggregate(&mut global, &full_up, &half_up, &m);
        // positions 0,1 (shared): avg(1,3)=2 ; positions 2,3 (full only): 1
        assert_eq!(global.0, vec![2.0, 2.0, 1.0, 1.0, 2.0]);
    }

    #[test]
    fn aggregate_half_only_population() {
        let m = linear_slice_map(1, 4);
        let mut global = ParamVec(vec![9.0; 5]);
        heterofl_aggregate(
            &mut global,
            &[],
            &[(ParamVec(vec![1.0, 2.0, 3.0]), 2.0)],
            &m,
        );
        // uncovered full-only positions keep the old value
        assert_eq!(global.0, vec![1.0, 2.0, 9.0, 9.0, 3.0]);
    }

    #[test]
    fn lazy_population_heterofl_constructs_and_rounds() {
        use crate::data::loader::Source;
        use crate::data::synthetic::{train_test, SynthKind};
        use std::sync::Arc;

        // the fleet-scale constructor: lazy profiles decide full-vs-half
        // width per sampled client, rounds run deterministically
        let f = 32 * 32 * 3;
        let full = LinearBackend::new(f, 10, 32);
        let half = LinearBackend::sliced(&full, f / 2);
        let map = linear_slice_map(10, f);
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.clients = 512;
        cfg.rounds_total = 2;
        cfg.population = crate::config::PopulationMode::Lazy;
        cfg.scenario = crate::sim::Scenario::preset("fleet").unwrap();
        let (train, test) = train_test(SynthKind::Synth10, 300, 100, cfg.seed);
        let run = HeteroFlRun::new_lazy(
            cfg,
            &full,
            &half,
            map,
            Source::Image(Arc::new(train)),
            Source::Image(Arc::new(test)),
            ParamVec::zeros(full.dim()),
        );
        let mut run = run.unwrap();
        // per-round budgeting uses the tier draw mass in lazy mode
        assert!(run.per_round_bytes() > 0);
        let s1 = run.round(0).unwrap();
        let s2 = run.round(1).unwrap();
        assert!(run.global.is_finite());
        assert!(s1.train_signal.is_finite() && s2.train_signal.is_finite());
    }

    #[test]
    fn heterofl_run_learns() {
        use crate::data::dirichlet::dirichlet_split;
        use crate::data::synthetic::{train_test, SynthKind};
        use crate::fed::server::shards_from_partition;
        use std::sync::Arc;

        let mut cfg = FedConfig::default().smoke_scale();
        cfg.lr_client_warm = 0.02;
        let f = 32 * 32 * 3;
        let full = LinearBackend::new(f, 10, 32);
        let half = LinearBackend::sliced(&full, f / 2);
        // half model sees only the first half of the features: the shard
        // batches carry full features, so the half backend needs its own
        // view. For the test we slice features by constructing half batches
        // — covered in exp/table2; here we exercise mechanics with full
        // feature dim for both (map = identity-prefix).
        let map = linear_slice_map(10, f);
        assert_eq!(map.half_dim(), half.dim());
        let (train, test) = train_test(SynthKind::Synth10, 300, 100, 0);
        let part = dirichlet_split(&train, cfg.clients, 0.5, 0);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        let init = ParamVec::zeros(full.dim());
        let run = HeteroFlRun::new(
            cfg,
            &full,
            &half,
            map,
            shards,
            Source::Image(Arc::new(test)),
            init,
        );
        // LinearBackend::fwd_loss on half batches would need feature
        // slicing — the image half-backend path is exercised against the
        // XLA cnn_half in integration tests. Here assert construction works.
        assert!(run.is_ok());
    }
}
