//! FedKSeed baseline (Qin et al., 2024): zeroth-order FL over a *finite*
//! candidate seed pool.
//!
//! Differences from ZOWarmUp's method (§2.3, §4.2):
//! * a fixed pool of `pool_size` candidate seeds is fixed at start; clients
//!   pick seeds from the pool rather than receiving fresh per-round seeds;
//! * clients take `local_steps` sequential ZO-SGD steps per round, each on
//!   a fresh minibatch (the paper's FedKSeed uses 200); the 1-step variant
//!   at equal data is our Figure 5 / §4.2 modification;
//! * clients upload the (pool_index, scalar-gradient) history; the server
//!   replays it into the global weights (communication stays seed-sized).
//!
//! Run cold (`pivot = 0`) it reproduces Table 2's "nc" rows; run as the
//! step-2 method after a warm start it is "ZOWarmUp + FedKSeed".
//!
//! **Probe budgeting:** FedKSeed's candidate pool and `local_steps` are
//! uniform across clients by construction — the capability-adaptive
//! per-client probe budgets of `--adaptive-s` (DESIGN.md §9) apply only
//! to ZOWarmUp's fresh-seed protocol, where the server controls each
//! client's per-round seed block. This baseline therefore always runs
//! uniform budgets and logs `seeds_issued = 0` / `eff_var = 0` in the
//! per-round CSV columns.

use std::time::Instant;

use crate::comm::{CommLedger, CostModel};
use crate::config::FedConfig;
use crate::data::loader::{eval_chunks, ClientData, Source};
use crate::fed::aggregate::{weighted_average, ServerOptState};
use crate::fed::client::{clients_from_profiles, round_client_rng, warm_local_train};
use crate::fed::population::Population;
use crate::fed::server::{finite_signal, RoundSummary};
use crate::metrics::{Phase, RoundRecord, RunLog};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::params::ParamVec;
use crate::sim;
use crate::util::pool::{parallel_map_n, resolve_workers};
use crate::util::rng::Xoshiro256;

/// FedKSeed-specific knobs.
#[derive(Debug, Clone, Copy)]
pub struct KSeedConfig {
    /// candidate pool size (paper: K in the thousands)
    pub pool_size: usize,
    /// local ZO-SGD steps per client per round (200 in Qin et al.)
    pub local_steps: usize,
    /// minibatch size per local step; the 1-step variant uses the whole
    /// shard in one step (equal data per round, §4.2)
    pub step_batch: usize,
}

impl Default for KSeedConfig {
    fn default() -> Self {
        Self {
            pool_size: 1024,
            local_steps: 200,
            step_batch: 8,
        }
    }
}

/// One client's uploaded history entry: which pool seed, what scalar.
#[derive(Debug, Clone, Copy)]
pub struct SeedGrad {
    pub pool_idx: u32,
    /// ΔL/(2ε), mean-normalized
    pub ghat: f64,
}

/// Client-side FedKSeed local training: `local_steps` sequential ZO steps,
/// each on a minibatch, updating the local weights immediately.
pub fn kseed_local<B: ModelBackend>(
    backend: &B,
    global: &ParamVec,
    data: &ClientData,
    pool: &[u64],
    ks: &KSeedConfig,
    zo: &crate::config::ZoConfig,
    lr_client: f32,
    rng: &mut Xoshiro256,
) -> anyhow::Result<Vec<SeedGrad>> {
    let mut w = global.clone();
    let mut history = Vec::with_capacity(ks.local_steps);
    for _ in 0..ks.local_steps {
        // 1-step variant at step_batch >= shard size takes the whole shard
        // in one padded batch (equal data per round, §4.2).
        let batch = data.minibatch(ks.step_batch, backend.batch_size(), rng);
        let pool_idx = rng.below(pool.len()) as u32;
        let seed = pool[pool_idx as usize];
        let dl = backend.zo_delta(&w, &batch, seed, zo.eps, zo.tau, zo.dist)?;
        let count = batch.real_count().max(1.0);
        let ghat = dl / count / (2.0 * zo.eps as f64);
        w.perturb_axpy(seed, zo.tau, zo.dist, (-(lr_client as f64) * ghat) as f32);
        history.push(SeedGrad { pool_idx, ghat });
    }
    Ok(history)
}

/// Replay a client history into weights (server side and, in a real
/// deployment, every other client).
pub fn replay(
    w: &mut ParamVec,
    pool: &[u64],
    history: &[SeedGrad],
    zo: &crate::config::ZoConfig,
    lr: f32,
    weight: f64,
) {
    for h in history {
        let coeff = -(lr as f64) * weight * h.ghat;
        w.perturb_axpy(pool[h.pool_idx as usize], zo.tau, zo.dist, coeff as f32);
    }
}

/// A full FedKSeed (or warm-started FedKSeed) training run.
pub struct FedKSeedRun<'a, B: ModelBackend> {
    pub cfg: FedConfig,
    pub ks: KSeedConfig,
    pub backend: &'a B,
    /// the client population (materialized or lazy — `fed::population`)
    pub pop: Population,
    pub test: Source,
    pub global: ParamVec,
    pub pool: Vec<u64>,
    pub log: RunLog,
    pub ledger: CommLedger,
    /// capability thresholds / timing profile (sim scenario engine)
    pub cost: CostModel,
    server_opt: ServerOptState,
    rng: Xoshiro256,
}

impl<'a, B: ModelBackend> FedKSeedRun<'a, B> {
    pub fn new(
        cfg: FedConfig,
        ks: KSeedConfig,
        backend: &'a B,
        shards: Vec<ClientData>,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(shards.len() == cfg.clients, "shard count != clients");
        let cost = backend.cost_model();
        let profiles = cfg
            .scenario
            .sample_profiles(cfg.clients, cfg.hi_count(), cfg.seed, &cost);
        let clients = clients_from_profiles(shards, profiles, &cost);
        Self::with_population(cfg, ks, backend, Population::materialized(clients), test, init)
    }

    /// Fleet-scale constructor: lazy per-client derivation over a shared
    /// source (see `fed::population`).
    pub fn new_lazy(
        cfg: FedConfig,
        ks: KSeedConfig,
        backend: &'a B,
        source: Source,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let cost = backend.cost_model();
        let pop = Population::lazy(
            cfg.clients,
            cfg.hi_count(),
            cfg.seed,
            cfg.scenario.clone(),
            cost,
            source,
        )?;
        Self::with_population(cfg, ks, backend, pop, test, init)
    }

    pub fn with_population(
        cfg: FedConfig,
        ks: KSeedConfig,
        backend: &'a B,
        pop: Population,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(pop.len() == cfg.clients, "population size != clients");
        anyhow::ensure!(ks.pool_size > 0 && ks.local_steps > 0, "bad KSeedConfig");
        let cost = backend.cost_model();
        let mut pool_rng = Xoshiro256::seed_from(cfg.seed ^ 0x4B_5EED);
        let pool: Vec<u64> = (0..ks.pool_size).map(|_| pool_rng.next_u64()).collect();
        let server_opt = ServerOptState::new(cfg.server_opt, backend.dim());
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xFEDC_5EED);
        Ok(Self {
            cfg,
            ks,
            backend,
            pop,
            test,
            global: init,
            pool,
            log: RunLog::default(),
            ledger: CommLedger::default(),
            cost,
            server_opt,
            rng,
        })
    }

    pub fn eval(&self) -> anyhow::Result<LossSums> {
        let mut sums = LossSums::default();
        for b in eval_chunks(&self.test, self.backend.batch_size()) {
            sums.add(self.backend.fwd_loss(&self.global, &b)?);
        }
        Ok(sums)
    }

    fn warm_round(&mut self, round: usize) -> anyhow::Result<RoundSummary> {
        let picked = self
            .pop
            .sample_high(&mut self.rng, self.cfg.sample_warm, &self.cost)?;
        // simulate capability timelines, then fan survivors out with
        // pre-derived RNGs and shards; fold back in sampled order (see
        // fed::server's threading model)
        let deadline = self.cfg.scenario.deadline_ms();
        let d4 = (self.backend.dim() * 4) as u64;
        let mut jobs: Vec<(usize, ClientData, Xoshiro256)> = Vec::with_capacity(picked.len());
        let (mut up, mut down) = (0u64, 0u64);
        let mut dropped = 0usize;
        for &cid in &picked {
            let profile = self.pop.profile(cid);
            if !sim::is_available(&profile, self.cfg.seed, round, cid) {
                dropped += 1;
                continue;
            }
            let plan = sim::RoundPlan {
                down_bytes: d4,
                passes: sim::fo_passes(self.pop.n_samples(cid), self.cfg.local_epochs),
                up_bytes: d4,
            };
            let mut trace = round_client_rng(self.cfg.seed, sim::SIM_SALT, round, cid);
            let o = sim::simulate_round(&profile, &plan, self.cost.params, deadline, &mut trace);
            up += o.up_bytes;
            down += o.down_bytes;
            if o.survives {
                jobs.push((
                    cid,
                    self.pop.data(cid),
                    round_client_rng(self.cfg.seed, 0, round, cid),
                ));
            } else {
                dropped += 1;
            }
        }
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let cfg = &self.cfg;
            parallel_map_n(
                resolve_workers(self.cfg.threads),
                jobs,
                move |(cid, data, mut crng)| {
                    warm_local_train(backend, global, &data, cfg, &mut crng)
                        .map(|out| (cid, data.n(), out))
                },
            )
        };
        let mut updates = Vec::new();
        let mut train = LossSums::default();
        for r in results {
            let (_cid, n, (w, sums)) = r?;
            train.add(sums);
            updates.push((w, n as f64));
        }
        self.ledger.record_round(up, down);
        if updates.is_empty() {
            // every sampled client dropped: no aggregate step this round
            return Ok(RoundSummary {
                train_signal: 0.0,
                dropped,
                catch_up_down: 0,
                seeds_issued: 0,
                eff_var: 0.0,
                staleness: 0.0,
                makespan_ms: 0.0,
                edge_drops: 0,
            });
        }
        let avg = weighted_average(&updates);
        let mut delta = avg;
        delta.axpy(-1.0, &self.global);
        self.server_opt
            .apply(&mut self.global, &delta, self.cfg.lr_server_warm);
        Ok(RoundSummary {
            train_signal: finite_signal(train.mean_loss()),
            dropped,
            catch_up_down: 0,
            seeds_issued: 0,
            eff_var: 0.0,
            staleness: 0.0,
            makespan_ms: 0.0,
            edge_drops: 0,
        })
    }

    fn kseed_round(&mut self, round: usize) -> anyhow::Result<RoundSummary> {
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients);
        let picked = self.rng.choose(self.cfg.clients, q);
        // simulate capability timelines (clients below even the ZO
        // footprint never participate), then parallel fan-out over
        // survivors, RNGs pre-derived, fold in sampled order
        let deadline = self.cfg.scenario.deadline_ms();
        let per_client_up = (self.ks.local_steps * (4 + 4)) as u64;
        let mut jobs: Vec<(usize, ClientData, Xoshiro256)> = Vec::with_capacity(q);
        let mut up = 0u64;
        let mut dropped = 0usize;
        for &cid in &picked {
            let profile = self.pop.profile(cid);
            if !sim::is_available(&profile, self.cfg.seed, round, cid)
                || !profile.zo_capable(&self.cost)
            {
                dropped += 1;
                continue;
            }
            let plan = sim::RoundPlan {
                down_bytes: 0, // histories are broadcast at round end
                passes: sim::kseed_passes(self.ks.local_steps, self.ks.step_batch),
                up_bytes: per_client_up,
            };
            let mut trace = round_client_rng(self.cfg.seed, sim::SIM_SALT, round, cid);
            let o = sim::simulate_round(&profile, &plan, self.cost.params, deadline, &mut trace);
            up += o.up_bytes;
            if o.survives {
                jobs.push((
                    cid,
                    self.pop.data(cid),
                    round_client_rng(self.cfg.seed, 0x4B, round, cid),
                ));
            } else {
                dropped += 1;
            }
        }
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let pool = &self.pool;
            let ks = &self.ks;
            let cfg = &self.cfg;
            parallel_map_n(
                resolve_workers(self.cfg.threads),
                jobs,
                move |(cid, data, mut crng)| {
                    kseed_local(
                        backend,
                        global,
                        &data,
                        pool,
                        ks,
                        &cfg.zo,
                        cfg.lr_client_zo,
                        &mut crng,
                    )
                    .map(|hist| (cid, data.n(), hist))
                },
            )
        };
        let mut histories: Vec<(Vec<SeedGrad>, f64)> = Vec::new();
        let mut mean_abs = 0.0f64;
        let mut count = 0usize;
        for r in results {
            let (_cid, n, hist) = r?;
            for h in &hist {
                mean_abs += h.ghat.abs();
                count += 1;
            }
            histories.push((hist, n as f64));
        }
        let n_total: f64 = histories.iter().map(|(_, n)| n).sum();
        let lr = self.cfg.lr_client_zo * self.cfg.lr_server_zo;
        for (hist, n) in &histories {
            replay(
                &mut self.global,
                &self.pool,
                hist,
                &self.cfg.zo,
                lr,
                n / n_total.max(1.0),
            );
        }
        // bytes: up = each participant's (idx u32 + ghat f32) history,
        // partial for dropouts; down = the round-end broadcast of the
        // *surviving* histories to each survivor (dropped histories were
        // never folded, so they are never broadcast)
        let survivors = histories.len() as u64;
        let down = survivors * survivors * per_client_up;
        self.ledger.record_round(up, down);
        Ok(RoundSummary {
            train_signal: finite_signal(if count > 0 {
                mean_abs / count as f64
            } else {
                0.0
            }),
            dropped,
            catch_up_down: 0,
            // the finite-pool protocol issues no fresh per-round seeds
            // and reports no per-round estimator variance — the
            // seeds_issued / eff_var columns are ZOWarmUp-specific
            // (adaptive probe budgeting does not apply here; see the
            // module docs)
            seeds_issued: 0,
            eff_var: 0.0,
            // barrier protocol, no event engine: the async columns
            // (staleness, simulated makespan) are ZOWarmUp-specific
            staleness: 0.0,
            makespan_ms: 0.0,
            // flat topology: baselines never model edge aggregators
            edge_drops: 0,
        })
    }

    pub fn run(&mut self) -> anyhow::Result<()> {
        for round in 0..self.cfg.rounds_total {
            let t0 = Instant::now();
            let (phase, summary) = if round < self.cfg.pivot {
                (Phase::Warm, self.warm_round(round)?)
            } else {
                (Phase::Zo, self.kseed_round(round)?)
            };
            let do_eval = round % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds_total
                || round + 1 == self.cfg.pivot;
            let (test_acc, test_loss) = if do_eval {
                let e = self.eval()?;
                (e.accuracy(), e.mean_loss())
            } else {
                (f64::NAN, f64::NAN)
            };
            let (up, down) = *self.ledger.per_round.last().unwrap();
            self.log.push(RoundRecord {
                round,
                phase,
                train_loss: summary.train_signal,
                test_acc,
                test_loss,
                bytes_up: up,
                bytes_down: down,
                dropped: summary.dropped,
                catch_up_down: summary.catch_up_down,
                seeds_issued: summary.seeds_issued,
                eff_var: summary.eff_var,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                staleness: summary.staleness,
                model_version: 0,
                makespan_ms: summary.makespan_ms,
                edge_drops: summary.edge_drops,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dirichlet::dirichlet_split;
    use crate::data::synthetic::{train_test, SynthKind};
    use crate::fed::server::shards_from_partition;
    use crate::model::backend::LinearBackend;
    use std::sync::Arc;

    fn setup(cfg: &FedConfig) -> (LinearBackend, Vec<ClientData>, Source) {
        let (train, test) = train_test(SynthKind::Synth10, 300, 100, cfg.seed);
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        (
            LinearBackend::pooled(32 * 32 * 3, 2, 10, 32),
            shards,
            Source::Image(Arc::new(test)),
        )
    }

    #[test]
    fn replay_matches_local_update() {
        // client's local weight after kseed_local must equal global after
        // replay with weight 1 and lr_server=1 — protocol consistency.
        let be = LinearBackend::new(16, 2, 8);
        let cfg = FedConfig::default().smoke_scale();
        let (train, _) = train_test(SynthKind::Synth10, 40, 10, 0);
        let _ = train;
        // small custom data: reuse toy separable via synthetic features
        let mut rng = Xoshiro256::seed_from(0);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..24 {
            y.push((i % 2) as i32);
            for j in 0..16 {
                x.push(if j % 2 == 0 {
                    if i % 2 == 0 {
                        -1.0
                    } else {
                        1.0
                    }
                } else {
                    0.0
                } + (rng.next_f32() - 0.5) * 0.1);
            }
        }
        // wrap as an image-free source is awkward; drive kseed_local with a
        // hand-built ClientData over a fake image dataset of matching len.
        // Instead: test replay arithmetic directly.
        let pool: Vec<u64> = (0..32).map(|i| 1000 + i).collect();
        let hist = vec![
            SeedGrad {
                pool_idx: 3,
                ghat: 0.5,
            },
            SeedGrad {
                pool_idx: 7,
                ghat: -0.2,
            },
        ];
        let zo = cfg.zo;
        let mut a = ParamVec::zeros(be.dim());
        replay(&mut a, &pool, &hist, &zo, 0.1, 1.0);
        // manual
        let mut b = ParamVec::zeros(be.dim());
        b.perturb_axpy(pool[3], zo.tau, zo.dist, -0.1 * 0.5);
        b.perturb_axpy(pool[7], zo.tau, zo.dist, 0.1 * 0.2);
        assert_eq!(a, b);
    }

    #[test]
    fn cold_fedkseed_struggles_warm_fedkseed_learns() {
        // miniature Table 2 shape: from-scratch multi-step FedKSeed is far
        // worse than the warm-started 1-step variant.
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.rounds_total = 16;
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;

        // cold: pivot 0, many local steps
        let mut cold_cfg = cfg.clone();
        cold_cfg.pivot = 0;
        let (be, shards, test) = setup(&cold_cfg);
        let ks_cold = KSeedConfig {
            pool_size: 64,
            local_steps: 20,
            step_batch: 8,
        };
        let mut cold = FedKSeedRun::new(
            cold_cfg,
            ks_cold,
            &be,
            shards,
            test,
            ParamVec::zeros(be.dim()),
        )
        .unwrap();
        cold.run().unwrap();

        // warm: pivot 8, single step
        let mut warm_cfg = cfg.clone();
        warm_cfg.pivot = 8;
        let (be2, shards2, test2) = setup(&warm_cfg);
        let ks_warm = KSeedConfig {
            pool_size: 64,
            local_steps: 1,
            step_batch: 32,
        };
        let mut warm = FedKSeedRun::new(
            warm_cfg,
            ks_warm,
            &be2,
            shards2,
            test2,
            ParamVec::zeros(be2.dim()),
        )
        .unwrap();
        warm.run().unwrap();

        let (ca, wa) = (cold.log.final_accuracy(), warm.log.final_accuracy());
        assert!(
            wa > ca,
            "warm 1-step ({wa}) must beat cold multi-step ({ca})"
        );
    }

    #[test]
    fn lazy_population_fedkseed_runs_deterministically() {
        // the fleet-scale constructor end-to-end: lazy profiles + keyed
        // shards through both phases, deterministic per seed
        let run = || {
            let mut cfg = FedConfig::default().smoke_scale();
            cfg.clients = 512;
            cfg.rounds_total = 6;
            cfg.pivot = 2;
            cfg.population = crate::config::PopulationMode::Lazy;
            cfg.scenario = crate::sim::Scenario::preset("fleet").unwrap();
            cfg.lr_client_warm = 0.06;
            cfg.lr_client_zo = 1.0;
            cfg.lr_server_zo = 0.01;
            let (train, test) = train_test(SynthKind::Synth10, 300, 100, cfg.seed);
            let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
            let ks = KSeedConfig {
                pool_size: 32,
                local_steps: 2,
                step_batch: 8,
            };
            let mut run = FedKSeedRun::new_lazy(
                cfg,
                ks,
                &be,
                Source::Image(Arc::new(train)),
                Source::Image(Arc::new(test)),
                ParamVec::zeros(be.dim()),
            )
            .unwrap();
            run.run().unwrap();
            (run.global.clone(), run.ledger.up_total, run.ledger.down_total)
        };
        let (g1, up1, down1) = run();
        let (g2, up2, down2) = run();
        assert_eq!(g1, g2);
        assert_eq!((up1, down1), (up2, down2));
        assert!(g1.is_finite());
        assert!(up1 > 0, "ZO rounds must upload histories");
    }

    #[test]
    fn comm_is_seed_sized() {
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.pivot = 0;
        cfg.rounds_total = 2;
        let (be, shards, test) = setup(&cfg);
        let ks = KSeedConfig {
            pool_size: 16,
            local_steps: 5,
            step_batch: 8,
        };
        let mut run =
            FedKSeedRun::new(cfg, ks, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        run.run().unwrap();
        let (up, _) = run.log.total_bytes();
        // 2 rounds × 4 clients × 5 steps × 8 bytes
        assert_eq!(up, 2 * 4 * 5 * 8);
        assert!(up < (be.dim() * 4) as u64 / 10); // far below one FedAvg upload
    }
}
