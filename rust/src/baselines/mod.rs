//! Comparator methods from the paper's evaluation: HeteroFL (Diao et al.,
//! 2020), FedKSeed (Qin et al., 2024), and the High-Res-Only exclusion
//! baseline (a `Federation` with pivot = total rounds, sampling only H).

pub mod fedkseed;
pub mod heterofl;

pub use fedkseed::{FedKSeedRun, KSeedConfig};
pub use heterofl::{heterofl_aggregate, HeteroFlRun, SliceMap};
