//! Client-side computations: warm-phase local SGD and the ZO-phase data
//! staging. Clients never see each other's data; everything they export is
//! either a weight vector (warm, high-resource only) or `S` scalars (ZO).

use crate::comm::CostModel;
use crate::config::FedConfig;
use crate::data::loader::ClientData;
use crate::model::backend::{Batch, LossSums, ModelBackend};
use crate::model::params::ParamVec;
use crate::sim::CapabilityProfile;
use crate::util::rng::Xoshiro256;

/// Resource class of an edge device (§3: a low-resource client cannot run
/// backprop-based training at all). Since the `sim` capability engine
/// this is a *derived* view: High ⇔ the client's [`CapabilityProfile`]
/// covers the eq. 4 backprop footprint of the run's cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    High,
    Low,
}

/// One simulated client.
pub struct ClientState {
    pub id: usize,
    pub data: ClientData,
    /// derived FO-eligibility class (see [`clients_from_profiles`])
    pub resource: Resource,
    /// sampled device capabilities (memory, bandwidth, compute, drops)
    pub profile: CapabilityProfile,
}

impl ClientState {
    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn is_high(&self) -> bool {
        self.resource == Resource::High
    }
}

/// Build the client list from shards and sampled capability profiles.
/// The legacy `Resource` class is derived here — the single place FO
/// eligibility is decided — by thresholding the profile's memory budget
/// against the cost model (`CostModel::fo_threshold_bytes`).
pub fn clients_from_profiles(
    shards: Vec<ClientData>,
    profiles: Vec<CapabilityProfile>,
    cost: &CostModel,
) -> Vec<ClientState> {
    assert_eq!(shards.len(), profiles.len());
    shards
        .into_iter()
        .zip(profiles)
        .enumerate()
        .map(|(id, (data, profile))| {
            let resource = if profile.fo_capable(cost) {
                Resource::High
            } else {
                Resource::Low
            };
            ClientState {
                id,
                data,
                resource,
                profile,
            }
        })
        .collect()
}

/// WARMUP (Algorithm 1 line 5): local_epochs of minibatch SGD starting
/// from the global weights. Returns the trained weights and the first
/// epoch's loss sums (the pre-update training signal).
pub fn warm_local_train<B: ModelBackend>(
    backend: &B,
    global: &ParamVec,
    data: &ClientData,
    cfg: &FedConfig,
    rng: &mut Xoshiro256,
) -> anyhow::Result<(ParamVec, LossSums)> {
    let mut w = global.clone();
    let mut first_epoch = LossSums::default();
    for epoch in 0..cfg.local_epochs {
        for batch in data.epoch_batches(cfg.batch, rng) {
            let sums = backend.sgd_step(&mut w, &batch, cfg.lr_client_warm)?;
            if epoch == 0 {
                first_epoch.add(sums);
            }
        }
    }
    Ok((w, first_epoch))
}

/// Client-id bound of the *compact* per-(round, client) RNG packing: ids
/// below this use the seed repo's historical `round << 20 | cid` stream
/// derivation unchanged, so every pre-fleet trace stays bit-identical.
pub const MAX_SIM_CLIENTS: usize = 1 << 20;

/// Hard population bound of the fleet-scale wide derivation (enforced by
/// `FedConfig::validate`): the wide packing gives the client id 40 bits,
/// so up to ~10^12 simulated clients derive collision-free streams.
pub const MAX_FLEET_CLIENTS: usize = 1 << 40;

// Stream salt of the wide (fleet-scale) derivation, decorrelating it
// from any value the compact linear packing can reach. Defined in the
// central registry (`util::rng::salts`, DESIGN.md §14).
use crate::util::rng::salts::WIDE_STREAM_SALT;

/// Per-(round, client) local RNG shared by every round engine (warm /
/// FO local SGD, FedKSeed minibatch + pool draws): a pure function of
/// immutable inputs, so it can be derived before a parallel fan-out.
/// `salt` decorrelates engines that need independent streams for the
/// same (round, client) pair.
///
/// Two derivation domains, split so fleet-scale populations do not
/// disturb historical traces:
/// * `cid < 2^20` — the seed repo's compact packing `round << 20 | cid`,
///   byte-for-byte the original stream;
/// * `cid >= 2^20` — the unique 64-bit pack `round << 40 | cid`
///   (`round < 2^24`, `cid < 2^40`) is hashed through
///   [`crate::util::rng::SplitMix64`] before seeding, so wide-domain
///   streams cannot alias the compact linear packings (which occupy a
///   low-entropy corner of the space).
pub fn round_client_rng(master: u64, salt: u64, round: usize, cid: usize) -> Xoshiro256 {
    if cid < MAX_SIM_CLIENTS {
        return Xoshiro256::seed_from(master ^ salt ^ ((round as u64) << 20) ^ cid as u64);
    }
    // hard bounds (not debug_assert): an overflowing field would alias
    // another (round, client) stream in release (DESIGN.md §14)
    assert!(
        cid < MAX_FLEET_CLIENTS,
        "client id {cid} overflows the 40-bit fleet RNG field"
    );
    assert!(
        round < crate::zo::MAX_ROUNDS,
        "round {round} overflows the 24-bit field"
    );
    let packed = ((round as u64) << 40) | cid as u64;
    let mut sm = crate::util::rng::SplitMix64(packed);
    Xoshiro256::seed_from(master ^ salt ^ WIDE_STREAM_SALT ^ sm.next_u64())
}

/// Number of seed blocks a client with `n` samples actually runs — the
/// server derives `s_seeds * zo_step_count(..)` seeds per client *before*
/// the parallel fan-out, so this must stay the single source of truth for
/// [`zo_step_chunks`]'s group count.
pub fn zo_step_count(n: usize, grad_steps: usize) -> usize {
    if n == 0 {
        grad_steps
    } else {
        grad_steps.min(n).max(1)
    }
}

/// ZO-phase data staging: split the client's full dataset into
/// `grad_steps` groups of chunked batches (grad_steps = 1 → one group =
/// the whole dataset, the paper's single full-batch step).
pub fn zo_step_chunks(data: &ClientData, batch: usize, grad_steps: usize) -> Vec<Vec<Batch>> {
    let n = data.n();
    if n == 0 {
        return vec![Vec::new(); grad_steps];
    }
    let steps = zo_step_count(n, grad_steps);
    let per = n.div_ceil(steps);
    let mut out = Vec::with_capacity(steps);
    for s in 0..steps {
        let lo = s * per;
        let hi = ((s + 1) * per).min(n);
        if lo >= hi {
            out.push(Vec::new());
            continue;
        }
        let sub = ClientData {
            source: data.source.clone(),
            indices: data.indices[lo..hi].to_vec(),
        };
        out.push(sub.chunks(batch));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::Source;
    use crate::data::synthetic::{generate, GenConfig, SynthKind};
    use crate::model::backend::LinearBackend;
    use std::sync::Arc;

    fn client(n: usize) -> ClientData {
        let d = generate(SynthKind::Synth10, n, GenConfig::default());
        ClientData {
            source: Source::Image(Arc::new(d)),
            indices: (0..n).collect(),
        }
    }

    #[test]
    fn round_client_rng_compact_domain_is_unchanged_and_wide_domain_is_distinct() {
        // compact ids reproduce the historical derivation exactly
        for (round, cid) in [(0usize, 0usize), (3, 7), (100, (1 << 20) - 1)] {
            let legacy =
                Xoshiro256::seed_from(9 ^ 5 ^ ((round as u64) << 20) ^ cid as u64).next_u64();
            assert_eq!(
                round_client_rng(9, 5, round, cid).next_u64(),
                legacy,
                "round={round} cid={cid}"
            );
        }
        // wide ids: deterministic, distinct across (round, cid, salt),
        // and distinct from nearby compact streams
        let a = round_client_rng(9, 5, 3, 1 << 20).next_u64();
        assert_eq!(a, round_client_rng(9, 5, 3, 1 << 20).next_u64());
        assert_ne!(a, round_client_rng(9, 5, 3, (1 << 20) + 1).next_u64());
        assert_ne!(a, round_client_rng(9, 5, 4, 1 << 20).next_u64());
        assert_ne!(a, round_client_rng(9, 6, 3, 1 << 20).next_u64());
        assert_ne!(a, round_client_rng(9, 5, 3, (1 << 20) - 1).next_u64());
        // a 10M-client fleet id derives fine
        let big = round_client_rng(0, 0, 0, 9_999_999).next_u64();
        assert_ne!(big, round_client_rng(0, 0, 0, 9_999_998).next_u64());
    }

    #[test]
    fn warm_local_train_learns() {
        let be = LinearBackend::new(32 * 32 * 3, 10, 16);
        let data = client(64);
        let global = ParamVec::zeros(be.dim());
        let mut cfg = FedConfig::default();
        cfg.local_epochs = 3;
        cfg.batch = 16;
        cfg.lr_client_warm = 0.06;
        let mut rng = Xoshiro256::seed_from(0);
        let (w, sums) = warm_local_train(&be, &global, &data, &cfg, &mut rng).unwrap();
        assert_eq!(sums.count, 64.0);
        assert_ne!(w, global);
        // after training, loss on own data must beat the zero-init loss
        let batch = data.chunks(16);
        let mut after = LossSums::default();
        for b in &batch {
            after.add(be.fwd_loss(&w, b).unwrap());
        }
        assert!(after.mean_loss() < (10f64).ln(), "{}", after.mean_loss());
    }

    #[test]
    fn zo_step_chunks_partition_everything() {
        let data = client(25);
        for steps in [1, 2, 4, 6] {
            let groups = zo_step_chunks(&data, 8, steps);
            assert_eq!(groups.len(), steps);
            assert_eq!(groups.len(), zo_step_count(data.n(), steps));
            let total: f64 = groups
                .iter()
                .flatten()
                .map(|b| b.real_count())
                .sum();
            assert_eq!(total, 25.0, "steps={steps}");
        }
    }

    #[test]
    fn zo_step_chunks_more_steps_than_samples() {
        let data = client(3);
        let groups = zo_step_chunks(&data, 8, 6);
        let total: f64 = groups.iter().flatten().map(|b| b.real_count()).sum();
        assert_eq!(total, 3.0);
    }

    #[test]
    fn empty_client_yields_empty_chunks() {
        let data = ClientData {
            source: client(4).source,
            indices: vec![],
        };
        let groups = zo_step_chunks(&data, 8, 2);
        assert!(groups.iter().all(|g| g.is_empty()));
    }
}
