//! The federated coordinator (Layer 3): Algorithm 1's two-phase training
//! loop, client simulation, and server-side aggregation.

pub mod aggregate;
pub mod client;
pub mod engine;
pub mod population;
pub mod server;

pub use client::{clients_from_profiles, ClientState, Resource};
pub use engine::AsyncEvent;
pub use population::{Population, SparseSync};
pub use server::{assign_resources, shards_from_partition, Federation, RoundSummary};
