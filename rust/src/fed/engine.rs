//! The buffered-asynchronous round engine: a discrete-event simulation
//! of FedBuff-style staleness-weighted aggregation over the same
//! classify→plan→simulate→contribute client path as the sync barrier.
//!
//! ## Model
//!
//! The sync engine (`Federation::zo_round`) samples a cohort, waits for
//! the barrier, and folds the survivors. This engine instead keeps up to
//! `cfg.async_concurrency()` dispatches **in flight** on a simulated
//! event clock: each dispatch samples one client, runs the exact
//! [`crate::sim`] timeline the barrier would have run, and schedules a
//! completion event at `now + arrival_jitter + sim_ms`. One *logical
//! round* pops completion events in arrival order and folds the first
//! `cfg.buffer_k()` survivors — stale contributions included, discounted
//! by the polynomial staleness weight `(1 + s)^(-decay)`
//! ([`crate::zo::staleness_multipliers`]) where `s` is the number of
//! parameter-mutating folds since the contribution's dispatch
//! (`model_version` now − then). Each surviving dispatch evaluates its
//! seed block against an `Arc`-shared snapshot of the global weights *as
//! of its dispatch* — the client genuinely computes on stale parameters,
//! exactly like a real async fleet.
//!
//! ## Determinism
//!
//! The engine is bit-identical for every worker count, by the same three
//! rules as the barrier (see `fed::server` module docs) plus one: event
//! order is decided by `(t_arrive, dispatch seq)` under `f64::total_cmp`
//! — never by thread scheduling. All per-dispatch randomness (client
//! pick, capability timeline, arrival jitter, seed block) derives from
//! the monotone dispatch sequence number, **not** the round counter, so
//! a client redispatched within one logical round gets a fresh timeline
//! (round-keyed streams would replay the same drop forever).
//! [`sim::ASYNC_SIM_SALT`] / [`sim::ARRIVAL_SALT`] keep these streams
//! disjoint from every sync-engine stream, and seeds are issued under
//! the dispatch-seq "round" key — collision-free against sync issuance
//! because an async run never executes a sync ZO round (warm rounds
//! issue no seeds).
//!
//! ## Accounting
//!
//! All accounting attributes to the logical round that **pops** the
//! event: uplink/downlink partial-transmission charges, catch-up bytes,
//! issued-seed counts, and drop counts ride the popped
//! [`ZoClientCharge`]s through the same [`zo_round_ledger_outcomes`]
//! fold the barrier uses. Dispatches refused at classification time
//! (absent / below the ZO footprint) count as drops in the dispatching
//! round; dispatches still in flight when the run ends are never
//! charged. The round's `makespan_ms` is the event-clock span its fold
//! consumed — the systems metric staleness buys down.

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::data::loader::ClientData;
use crate::fed::client::round_client_rng;
use crate::fed::server::{run_zo_client, zo_train_signal, ClientClass, Federation, RoundSummary};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::params::{perturb_axpy_many_sharded_kernel, ParamVec};
use crate::sim;
use crate::zo::{
    self, staleness_multipliers, zo_round_ledger_outcomes, zo_round_ledger_outcomes_per_edge,
    zo_update_items_two_tier, zo_update_items_weighted, ZoClientCharge, ZoContribution,
};

/// One folded completion event — the engine's deterministic trace unit.
/// The async acceptance tests pin runs at different worker counts to
/// byte-identical traces (`t_ms` compared via `to_bits`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncEvent {
    /// event-clock arrival time (simulated ms since the run began)
    pub t_ms: f64,
    /// monotone dispatch sequence number (unique, ties broken by it)
    pub seq: u64,
    pub cid: usize,
    /// server model version the dispatch computed against
    pub version: usize,
    /// false when the capability timeline cut the client mid-round
    pub survived: bool,
}

/// A surviving dispatch's deferred local computation: everything
/// [`run_zo_client`] needs, including the `Arc`-shared snapshot of the
/// global weights the client downloaded at dispatch time.
struct PendingJob {
    data: ClientData,
    seeds: Vec<u64>,
    s_block: usize,
    global: Arc<ParamVec>,
}

/// One in-flight dispatch awaiting its completion event.
struct InFlight {
    /// completion time on the event clock
    t_arrive: f64,
    /// dispatch sequence number (the RNG/seed key and the tie-breaker)
    seq: u64,
    cid: usize,
    /// model version at dispatch — staleness at fold = now − this
    version: usize,
    /// logical round at dispatch — the sync-ledger round a completed
    /// catch-up download brings the client to
    dispatch_round: usize,
    /// the edge aggregator this dispatch routes through (two-tier
    /// topology; 0 in flat runs) — its completion lands in that edge's
    /// slice of the round buffer and its charges book on that edge
    edge: usize,
    /// catch-up bytes fronting the download leg (`ckpt` subsystem)
    catch_bytes: u64,
    /// wire/probe charges, resolved at dispatch from the simulated
    /// timeline, booked at pop
    charge: ZoClientCharge,
    /// `Some` only for survivors
    job: Option<PendingJob>,
}

/// Min-heap adapter: `BinaryHeap` is a max-heap, so `Ord` is reversed —
/// the pop order is ascending `(t_arrive, seq)` under `total_cmp`.
struct HeapItem(InFlight);

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.0.seq == other.0.seq
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .0
            .t_arrive
            .total_cmp(&self.0.t_arrive)
            .then_with(|| other.0.seq.cmp(&self.0.seq))
    }
}

/// Persistent event-engine state, carried across logical rounds inside
/// `Federation::async_state` (in-flight dispatches straddle round
/// boundaries — that is the whole point of the buffered design).
#[derive(Default)]
pub(crate) struct AsyncState {
    heap: BinaryHeap<HeapItem>,
    /// event clock (simulated ms since the run began)
    now: f64,
    /// next dispatch sequence number
    seq: u64,
    /// every folded completion event, in pop order
    trace: Vec<AsyncEvent>,
    /// live `(model_version, weights)` snapshots shared by in-flight
    /// survivors; GC'd once no in-flight dispatch can reference them
    snapshots: Vec<(usize, Arc<ParamVec>)>,
}

impl AsyncState {
    /// The shared snapshot of `global` at `version`, created on first
    /// use. Dispatches at the same version share one allocation, so
    /// memory is O(distinct live versions), not O(in-flight).
    fn snapshot(&mut self, version: usize, global: &ParamVec) -> Arc<ParamVec> {
        if let Some((_, arc)) = self.snapshots.iter().find(|(v, _)| *v == version) {
            return arc.clone();
        }
        let arc = Arc::new(global.clone());
        self.snapshots.push((version, arc.clone()));
        arc
    }

    /// Drop snapshots no in-flight dispatch can still reference.
    fn gc_snapshots(&mut self) {
        match self.heap.iter().map(|h| h.0.version).min() {
            Some(min_live) => self.snapshots.retain(|(v, _)| *v >= min_live),
            None => self.snapshots.clear(),
        }
    }
}

/// A folded survivor awaiting the round's weighted aggregation.
struct Buffered {
    cid: usize,
    /// model version its snapshot was taken at
    version: usize,
    /// whether its download leg covered the full catch-up payload
    caught_up: bool,
    /// the edge whose buffer this completion routed through
    edge: usize,
    job: PendingJob,
}

/// What became of one dispatch attempt (see [`Federation::dispatch_one`]).
enum DispatchOutcome {
    /// in flight: a completion event is on the heap
    InFlight,
    /// refused at classification (absent / below the ZO footprint)
    Refused,
    /// the sampled client's edge aggregator is down this logical round —
    /// its whole cohort is unreachable (scenario edge modeling only)
    EdgeDown,
}

impl<'b, B: ModelBackend> Federation<'b, B> {
    /// The folded completion-event trace of the async engine so far —
    /// the deterministic inspection surface behind the async acceptance
    /// tests. Empty for sync runs.
    pub fn async_trace(&self) -> &[AsyncEvent] {
        self.async_state.as_ref().map_or(&[], |s| &s.trace)
    }

    /// One buffered-async logical round: keep the dispatch pipeline
    /// full, pop completion events in arrival order, fold the first
    /// `cfg.buffer_k()` survivors with staleness-decayed weights. Public
    /// because the throughput benches drive it directly.
    pub fn async_zo_round(&mut self) -> anyhow::Result<RoundSummary> {
        // take the state out of self so the borrow checker sees the
        // engine core borrow `self` and the event state independently
        let mut st = self.async_state.take().unwrap_or_default();
        let r = self.async_round_inner(&mut st);
        self.async_state = Some(st);
        r
    }

    fn async_round_inner(&mut self, st: &mut AsyncState) -> anyhow::Result<RoundSummary> {
        let k = self.cfg.buffer_k();
        let cslots = self.cfg.async_concurrency();
        let d4 = (self.backend.dim() * 4) as u64;
        let two_tier = self.cfg.edges > 1;
        let e_slots = if two_tier { self.cfg.edges } else { 0 };
        let round_start = st.now;
        // deterministic give-up bound: a fleet where every pick drops at
        // classification (full-churn rounds) must still terminate — the
        // round then folds whatever arrived, possibly nothing
        let mut dispatches_left = k * 64 + cslots;

        let mut dropped = 0usize;
        let mut edge_drops = 0usize;
        let mut catch_up_down = 0u64;
        let mut catch_edge = vec![0u64; e_slots];
        let mut charges: Vec<ZoClientCharge> = Vec::new();
        // the edge each popped charge books on (parallel to `charges`)
        let mut charge_edges: Vec<usize> = Vec::new();
        let mut buffer: Vec<Buffered> = Vec::with_capacity(k);
        loop {
            // keep the pipeline full
            while st.heap.len() < cslots && dispatches_left > 0 {
                dispatches_left -= 1;
                match self.dispatch_one(st, d4)? {
                    DispatchOutcome::InFlight => {}
                    DispatchOutcome::Refused => dropped += 1,
                    DispatchOutcome::EdgeDown => {
                        dropped += 1;
                        edge_drops += 1;
                    }
                }
            }
            let Some(HeapItem(ev)) = st.heap.pop() else {
                break; // pipeline dry and no dispatch budget left
            };
            st.now = st.now.max(ev.t_arrive);
            let cu = ev.charge.seed_down_bytes.min(ev.catch_bytes);
            catch_up_down += cu;
            if two_tier {
                catch_edge[ev.edge] += cu;
            }
            let caught_up = ev.charge.seed_down_bytes >= ev.catch_bytes;
            if caught_up {
                // download legs are ordered catch-up first (see
                // zo_round): the client now holds the global entering
                // its dispatch round
                self.mark_synced(ev.cid, ev.dispatch_round);
            }
            st.trace.push(AsyncEvent {
                t_ms: ev.t_arrive,
                seq: ev.seq,
                cid: ev.cid,
                version: ev.version,
                survived: ev.charge.survives,
            });
            let survived = ev.charge.survives;
            charges.push(ev.charge);
            charge_edges.push(ev.edge);
            if survived {
                // a malformed survivor event with no deferred job used to
                // abort the whole fleet run via expect(); degrade it to a
                // counted drop instead (warned once on stderr)
                match take_survivor_job(ev.job, ev.seq, ev.cid) {
                    Some(job) => {
                        buffer.push(Buffered {
                            cid: ev.cid,
                            version: ev.version,
                            caught_up,
                            edge: ev.edge,
                            job,
                        });
                        if buffer.len() >= k {
                            break; // buffer full: fold
                        }
                    }
                    None => dropped += 1,
                }
            } else {
                dropped += 1;
            }
        }

        // staleness per buffered survivor, measured before this fold
        // can bump the version counter
        let staleness: Vec<usize> = buffer
            .iter()
            .map(|b| self.model_version - b.version)
            .collect();
        let survivor_info: Vec<(usize, bool)> =
            buffer.iter().map(|b| (b.cid, b.caught_up)).collect();
        // fold order is pop order; each survivor's contribution routes
        // through its edge's slice of the buffer (two-tier fold below)
        let survivor_edges: Vec<usize> = buffer.iter().map(|b| b.edge).collect();

        // the exact client path the barrier runs, against each job's own
        // dispatch-time snapshot (determinism rules 1–3 hold: inputs are
        // pre-derived, jobs are pure, the fold is in pop order)
        let workers = self.workers();
        let results = {
            let backend = self.backend;
            let cfg = &self.cfg;
            let jobs: Vec<(usize, PendingJob)> =
                buffer.into_iter().map(|b| (b.cid, b.job)).collect();
            crate::util::pool::parallel_map_n(workers, jobs, move |(cid, job)| {
                run_zo_client(
                    backend, &job.global, cfg, cid, &job.data, job.seeds, job.s_block,
                )
            })
        };
        let mut contributions: Vec<ZoContribution> = Vec::with_capacity(k);
        for r in results {
            contributions.push(r?);
        }

        // ZOUPDATE with staleness-decayed weights: the polynomial
        // multiplier discounts each contribution by the folds it missed,
        // renormalized inside the fold so total step mass is conserved
        let eff_var = zo::effective_variance(&contributions, &self.cfg.zo);
        let mults = staleness_multipliers(&staleness, self.cfg.async_zo.staleness_decay);
        let items = if two_tier {
            // buffered completions route through their edge's buffer:
            // each edge partially folds its slice (staleness weights
            // resolved at the root over the full buffer) and the root
            // merges in edge-index order — bit-identical to the flat
            // weighted fold (`zo_update_items_two_tier`)
            let (_partials, merged) = zo_update_items_two_tier(
                &contributions,
                Some(&mults),
                &survivor_edges,
                self.cfg.edges,
                &self.cfg.zo,
                self.cfg.lr_client_zo,
                self.cfg.lr_server_zo,
            );
            merged
        } else {
            zo_update_items_weighted(
                &contributions,
                Some(&mults),
                &self.cfg.zo,
                self.cfg.lr_client_zo,
                self.cfg.lr_server_zo,
            )
        };
        perturb_axpy_many_sharded_kernel(
            &mut self.global.0,
            &items,
            self.cfg.zo.tau,
            self.cfg.zo.dist,
            workers,
            self.cfg.zo.kernel,
        );
        if !items.is_empty() {
            self.model_version += 1;
        }
        // fresh (staleness-0), caught-up survivors received every
        // broadcast between their dispatch and this fold — all identity
        // rounds by definition of staleness 0 — plus this round's item
        // list, so they can reconstruct the global entering round+1.
        // Stale survivors cannot: they missed intermediate item lists.
        for (i, (cid, caught_up)) in survivor_info.iter().enumerate() {
            if staleness[i] == 0 && *caught_up {
                self.mark_synced(*cid, self.round + 1);
            }
        }
        // every async fold is seed-replayable (validate() rejects the
        // opaque mixed-FO fold under this engine), so the compacted seed
        // log can always cross it — empty rounds included
        self.ckpt.record_seed_round(self.round, items, &self.global);

        // book the popped charges through the barrier's ledger fold
        let seeds_issued: usize = charges.iter().map(|c| c.issued_seeds).sum();
        let (up, down) = zo_round_ledger_outcomes(&charges, 0, 0);
        self.ledger.record_round(up, down);
        self.ledger.record_catch_up(catch_up_down);
        self.ledger.record_seeds(seeds_issued as u64);
        if two_tier {
            // per-edge sub-attribution of the exact flat totals (no FO
            // traffic exists under this engine)
            let per_edge = zo_round_ledger_outcomes_per_edge(
                &charges,
                &charge_edges,
                self.cfg.edges,
                &[],
                &[],
            );
            for (e, &(eu, ed)) in per_edge.iter().enumerate() {
                self.ledger.record_edge_round(e, eu, ed);
            }
            for (e, &cb) in catch_edge.iter().enumerate() {
                if cb > 0 {
                    self.ledger.record_edge_catch_up(e, cb);
                }
            }
        }
        st.gc_snapshots();

        let mean_staleness = if staleness.is_empty() {
            0.0
        } else {
            staleness.iter().sum::<usize>() as f64 / staleness.len() as f64
        };
        Ok(RoundSummary {
            train_signal: zo_train_signal(&contributions, &LossSums::default()),
            dropped,
            catch_up_down,
            seeds_issued,
            eff_var,
            staleness: mean_staleness,
            makespan_ms: st.now - round_start,
            edge_drops,
        })
    }

    /// Sample one client and put its dispatch in flight, or report why
    /// it was refused ([`DispatchOutcome`]) — refusals are drops charged
    /// to the dispatching round. All randomness is keyed by the dispatch
    /// sequence number, so redispatching a client that just dropped
    /// rolls a *fresh* timeline. The client-pick draw is consumed before
    /// any refusal check, so every refusal kind advances the sampler
    /// stream identically.
    fn dispatch_one(&mut self, st: &mut AsyncState, d4: u64) -> anyhow::Result<DispatchOutcome> {
        let seq = st.seq;
        anyhow::ensure!(
            (seq as usize) < zo::MAX_ROUNDS,
            "async dispatch counter exhausted the seed issuer's round domain"
        );
        st.seq += 1;
        let cid = self.rng.choose(self.cfg.clients, 1)[0];
        // a down edge aggregator makes its whole cohort unreachable for
        // this logical round (keyed per-edge trace; inert unless the
        // scenario models edges)
        let edge = self.edge_of(cid);
        if self.cfg.scenario.has_edge_profiles() && self.edge_is_down(edge, self.round) {
            return Ok(DispatchOutcome::EdgeDown);
        }
        let profile = self.pop.profile(cid);
        match self.classify(cid, &profile, self.round) {
            ClientClass::Dropped => return Ok(DispatchOutcome::Refused),
            // unreachable: validate() rejects engine=async + mixed_step2
            // (the FO fold needs the barrier); refuse defensively
            ClientClass::Fo { .. } => return Ok(DispatchOutcome::Refused),
            ClientClass::Zo => {}
        }
        let cand = self.zo_candidate(cid, profile, d4);
        // the dispatch runs against its edge's deadline override (equal
        // to the scenario deadline everywhere the scenario doesn't
        // model edges)
        let deadline = self.cfg.scenario.edge_deadline_ms(cand.edge);
        // adaptive probe budget: with a deadline the planner fits each
        // dispatch to it exactly as the barrier does; without one there
        // is no cohort to equalize against (no barrier, no straggler
        // envelope), so the uniform S applies
        let z = self.cfg.zo;
        let s_block = if z.adaptive_s && deadline > 0.0 {
            sim::max_affordable_s(&cand.profile, self.cost.params, deadline, z.s_min, z.s_max, |s| {
                self.zo_candidate_plan(&cand, s)
            })
        } else {
            z.s_seeds
        };
        let n_seeds = s_block * cand.steps;
        let plan = self.zo_candidate_plan(&cand, s_block);
        let mut trace = round_client_rng(self.cfg.seed, sim::ASYNC_SIM_SALT, seq as usize, cid);
        let o = sim::simulate_round(&cand.profile, &plan, self.cost.params, deadline, &mut trace);
        let delay =
            sim::arrival_delay_ms(self.cfg.seed, seq as usize, cid, self.cfg.async_zo.arrival_rate);
        let job = o.survives.then(|| PendingJob {
            data: self.pop.data(cid),
            seeds: self.issuer.seeds_for(seq as usize, cid, n_seeds),
            s_block,
            global: st.snapshot(self.model_version, &self.global),
        });
        st.heap.push(HeapItem(InFlight {
            t_arrive: st.now + delay + o.sim_ms,
            seq,
            cid,
            version: self.model_version,
            dispatch_round: self.round,
            edge: cand.edge,
            catch_bytes: cand.catch_bytes,
            charge: ZoClientCharge {
                issued_seeds: n_seeds,
                up_bytes: o.up_bytes,
                seed_down_bytes: o.down_bytes,
                survives: o.survives,
            },
            job,
        }));
        Ok(DispatchOutcome::InFlight)
    }
}

/// Unwrap a survivor's deferred job. Every dispatch that simulates as a
/// survivor attaches one ([`Federation::dispatch_one`]), so `None` here is
/// a malformed event — but one bad event must not panic an entire fleet
/// run. It degrades to `None` (the caller books it in the round's
/// `dropped` column) with a one-line stderr warning, emitted once per
/// process like `util::pool`'s bad-threads warning.
fn take_survivor_job(job: Option<PendingJob>, seq: u64, cid: usize) -> Option<PendingJob> {
    if job.is_none() {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "zowarmup: survivor event (seq {seq}, client {cid}) carries no deferred \
                 job — malformed; counting it as a drop (warning shown once)"
            );
        });
    }
    job
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(t: f64, seq: u64) -> HeapItem {
        HeapItem(InFlight {
            t_arrive: t,
            seq,
            cid: 0,
            version: 0,
            dispatch_round: 0,
            edge: 0,
            catch_bytes: 0,
            charge: ZoClientCharge {
                issued_seeds: 0,
                up_bytes: 0,
                seed_down_bytes: 0,
                survives: false,
            },
            job: None,
        })
    }

    #[test]
    fn heap_pops_by_arrival_time_then_sequence() {
        let mut h = BinaryHeap::new();
        for (t, s) in [(5.0, 0), (1.0, 3), (1.0, 1), (3.0, 2)] {
            h.push(item(t, s));
        }
        let order: Vec<(u64, u64)> = std::iter::from_fn(|| h.pop())
            .map(|HeapItem(e)| (e.t_arrive.to_bits(), e.seq))
            .collect();
        let expect: Vec<(u64, u64)> = vec![
            (1.0f64.to_bits(), 1),
            (1.0f64.to_bits(), 3),
            (3.0f64.to_bits(), 2),
            (5.0f64.to_bits(), 0),
        ];
        assert_eq!(order, expect, "min-heap order must be (t_arrive, seq)");
    }

    #[test]
    fn jobless_survivor_degrades_to_drop_not_panic() {
        // the malformed event: charge says "survived" but no deferred job
        // is attached — the shape that used to panic the fold loop via
        // expect(). The unwrap helper must degrade it to None (the loop
        // books that as a drop) and pass real jobs through untouched.
        let mut bad = item(1.0, 7);
        bad.0.charge.survives = true;
        assert!(bad.0.charge.survives && bad.0.job.is_none(), "malformed by construction");
        let mut dropped = 0usize;
        match take_survivor_job(bad.0.job, bad.0.seq, bad.0.cid) {
            Some(_) => panic!("jobless survivor must not yield a job"),
            None => dropped += 1,
        }
        assert_eq!(dropped, 1, "the malformed event books as a drop");
        // a well-formed survivor's job passes through intact
        let empty = crate::data::synthetic::Dataset {
            x: Vec::new(),
            y: Vec::new(),
            classes: 2,
        };
        let job = PendingJob {
            data: ClientData {
                source: crate::data::loader::Source::Image(Arc::new(empty)),
                indices: Vec::new(),
            },
            seeds: vec![1, 2, 3],
            s_block: 3,
            global: Arc::new(ParamVec::zeros(4)),
        };
        let out = take_survivor_job(Some(job), 8, 1).expect("real job passes through");
        assert_eq!(out.seeds, vec![1, 2, 3]);
        assert_eq!(out.s_block, 3);
    }

    #[test]
    fn snapshots_are_shared_per_version_and_gc_clears() {
        let mut st = AsyncState::default();
        let g = ParamVec::zeros(8);
        let a = st.snapshot(3, &g);
        let b = st.snapshot(3, &g);
        assert!(Arc::ptr_eq(&a, &b), "same version must share one snapshot");
        let c = st.snapshot(4, &g);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(st.snapshots.len(), 2);
        // empty heap: nothing in flight can reference any snapshot
        st.gc_snapshots();
        assert!(st.snapshots.is_empty());
        // a live in-flight dispatch at version 4 keeps >= 4 alive only
        st.snapshot(3, &g);
        st.snapshot(4, &g);
        let mut inf = item(1.0, 0);
        inf.0.version = 4;
        st.heap.push(inf);
        st.gc_snapshots();
        assert_eq!(st.snapshots.len(), 1);
        assert_eq!(st.snapshots[0].0, 4);
    }
}
