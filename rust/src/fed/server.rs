//! The federated coordinator: Algorithm 1's two-phase loop.
//!
//! Phase 1 (rounds 0..pivot): FedAvg/FedAdam over high-resource clients
//! only — the warm-up that makes from-scratch ZO training feasible.
//! Phase 2 (rounds pivot..total): the seed-based SPSA protocol over *all*
//! clients (optionally mixed with continued FO updates for the §A.4
//! ablation).

use std::time::Instant;

use crate::comm::CommLedger;
use crate::config::FedConfig;
use crate::data::loader::{eval_chunks, ClientData, Source};
use crate::fed::aggregate::{weighted_average, ServerOptState};
use crate::fed::client::{warm_local_train, zo_step_chunks, ClientState, Resource};
use crate::metrics::{Phase, RoundRecord, RunLog};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::params::ParamVec;
use crate::util::rng::Xoshiro256;
use crate::zo::{apply_zo_update, zo_round_bytes, zoopt, SeedIssuer, ZoContribution};

/// Full federation state for one training run.
pub struct Federation<'b, B: ModelBackend> {
    pub cfg: FedConfig,
    pub backend: &'b B,
    pub clients: Vec<ClientState>,
    pub test: Source,
    pub global: ParamVec,
    pub round: usize,
    pub log: RunLog,
    pub ledger: CommLedger,
    server_opt: ServerOptState,
    issuer: SeedIssuer,
    rng: Xoshiro256,
}

/// Assign resource classes: the first `hi_count` of a seed-shuffled client
/// order are high-resource ("clients are randomly assigned", §4).
pub fn assign_resources(k: usize, hi_count: usize, seed: u64) -> Vec<Resource> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0x4E50_11);
    let mut order: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut order);
    let mut out = vec![Resource::Low; k];
    for &i in order.iter().take(hi_count.min(k)) {
        out[i] = Resource::High;
    }
    out
}

impl<'b, B: ModelBackend> Federation<'b, B> {
    /// Build a federation from per-client shards and a test source.
    /// `init` seeds the global weights (callers init via manifest He-init
    /// for XLA backends, zeros for the linear probe).
    pub fn new(
        cfg: FedConfig,
        backend: &'b B,
        shards: Vec<ClientData>,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(shards.len() == cfg.clients, "shard count != clients");
        anyhow::ensure!(init.dim() == backend.dim(), "init dim mismatch");
        let classes = assign_resources(cfg.clients, cfg.hi_count(), cfg.seed);
        let clients = shards
            .into_iter()
            .zip(classes)
            .enumerate()
            .map(|(id, (data, resource))| ClientState { id, data, resource })
            .collect();
        let server_opt = ServerOptState::new(cfg.server_opt, backend.dim());
        let issuer = SeedIssuer::new(cfg.seed ^ 0x5EED_1557);
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xFED_0_FED);
        Ok(Self {
            cfg,
            backend,
            clients,
            test,
            global: init,
            round: 0,
            log: RunLog::default(),
            ledger: CommLedger::default(),
            server_opt,
            issuer,
            rng,
        })
    }

    pub fn high_ids(&self) -> Vec<usize> {
        self.clients
            .iter()
            .filter(|c| c.is_high())
            .map(|c| c.id)
            .collect()
    }

    /// Evaluate the current global weights on the server's test set.
    pub fn eval(&self) -> anyhow::Result<LossSums> {
        let mut sums = LossSums::default();
        for b in eval_chunks(&self.test, self.backend.batch_size()) {
            sums.add(self.backend.fwd_loss(&self.global, &b)?);
        }
        Ok(sums)
    }

    /// One warm round (Algorithm 1 lines 2-8).
    pub fn warm_round(&mut self) -> anyhow::Result<f64> {
        let hi = self.high_ids();
        anyhow::ensure!(!hi.is_empty(), "no high-resource clients to warm up");
        let p = self.cfg.sample_warm.clamp(1, hi.len());
        let picked: Vec<usize> = self
            .rng
            .choose(hi.len(), p)
            .into_iter()
            .map(|i| hi[i])
            .collect();

        let mut updates: Vec<(ParamVec, f64)> = Vec::with_capacity(p);
        let mut train = LossSums::default();
        for &cid in &picked {
            let mut crng = Xoshiro256::seed_from(
                self.cfg.seed ^ (self.round as u64) << 20 ^ cid as u64,
            );
            let (w, sums) = warm_local_train(
                self.backend,
                &self.global,
                &self.clients[cid].data,
                &self.cfg,
                &mut crng,
            )?;
            train.add(sums);
            updates.push((w, self.clients[cid].n() as f64));
        }
        let avg = weighted_average(&updates);
        let mut delta = avg;
        delta.axpy(-1.0, &self.global);
        self.server_opt
            .apply(&mut self.global, &delta, self.cfg.lr_server_warm);

        // full weights both ways, per participating client
        let d4 = (self.backend.dim() * 4) as u64;
        self.ledger.record_round(d4 * p as u64, d4 * p as u64);
        Ok(train.mean_loss())
    }

    /// One ZO round (Algorithm 1 lines 11-21).
    pub fn zo_round(&mut self) -> anyhow::Result<f64> {
        // Q ⊆ K — all resource classes participate in step 2. With
        // mixed_step2 (§A.4 ablation) the sampled high-res clients do FO
        // updates instead.
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients);
        let picked = self.rng.choose(self.cfg.clients, q);

        let mut contributions: Vec<ZoContribution> = Vec::new();
        let mut fo_updates: Vec<(ParamVec, f64)> = Vec::new();
        let mut train = LossSums::default();
        let mut fo_participants = 0usize;
        for &cid in &picked {
            let client = &self.clients[cid];
            if self.cfg.mixed_step2 && client.is_high() {
                let mut crng = Xoshiro256::seed_from(
                    self.cfg.seed ^ (self.round as u64) << 20 ^ cid as u64,
                );
                let (w, sums) =
                    warm_local_train(self.backend, &self.global, &client.data, &self.cfg, &mut crng)?;
                train.add(sums);
                fo_updates.push((w, client.n() as f64));
                fo_participants += 1;
                continue;
            }
            let groups = zo_step_chunks(
                &client.data,
                self.backend.batch_size(),
                self.cfg.zo.grad_steps,
            );
            let steps = groups.len();
            let seeds = self
                .issuer
                .seeds_for(self.round, cid, self.cfg.zo.s_seeds * steps);
            let deltas = zoopt(
                self.backend,
                &self.global,
                &groups,
                &seeds,
                &self.cfg.zo,
                self.cfg.lr_client_zo,
            )?;
            contributions.push(ZoContribution {
                client: cid,
                seeds,
                delta_l: deltas,
                n_samples: client.n(),
            });
        }

        // ZOUPDATE: reconstruct the aggregated step from (seed, ΔL) pairs.
        let lr = self.cfg.lr_client_zo * self.cfg.lr_server_zo;
        apply_zo_update(&mut self.global, &contributions, &self.cfg.zo, lr);

        // mixed step-2: fold FO updates in afterwards (weighted FedAvg step)
        if !fo_updates.is_empty() {
            let avg = weighted_average(&fo_updates);
            let mut delta = avg;
            delta.axpy(-1.0, &self.global);
            // scale FO influence by its share of participants
            let share = fo_participants as f32 / q as f32;
            self.server_opt
                .apply(&mut self.global, &delta, self.cfg.lr_server_warm * share);
        }

        // comm accounting
        let zo_participants = contributions.len();
        let (up_per, down_per) = zo_round_bytes(
            self.cfg.zo.s_seeds * self.cfg.zo.grad_steps,
            zo_participants,
        );
        let d4 = (self.backend.dim() * 4) as u64;
        let up = up_per * zo_participants as u64 + d4 * fo_participants as u64;
        let down = down_per * q as u64 + d4 * fo_participants as u64;
        self.ledger.record_round(up, down);

        // training signal: mean |ΔL| is the ZO-phase progress proxy; report
        // the mean loss at w via the contributions' side data when FO ran.
        let mean_abs_dl = {
            let all: Vec<f64> = contributions
                .iter()
                .flat_map(|c| c.delta_l.iter().cloned())
                .collect();
            if all.is_empty() {
                train.mean_loss()
            } else {
                all.iter().map(|d| d.abs()).sum::<f64>() / all.len() as f64
            }
        };
        Ok(mean_abs_dl)
    }

    /// Run one round (phase chosen by the pivot), with eval + logging.
    pub fn step(&mut self) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let (phase, train_signal) = if self.round < self.cfg.pivot {
            (Phase::Warm, self.warm_round()?)
        } else {
            (Phase::Zo, self.zo_round()?)
        };
        let do_eval = self.round % self.cfg.eval_every == 0
            || self.round + 1 == self.cfg.rounds_total
            || self.round + 1 == self.cfg.pivot;
        let (test_acc, test_loss) = if do_eval {
            let e = self.eval()?;
            (e.accuracy(), e.mean_loss())
        } else {
            (f64::NAN, f64::NAN)
        };
        let (up, down) = *self.ledger.per_round.last().unwrap_or(&(0, 0));
        self.log.push(RoundRecord {
            round: self.round,
            phase,
            train_loss: train_signal,
            test_acc,
            test_loss,
            bytes_up: up,
            bytes_down: down,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        self.round += 1;
        Ok(())
    }

    /// Run to completion.
    pub fn run(&mut self) -> anyhow::Result<()> {
        while self.round < self.cfg.rounds_total {
            self.step()?;
        }
        Ok(())
    }
}

/// Build per-client shards from a Dirichlet partition over a source.
pub fn shards_from_partition(
    source: &Source,
    partition: &crate::data::dirichlet::Partition,
) -> Vec<ClientData> {
    partition
        .clients
        .iter()
        .map(|idx| ClientData {
            source: source.clone(),
            indices: idx.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dirichlet::dirichlet_split;
    use crate::data::synthetic::{train_test, SynthKind};
    use crate::model::backend::LinearBackend;
    use std::sync::Arc;

    fn build(cfg: FedConfig) -> (LinearBackend, Vec<ClientData>, Source) {
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
        (be, shards, Source::Image(Arc::new(test)))
    }

    fn smoke_cfg() -> FedConfig {
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg
    }

    #[test]
    fn resource_assignment_counts() {
        let r = assign_resources(20, 6, 0);
        assert_eq!(r.iter().filter(|&&x| x == Resource::High).count(), 6);
        assert_eq!(assign_resources(20, 6, 0), assign_resources(20, 6, 0));
        assert_ne!(assign_resources(20, 6, 0), assign_resources(20, 6, 1));
    }

    #[test]
    fn full_run_improves_over_random() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let acc = fed.log.final_accuracy();
        assert!(acc > 0.2, "final acc {acc} should beat random (0.1)");
        assert_eq!(fed.round, fed.cfg.rounds_total);
        // both phases logged
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Warm));
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Zo));
    }

    #[test]
    fn zo_phase_adds_accuracy_over_warm_only() {
        // the paper's core claim at miniature scale: continuing with ZO
        // (all clients) beats stopping at the pivot.
        let mut cfg = smoke_cfg();
        cfg.rounds_total = 30;
        cfg.pivot = 10;
        cfg.hi_frac = 0.25;
        cfg.eval_every = 1;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let curve = fed.log.accuracy_curve();
        let at_pivot = curve
            .iter()
            .find(|(r, _)| *r == fed.cfg.pivot - 1)
            .map(|(_, a)| *a)
            .unwrap();
        let final_acc = fed.log.final_accuracy();
        // SPSA is noisy at this miniature scale; assert no collapse here.
        // The paper's "ZO adds accuracy over High-Res-Only" claim is
        // validated at experiment scale in exp/table2 + integration tests.
        assert!(
            final_acc > at_pivot - 0.06,
            "ZO phase should not collapse: pivot {at_pivot} -> final {final_acc}"
        );
    }

    #[test]
    fn comm_costs_drop_after_pivot() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let warm_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Warm)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        let zo_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Zo)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        assert!(
            zo_up * 1000 < warm_up,
            "ZO up-link ({zo_up} B) must be orders below FO ({warm_up} B)"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = smoke_cfg();
        let run = |cfg: FedConfig| {
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log.final_accuracy())
        };
        let (g1, a1) = run(cfg.clone());
        let (g2, a2) = run(cfg);
        assert_eq!(g1, g2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn mixed_step2_also_runs() {
        let mut cfg = smoke_cfg();
        cfg.mixed_step2 = true;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.final_accuracy() > 0.15);
    }

    #[test]
    fn high_res_only_is_pivot_equals_total() {
        let mut cfg = smoke_cfg();
        cfg.pivot = cfg.rounds_total;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.rounds.iter().all(|r| r.phase == Phase::Warm));
    }
}
