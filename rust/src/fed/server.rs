//! The federated coordinator: Algorithm 1's two-phase loop.
//!
//! Phase 1 (rounds 0..pivot): FedAvg/FedAdam over high-resource clients
//! only — the warm-up that makes from-scratch ZO training feasible.
//! Phase 2 (rounds pivot..total): the seed-based SPSA protocol over *all*
//! clients (optionally mixed with continued FO updates for the §A.4
//! ablation).
//!
//! ## Threading model
//!
//! Client-local work inside a round is embarrassingly parallel, so both
//! round kinds fan the sampled clients out over a scoped thread pool
//! ([`crate::util::pool::parallel_map_n`]). The engine guarantees results
//! **bit-identical to the sequential path for every worker count**:
//!
//! 1. every per-client random input (local-SGD RNG, issued seed block,
//!    and the `sim` capability timeline deciding who drops mid-round) is
//!    derived *before* the fan-out from `(master seed, round, client id)`
//!    or the stateless [`SeedIssuer`], never from shared mutable RNG state
//!    inside a job;
//! 2. jobs are pure `Send` functions of `(global weights, shard, inputs)`
//!    — all mutation of the federation (ledger, server optimizer, log)
//!    happens after the join;
//! 3. contributions fold back in sampled-client order, and the fused
//!    ZOUPDATE applies them in one order-canonicalized pass
//!    (`perturb_axpy_many_sharded`, itself sharded across the same worker
//!    budget with bit-exact stream fast-forwarding).
//!
//! Worker count comes from `FedConfig::threads` (`0` = auto: the
//! `ZOWARMUP_THREADS` env override, else available parallelism).

use std::time::Instant;

use crate::ckpt::CheckpointStore;
use crate::comm::{CommLedger, CostModel};
use crate::config::{EngineKind, FedConfig};
use crate::data::loader::{eval_chunks, ClientData, Source};
use crate::fed::aggregate::{weighted_average, ServerOptState};
use crate::fed::client::{
    clients_from_profiles, round_client_rng, warm_local_train, zo_step_chunks, zo_step_count,
    Resource,
};
use crate::fed::population::{Population, SparseSync};
use crate::metrics::{Phase, RoundRecord, RunLog};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::params::{perturb_axpy_many_sharded_kernel, ParamVec};
use crate::sim::{self, CapabilityProfile, Scenario};
use crate::util::pool::{parallel_map_n, resolve_workers};
use crate::util::rng::Xoshiro256;
use crate::zo::{
    zo_round_ledger_outcomes, zo_round_ledger_outcomes_per_edge, zo_update_items,
    zo_update_items_two_tier, zoopt, SeedIssuer, ZoClientCharge, ZoContribution,
};

/// Full federation state for one training run.
pub struct Federation<'b, B: ModelBackend> {
    pub cfg: FedConfig,
    pub backend: &'b B,
    /// the client population — materialized (seed-era, O(N) state) or
    /// lazy (fleet-scale, O(1) state; see `fed::population`)
    pub pop: Population,
    pub test: Source,
    pub global: ParamVec,
    pub round: usize,
    pub log: RunLog,
    pub ledger: CommLedger,
    /// the backend's eq. 4/5 cost profile — the capability thresholds
    /// and simulated timing of the `sim` scenario engine
    pub cost: CostModel,
    /// server-side checkpoint + compacted seed log (`cfg.ckpt_every`;
    /// inert when 0 — see the `ckpt` module)
    pub ckpt: CheckpointStore,
    /// per-client sync ledger: `synced.get(c) = r` means client c can
    /// reconstruct the global parameters *entering* round r (it received
    /// every broadcast through round r−1). Everyone starts at 0 (init
    /// weights). The gap `round − synced.get(c)` is what catch-up must
    /// cover. Sparse: only clients that ever deviated from 0 occupy
    /// memory, so the ledger is O(participants), never O(N).
    pub synced: SparseSync,
    /// dense mirror of `synced`, maintained only under `cfg(test)` — and
    /// only for materialized populations, so test builds of 10^7-client
    /// lazy federations don't resurrect the O(N) vector the layer
    /// removes — pinning the sparse fold's equivalence with the seed-era
    /// `Vec<usize>` ledger on real churn runs
    /// (`sparse_synced_reproduces_dense_ledger_on_churn`)
    #[cfg(test)]
    pub synced_dense_mirror: Option<Vec<usize>>,
    /// server model-version counter: increments once per
    /// parameter-mutating fold (warm aggregate, non-empty ZO fold,
    /// buffered-async fold). The async engine stamps every dispatch with
    /// the version it computed against, and `now − v` is its staleness.
    pub model_version: usize,
    pub(crate) server_opt: ServerOptState,
    pub(crate) issuer: SeedIssuer,
    pub(crate) rng: Xoshiro256,
    /// discrete-event state of the buffered-async engine (`fed::engine`);
    /// lazily created on the first async round, `None` under sync
    pub(crate) async_state: Option<Box<crate::fed::engine::AsyncState>>,
}

/// One round's outcome as seen by the logger.
#[derive(Debug, Clone, Copy)]
pub struct RoundSummary {
    /// the round's training signal (always finite; see [`zo_train_signal`])
    pub train_signal: f64,
    /// sampled clients that missed the deadline, failed mid-round, could
    /// not fit even the ZO footprint, or were absent / not yet joined
    pub dropped: usize,
    /// catch-up downlink actually transmitted this round (`ckpt`
    /// subsystem; 0 with checkpointing disabled or in warm rounds)
    pub catch_up_down: u64,
    /// total probes the server derived for this round's ZO participants
    /// (dropouts included — seeds are issued before the timeline runs);
    /// 0 in warm rounds. Uniform `sample_zo · S · steps` with
    /// `adaptive_s` off, heterogeneous per-client budgets with it on.
    pub seeds_issued: usize,
    /// effective variance of the aggregated SPSA step
    /// ([`crate::zo::effective_variance`]); always finite, 0.0 in warm
    /// or empty rounds
    pub eff_var: f64,
    /// mean model-version staleness of the contributions the fold
    /// accepted (buffered-async engine; 0.0 under the sync barrier)
    pub staleness: f64,
    /// simulated wall-clock makespan of the round in scenario ms: under
    /// the barrier, the slowest simulated participant (dropout cuts
    /// included); under the async engine, the event-clock span the fold
    /// consumed
    pub makespan_ms: f64,
    /// sampled clients lost because their *edge aggregator* was down
    /// this round ([`sim::edge_failed`] against the scenario's
    /// per-edge failure rate) — a subset of `dropped`. Always 0 unless
    /// the scenario declares edge profiles (`geo-*` presets / custom
    /// `"edges"` JSON).
    pub edge_drops: usize,
}

/// One sampled ZO participant's resolved pre-round inputs — the unit the
/// adaptive probe-budget planner works over (see
/// [`Federation::zo_probe_budgets`]). Carries the resolved profile and
/// sample count so the round engine touches the population layer exactly
/// once per sampled client — the O(sampled) discipline.
pub(crate) struct ZoCandidate {
    pub(crate) cid: usize,
    /// the edge aggregator this client's traffic routes through
    /// (`sim::edge_of`; 0 in flat runs)
    pub(crate) edge: usize,
    /// the client's capability profile (lazy mode derives it on demand),
    /// bottlenecked through its edge backhaul when the scenario models
    /// edges ([`sim::edge_adjusted_profile`])
    pub(crate) profile: CapabilityProfile,
    /// local sample count n_j
    pub(crate) n: usize,
    /// local `grad_steps` blocks this client actually runs
    pub(crate) steps: usize,
    /// catch-up downlink fronting its download leg (`ckpt` subsystem)
    pub(crate) catch_bytes: u64,
    /// fused items it replays locally during catch-up
    pub(crate) replay_items: usize,
}

/// Classification verdict for one sampled client entering a round — the
/// shared head of the classify→plan→simulate→contribute client path,
/// used identically by the sync barrier (`zo_round`, `planned_seed_counts`)
/// and the async event engine (`fed::engine`).
pub(crate) enum ClientClass {
    /// absent / not yet joined (churn trace), or below even the eq. 5 ZO
    /// memory footprint — transmits nothing
    Dropped,
    /// runs a local FO update this round (`mixed_step2` high-res arm)
    Fo { n: usize },
    /// seed-protocol participant
    Zo,
}

/// Clamp a training signal to the finite domain the CSV log expects
/// (shared by every round engine, including the baselines).
pub(crate) fn finite_signal(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Assign resource classes ("clients are randomly assigned", §4).
///
/// Compatibility shim over `sim` profile sampling: the Binary scenario
/// consumes the identical RNG stream the seed repo's implementation did
/// (one shuffle of `0..k` seeded from `seed ^ sim::ASSIGN_SALT`, first
/// `hi_count` of the order high-resource), so seed-equivalent configs
/// reproduce the exact same High/Low assignment. Symbolic tier budgets
/// make the split independent of the cost model used to resolve them.
pub fn assign_resources(k: usize, hi_count: usize, seed: u64) -> Vec<Resource> {
    let cost = CostModel::generic(1 << 20, 1);
    Scenario::Binary
        .sample_profiles(k, hi_count.min(k), seed, &cost)
        .iter()
        .map(|p| {
            if p.fo_capable(&cost) {
                Resource::High
            } else {
                Resource::Low
            }
        })
        .collect()
}

impl<'b, B: ModelBackend> Federation<'b, B> {
    /// Build a federation from per-client shards and a test source — the
    /// seed-era **materialized** path, bit-compatible with every
    /// historical trace. `init` seeds the global weights (callers init
    /// via manifest He-init for XLA backends, zeros for the linear
    /// probe).
    pub fn new(
        cfg: FedConfig,
        backend: &'b B,
        shards: Vec<ClientData>,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        // validate before hi_count(): its clamp(1, clients) panics on the
        // clients == 0 configs validate exists to reject (the re-check in
        // with_population is then a cheap no-op)
        cfg.validate()?;
        anyhow::ensure!(shards.len() == cfg.clients, "shard count != clients");
        let cost = backend.cost_model();
        let profiles = cfg
            .scenario
            .sample_profiles(cfg.clients, cfg.hi_count(), cfg.seed, &cost);
        let clients = clients_from_profiles(shards, profiles, &cost);
        Self::with_population(cfg, backend, Population::materialized(clients), test, init)
    }

    /// Build a federation over a **lazy** population drawing shards from
    /// `source`: per-client profiles and data derive on demand, so setup
    /// is O(1) and every round costs O(sampled) — the fleet-scale path
    /// (`--clients 10000000`).
    pub fn new_lazy(
        cfg: FedConfig,
        backend: &'b B,
        source: Source,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let cost = backend.cost_model();
        let pop = Population::lazy(
            cfg.clients,
            cfg.hi_count(),
            cfg.seed,
            cfg.scenario.clone(),
            cost,
            source,
        )?;
        Self::with_population(cfg, backend, pop, test, init)
    }

    /// Shared constructor over an already-built [`Population`].
    pub fn with_population(
        cfg: FedConfig,
        backend: &'b B,
        pop: Population,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(pop.len() == cfg.clients, "population size != clients");
        anyhow::ensure!(init.dim() == backend.dim(), "init dim mismatch");
        let cost = backend.cost_model();
        if cfg.pivot > 0 {
            anyhow::ensure!(
                pop.any_fo_capable(&cost),
                "scenario {:?} yields no FO-capable clients but pivot > 0",
                cfg.scenario.name()
            );
        }
        let server_opt = ServerOptState::new(cfg.server_opt, backend.dim());
        let issuer = SeedIssuer::new(cfg.seed ^ 0x5EED_1557);
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xFED_0_FED);
        let ckpt = CheckpointStore::new(cfg.ckpt_every, &init);
        Ok(Self {
            #[cfg(test)]
            synced_dense_mirror: (!pop.is_lazy()).then(|| vec![0usize; cfg.clients]),
            cfg,
            backend,
            pop,
            test,
            global: init,
            round: 0,
            log: RunLog::default(),
            ledger: CommLedger::default(),
            cost,
            ckpt,
            synced: SparseSync::default(),
            model_version: 0,
            server_opt,
            issuer,
            rng,
            async_state: None,
        })
    }

    /// Fold `synced[cid] = max(synced[cid], round)` — the single place
    /// the sync ledger advances, so the `cfg(test)` dense mirror stays a
    /// faithful replica of the sparse fold.
    pub(crate) fn mark_synced(&mut self, cid: usize, round: usize) {
        self.synced.advance(cid, round);
        #[cfg(test)]
        if let Some(mirror) = &mut self.synced_dense_mirror {
            if round > mirror[cid] {
                mirror[cid] = round;
            }
        }
    }

    /// Evaluate the current global weights on the server's test set.
    pub fn eval(&self) -> anyhow::Result<LossSums> {
        let mut sums = LossSums::default();
        for b in eval_chunks(&self.test, self.backend.batch_size()) {
            sums.add(self.backend.fwd_loss(&self.global, &b)?);
        }
        Ok(sums)
    }

    /// Per-(round, client) local RNG (see [`round_client_rng`]).
    fn client_rng(&self, cid: usize) -> Xoshiro256 {
        round_client_rng(self.cfg.seed, 0, self.round, cid)
    }

    /// Effective worker count for this run (see module docs).
    pub fn workers(&self) -> usize {
        resolve_workers(self.cfg.threads)
    }

    /// The edge aggregator client `cid`'s traffic routes through under
    /// the two-tier topology (`--edges E`). Deterministic keyed
    /// assignment; 0 for every client in flat runs (`edges == 1`).
    pub fn edge_of(&self, cid: usize) -> usize {
        sim::edge_of(cid, self.cfg.edges, self.cfg.seed)
    }

    /// Whether edge `edge`'s aggregator is down for `round` — its whole
    /// cohort transmits nothing and counts as `edge_drops`. Only
    /// scenarios that model edges can fail one (plain scenarios keep
    /// `--edges E` pure attribution, byte-identical to the flat engine).
    pub(crate) fn edge_is_down(&self, edge: usize, round: usize) -> bool {
        match self.cfg.scenario.edge_profile(edge) {
            Some(ep) => sim::edge_failed(self.cfg.seed, round, edge, ep.failure_rate),
            None => false,
        }
    }

    /// A client's effective capability profile behind its edge: the
    /// bottleneck of its own link and the edge backhaul when the
    /// scenario declares edge profiles; the unmodified profile otherwise.
    pub(crate) fn edge_profile_of(
        &self,
        edge: usize,
        profile: CapabilityProfile,
    ) -> CapabilityProfile {
        match self.cfg.scenario.edge_profile(edge) {
            Some(ep) => sim::edge_adjusted_profile(&profile, ep),
            None => profile,
        }
    }

    /// Classify one sampled client for round `round`: the exact
    /// availability → FO-role → ZO-capability decision chain both round
    /// engines share. Consumes no RNG ([`sim::is_available`] derives its
    /// own keyed stream), so classification order is invisible to every
    /// trace stream.
    pub(crate) fn classify(
        &self,
        cid: usize,
        profile: &CapabilityProfile,
        round: usize,
    ) -> ClientClass {
        // churn trace: late joiners and whole-round absences transmit
        // nothing and stay stale
        if !sim::is_available(profile, self.cfg.seed, round, cid) {
            return ClientClass::Dropped;
        }
        if self.cfg.mixed_step2 && profile.fo_capable(&self.cost) {
            return ClientClass::Fo {
                n: self.pop.n_samples(cid),
            };
        }
        if profile.zo_capable(&self.cost) {
            ClientClass::Zo
        } else {
            // below even the eq. 5 ZO footprint: cannot participate
            ClientClass::Dropped
        }
    }

    /// An FO participant's planned round timeline: full weights down,
    /// `local_epochs` backprop passes, full weights up — shared by the
    /// warm engine and the mixed-step-2 arm.
    fn fo_plan(&self, n: usize, d4: u64) -> sim::RoundPlan {
        sim::RoundPlan {
            down_bytes: d4,
            passes: sim::fo_passes(n, self.cfg.local_epochs),
            up_bytes: d4,
        }
    }

    /// One warm round (Algorithm 1 lines 2-8). Sampled clients train in
    /// parallel; see the module-level threading model for the
    /// determinism argument.
    ///
    /// Every picked client first runs its simulated capability timeline
    /// ([`sim::simulate_round`]): clients that miss the scenario deadline
    /// or fail on their availability trace drop out mid-round — the
    /// server aggregates only survivors and the ledger charges only the
    /// bytes on the wire before each drop. The simulation is evaluated
    /// *before* the fan-out from pure per-(round, client) inputs, so it
    /// cannot perturb the worker-count invariance.
    pub fn warm_round(&mut self) -> anyhow::Result<RoundSummary> {
        // materialized mode reproduces the seed repo's hi-list choose
        // stream exactly; lazy mode rejection-samples the FO-capable
        // sub-population (see Population::sample_high)
        let picked = self
            .pop
            .sample_high(&mut self.rng, self.cfg.sample_warm, &self.cost)?;
        let p = picked.len();

        // simulate each picked client's timeline, then derive survivor
        // RNGs and fetch survivor shards, all before the fan-out
        // (determinism rule 1). Only the O(sampled) picked clients ever
        // touch the population layer.
        let d4 = (self.backend.dim() * 4) as u64;
        let two_tier = self.cfg.edges > 1;
        let has_edge_model = self.cfg.scenario.has_edge_profiles();
        let mut jobs: Vec<(usize, usize, ClientData, Xoshiro256)> = Vec::with_capacity(p);
        let (mut up, mut down) = (0u64, 0u64);
        let mut edge_bytes = vec![(0u64, 0u64); if two_tier { self.cfg.edges } else { 0 }];
        let mut dropped = 0usize;
        let mut edge_drops = 0usize;
        let mut makespan_ms = 0.0f64;
        for &cid in &picked {
            let edge = self.edge_of(cid);
            // a failed edge aggregator loses its whole cohort for the
            // round before anything is transmitted (keyed per-edge trace;
            // never fires unless the scenario models edges)
            if has_edge_model && self.edge_is_down(edge, self.round) {
                dropped += 1;
                edge_drops += 1;
                continue;
            }
            let profile = self.edge_profile_of(edge, self.pop.profile(cid));
            let n = self.pop.n_samples(cid);
            // churn trace: late joiners and whole-round absences transmit
            // nothing and stay stale
            if !sim::is_available(&profile, self.cfg.seed, self.round, cid) {
                dropped += 1;
                continue;
            }
            let plan = self.fo_plan(n, d4);
            let deadline = self.cfg.scenario.edge_deadline_ms(edge);
            let mut trace = round_client_rng(self.cfg.seed, sim::SIM_SALT, self.round, cid);
            let o = sim::simulate_round(&profile, &plan, self.cost.params, deadline, &mut trace);
            up += o.up_bytes;
            down += o.down_bytes;
            if two_tier {
                edge_bytes[edge].0 += o.up_bytes;
                edge_bytes[edge].1 += o.down_bytes;
            }
            // barrier semantics: the round lasts until its slowest
            // simulated participant finishes (or is cut)
            makespan_ms = makespan_ms.max(o.sim_ms);
            if o.down_bytes == plan.down_bytes {
                // a completed full-weight download IS a sync: the client
                // now holds the global entering this round
                self.mark_synced(cid, self.round);
            }
            if o.survives {
                jobs.push((cid, n, self.pop.data(cid), self.client_rng(cid)));
            } else {
                dropped += 1;
            }
        }
        let workers = self.workers();
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let cfg = &self.cfg;
            parallel_map_n(workers, jobs, move |(cid, n, data, mut crng)| {
                warm_local_train(backend, global, &data, cfg, &mut crng)
                    .map(|out| (cid, n, out))
            })
        };

        // fold in sampled order (determinism rule 3)
        let mut updates: Vec<(ParamVec, f64)> = Vec::with_capacity(p);
        let mut train = LossSums::default();
        for r in results {
            let (_cid, n, (w, sums)) = r?;
            train.add(sums);
            updates.push((w, n as f64));
        }
        // partial/zero transmissions are already folded into up/down
        self.ledger.record_round(up, down);
        if two_tier {
            // split the flat round across the edges it crossed (pure
            // attribution; sums reduce to (up, down) bit-exactly)
            for (e, &(eu, ed)) in edge_bytes.iter().enumerate() {
                self.ledger.record_edge_round(e, eu, ed);
            }
        }
        if updates.is_empty() {
            // every sampled client dropped: no aggregate step — the
            // identity round is seed-replayable with an empty item list,
            // so a catch-up tail can cross it
            self.ckpt.record_seed_round(self.round, Vec::new(), &self.global);
            return Ok(RoundSummary {
                train_signal: 0.0,
                dropped,
                catch_up_down: 0,
                seeds_issued: 0,
                eff_var: 0.0,
                staleness: 0.0,
                makespan_ms,
                edge_drops,
            });
        }
        let avg = weighted_average(&updates);
        let mut delta = avg;
        delta.axpy(-1.0, &self.global);
        self.server_opt
            .apply(&mut self.global, &delta, self.cfg.lr_server_warm);
        self.model_version += 1;
        // a FedAvg step cannot be replayed from seeds: snapshot after it
        self.ckpt.record_opaque(self.round, &self.global);
        Ok(RoundSummary {
            train_signal: finite_signal(train.mean_loss()),
            dropped,
            catch_up_down: 0,
            seeds_issued: 0,
            eff_var: 0.0,
            staleness: 0.0,
            makespan_ms,
            edge_drops,
        })
    }

    /// One ZO participant's resolved round inputs, gathered before the
    /// probe-budget planning pass: its profile and sample count (one
    /// population-layer touch), its local step count, and the catch-up
    /// charge fronting its download leg (`ckpt` subsystem).
    pub(crate) fn zo_candidate(&self, cid: usize, profile: CapabilityProfile, d4: u64) -> ZoCandidate {
        let catch_plan = self.ckpt.catch_up_plan(self.synced.get(cid), self.round, d4);
        let n = self.pop.n_samples(cid);
        let edge = self.edge_of(cid);
        // behind a modeled edge the whole timeline — catch-up download
        // included (served from the edge-local checkpoint cache) — runs
        // at the bottlenecked rates, so catch-up is charged at edge rates
        let profile = self.edge_profile_of(edge, profile);
        ZoCandidate {
            cid,
            edge,
            profile,
            n,
            steps: zo_step_count(n, self.cfg.zo.grad_steps),
            catch_bytes: catch_plan.map_or(0, |p| p.bytes),
            replay_items: catch_plan.map_or(0, |p| p.replay_items),
        }
    }

    /// The candidate's round timeline at probe count `s`: catch-up payload
    /// and seed issue down, `2·s` forward passes per sample plus the
    /// catch-up replay, ΔL scalars up — the exact plan
    /// [`sim::simulate_round`] runs, which is what makes the planner's
    /// inversion honest.
    pub(crate) fn zo_candidate_plan(&self, c: &ZoCandidate, s: usize) -> sim::RoundPlan {
        sim::RoundPlan {
            down_bytes: c.catch_bytes + (s * c.steps * 8) as u64,
            passes: sim::zo_passes(c.n, s) + sim::replay_passes(c.replay_items),
            up_bytes: (s * c.steps * 4) as u64,
        }
    }

    /// Per-candidate probe budgets S_j for one ZO round (the tentpole's
    /// planner). With `adaptive_s` off every candidate gets the uniform
    /// `cfg.zo.s_seeds` — bit-identical to the seed behavior. With it on,
    /// the round budget is the scenario deadline when one is set;
    /// otherwise the slowest candidate's uniform-S timeline (the
    /// straggler-equalization envelope: the round takes as long as it
    /// would have anyway, and faster clients convert their idle wait into
    /// extra probes). Each candidate then receives the largest
    /// `S_j ∈ [s_min, s_max]` whose full timeline — catch-up charge
    /// included — fits ([`sim::max_affordable_s`]). Deterministic: no RNG
    /// is consumed, so enabling the planner never perturbs the
    /// drop/availability trace streams.
    fn zo_probe_budgets(&self, cands: &[ZoCandidate]) -> Vec<usize> {
        let z = &self.cfg.zo;
        if !z.adaptive_s {
            return vec![z.s_seeds; cands.len()];
        }
        let deadline = self.cfg.scenario.deadline_ms();
        let budget = if deadline > 0.0 {
            deadline
        } else {
            let s_ref = z.s_seeds.clamp(z.s_min, z.s_max);
            cands
                .iter()
                .map(|c| {
                    sim::plan_time_ms(
                        &c.profile,
                        &self.zo_candidate_plan(c, s_ref),
                        self.cost.params,
                    )
                })
                .fold(0.0f64, f64::max)
        };
        cands
            .iter()
            .map(|c| {
                sim::max_affordable_s(
                    &c.profile,
                    self.cost.params,
                    budget,
                    z.s_min,
                    z.s_max,
                    |s| self.zo_candidate_plan(c, s),
                )
            })
            .collect()
    }

    /// The probe budgets the planner would issue to a round *starting
    /// now* whose ZO candidates are exactly the eligible clients among
    /// `cids` (each paired with its id) — the deterministic inspection
    /// surface behind the adaptive-S acceptance tests and
    /// `examples/adaptive_fleet.rs`. Eligibility mirrors `zo_round`'s
    /// classification pass: clients that are unavailable this round
    /// (churn), run FO under `mixed_step2`, or cannot afford even the
    /// ZO footprint are skipped — they would never enter the planner's
    /// envelope. Note a real round plans over its *sampled* Q-subset, so
    /// budgets there can differ when the sample excludes the slowest
    /// client. Uniform `s_seeds` per client with `adaptive_s` off.
    pub fn planned_seed_counts(&self, cids: &[usize]) -> Vec<(usize, usize)> {
        let d4 = (self.backend.dim() * 4) as u64;
        let cands: Vec<ZoCandidate> = cids
            .iter()
            .filter_map(|&cid| {
                let profile = self.pop.profile(cid);
                matches!(self.classify(cid, &profile, self.round), ClientClass::Zo)
                    .then(|| self.zo_candidate(cid, profile, d4))
            })
            .collect();
        let budgets = self.zo_probe_budgets(&cands);
        cands
            .iter()
            .zip(budgets)
            .map(|(c, s)| (c.cid, s))
            .collect()
    }

    /// One ZO round (Algorithm 1 lines 11-21). Sampled clients evaluate
    /// their seed blocks (or, with `mixed_step2`, run FO locally) in
    /// parallel; every random input is pre-derived and the fold-back is
    /// order-canonical, so the round is bit-identical for any worker
    /// count (see module docs).
    ///
    /// Deadline semantics: every sampled client runs its simulated
    /// capability timeline first. Dropouts contribute nothing — the
    /// server folds only surviving contributions (the finite-signal path
    /// of [`zo_train_signal`] covers the all-drop edge) — and the ledger
    /// charges each dropout only the bytes transmitted before its cut
    /// ([`zo_round_ledger_outcomes`]). Clients whose memory budget is
    /// below even the eq. 5 ZO footprint never participate and transmit
    /// nothing.
    ///
    /// Churn & catch-up: sampled clients that are absent or not yet
    /// joined ([`sim::is_available`]) transmit nothing and stay stale.
    /// With checkpointing enabled, a stale participant's timeline is
    /// fronted with the catch-up charge — the cheaper of snapshot vs
    /// tail replay ([`CheckpointStore::catch_up_plan`]), download bytes
    /// plus local replay passes — and the per-client sync ledger
    /// advances: full download ⇒ synced to this round; survival
    /// (broadcast received) ⇒ synced to the next, but only when the
    /// round stays seed-replayable (a mixed-FO fold is opaque — the
    /// broadcast alone cannot reach the post-fold global).
    ///
    /// Adaptive probe budgets (`cfg.zo.adaptive_s`): issuing happens in
    /// two passes — a classification pass resolves each sampled client's
    /// availability, FO/ZO role and catch-up charge; the planner
    /// (`Self::zo_probe_budgets`) then picks every ZO candidate's
    /// largest affordable S_j; and the simulation pass runs the exact
    /// timelines and issues `S_j · steps` seeds. All planner inputs are
    /// deterministic and consume no RNG, and the per-client trace streams
    /// are pure functions of (master seed, round, client id) — so the
    /// two-pass structure is invisible to worker-count invariance, and
    /// with the planner off the pass is operation-for-operation the seed
    /// behavior.
    pub fn zo_round(&mut self) -> anyhow::Result<RoundSummary> {
        // Q ⊆ K — all resource classes participate in step 2. With
        // mixed_step2 (§A.4 ablation) the sampled high-res clients do FO
        // updates instead.
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients);
        let picked = self.rng.choose(self.cfg.clients, q);

        enum Job {
            Fo { cid: usize, n: usize, data: ClientData, rng: Xoshiro256 },
            Zo { cid: usize, data: ClientData, seeds: Vec<u64>, s_block: usize },
        }
        enum Out {
            Fo { n: usize, w: ParamVec, sums: LossSums },
            Zo(ZoContribution),
        }
        /// classification-pass verdict per sampled client, in picked order
        enum Pending {
            Dropped,
            /// FO participant: (cid, profile, n)
            Fo(usize, CapabilityProfile, usize),
            /// index into the ZO candidate list
            Zo(usize),
        }

        // pass 1 — classification: availability, FO/ZO role, catch-up
        // charge. Pure reads; no RNG stream is touched. The population
        // layer is consulted once per sampled client (O(sampled), the
        // fleet-scale contract).
        let d4 = (self.backend.dim() * 4) as u64;
        let two_tier = self.cfg.edges > 1;
        let has_edge_model = self.cfg.scenario.has_edge_profiles();
        let mut edge_drops = 0usize;
        let mut pendings: Vec<Pending> = Vec::with_capacity(q);
        let mut cands: Vec<ZoCandidate> = Vec::with_capacity(q);
        for &cid in &picked {
            // a failed edge aggregator loses its whole cohort before
            // anything transmits (keyed per-edge trace; inert unless the
            // scenario models edges). The pre-drop is safe for worker
            // invariance: every skipped client's streams are keyed, so
            // nothing downstream shifts.
            let edge = self.edge_of(cid);
            if has_edge_model && self.edge_is_down(edge, self.round) {
                edge_drops += 1;
                pendings.push(Pending::Dropped);
                continue;
            }
            let profile = self.pop.profile(cid);
            match self.classify(cid, &profile, self.round) {
                ClientClass::Dropped => pendings.push(Pending::Dropped),
                ClientClass::Fo { n } => {
                    // FO traffic rate-limits at the edge backhaul too
                    pendings.push(Pending::Fo(cid, self.edge_profile_of(edge, profile), n))
                }
                ClientClass::Zo => {
                    // a stale client must first reconstruct the current
                    // global: the server charges the cheaper of snapshot vs
                    // tail replay (ckpt subsystem; nothing when synced or
                    // when checkpointing is disabled). Both the catch-up
                    // download and the local replay passes lead the
                    // timeline, so a tight deadline can cut either short —
                    // and both shrink the adaptive probe budget.
                    cands.push(self.zo_candidate(cid, profile, d4));
                    pendings.push(Pending::Zo(cands.len() - 1));
                }
            }
        }
        // planning — per-candidate probe budgets (uniform s_seeds with
        // the planner off)
        let budgets = self.zo_probe_budgets(&cands);

        // pass 2 — simulation + issuing: pre-derive every per-client
        // random input (determinism rule 1): the FO local RNG, the issued
        // seed block, and the capability timeline are all pure functions
        // of (master seed, round, client id) and the sampled profile.
        let mut jobs: Vec<Job> = Vec::with_capacity(q);
        let mut zo_charges: Vec<ZoClientCharge> = Vec::with_capacity(q);
        // per-edge attribution state (two-tier only): the edge of every
        // charge in zo_charges order, FO bytes per edge, and the slice
        // of catch-up downlink each edge's checkpoint cache served
        let mut charge_edges: Vec<usize> = Vec::with_capacity(q);
        let e_slots = if two_tier { self.cfg.edges } else { 0 };
        let (mut fo_up_edge, mut fo_down_edge) = (vec![0u64; e_slots], vec![0u64; e_slots]);
        let mut catch_edge = vec![0u64; e_slots];
        let (mut fo_up, mut fo_down) = (0u64, 0u64);
        let mut dropped = 0usize;
        let mut catch_up_down = 0u64;
        let mut seeds_issued = 0usize;
        let mut makespan_ms = 0.0f64;
        // ZO survivors whose sync ledger may advance to round+1 — only
        // once the round is known to be seed-replayable (no mixed-FO
        // fold), decided after the join
        let mut zo_survivors: Vec<usize> = Vec::with_capacity(q);
        for p in &pendings {
            match p {
                Pending::Dropped => dropped += 1,
                Pending::Fo(cid, profile, n) => {
                    let (cid, n) = (*cid, *n);
                    let edge = self.edge_of(cid);
                    let deadline = self.cfg.scenario.edge_deadline_ms(edge);
                    let mut trace =
                        round_client_rng(self.cfg.seed, sim::SIM_SALT, self.round, cid);
                    let plan = self.fo_plan(n, d4);
                    let o =
                        sim::simulate_round(profile, &plan, self.cost.params, deadline, &mut trace);
                    fo_up += o.up_bytes;
                    fo_down += o.down_bytes;
                    if two_tier {
                        fo_up_edge[edge] += o.up_bytes;
                        fo_down_edge[edge] += o.down_bytes;
                    }
                    makespan_ms = makespan_ms.max(o.sim_ms);
                    if o.down_bytes == plan.down_bytes {
                        // full-weight download = sync to the current round
                        self.mark_synced(cid, self.round);
                    }
                    if o.survives {
                        jobs.push(Job::Fo {
                            cid,
                            n,
                            data: self.pop.data(cid),
                            rng: self.client_rng(cid),
                        });
                    } else {
                        dropped += 1;
                    }
                }
                Pending::Zo(i) => {
                    let c = &cands[*i];
                    let cid = c.cid;
                    let s_block = budgets[*i];
                    let n_seeds = s_block * c.steps;
                    let plan = self.zo_candidate_plan(c, s_block);
                    let deadline = self.cfg.scenario.edge_deadline_ms(c.edge);
                    let mut trace =
                        round_client_rng(self.cfg.seed, sim::SIM_SALT, self.round, cid);
                    let o = sim::simulate_round(
                        &c.profile,
                        &plan,
                        self.cost.params,
                        deadline,
                        &mut trace,
                    );
                    let cu = o.down_bytes.min(c.catch_bytes);
                    catch_up_down += cu;
                    seeds_issued += n_seeds;
                    makespan_ms = makespan_ms.max(o.sim_ms);
                    if two_tier {
                        catch_edge[c.edge] += cu;
                    }
                    charge_edges.push(c.edge);
                    zo_charges.push(ZoClientCharge {
                        issued_seeds: n_seeds,
                        up_bytes: o.up_bytes,
                        seed_down_bytes: o.down_bytes,
                        survives: o.survives,
                    });
                    let caught_up = o.down_bytes >= c.catch_bytes;
                    if caught_up {
                        // the download leg is ordered catch-up first, so
                        // receiving at least `catch` bytes means the client
                        // holds the full catch-up payload — even if the seed
                        // issue (or anything later in its timeline) was cut.
                        // A replay interrupted by the deadline finishes
                        // offline before the next round (the deadline bounds
                        // round participation, not between-round local
                        // compute), so the client counts as synced and the
                        // catch-up is never re-charged.
                        self.mark_synced(cid, self.round);
                    }
                    if o.survives {
                        // survivors also receive the end-of-round broadcast;
                        // whether that reaches the *next* round's global
                        // depends on the round staying seed-replayable —
                        // resolved after the join (see zo_survivors)
                        zo_survivors.push(cid);
                        jobs.push(Job::Zo {
                            cid,
                            data: self.pop.data(cid),
                            seeds: self.issuer.seeds_for(self.round, cid, n_seeds),
                            s_block,
                        });
                    } else {
                        dropped += 1;
                    }
                }
            }
        }

        let workers = self.workers();
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let cfg = &self.cfg;
            parallel_map_n(workers, jobs, move |job| -> anyhow::Result<Out> {
                match job {
                    Job::Fo { cid: _, n, data, mut rng } => {
                        let (w, sums) = warm_local_train(backend, global, &data, cfg, &mut rng)?;
                        Ok(Out::Fo { n, w, sums })
                    }
                    Job::Zo { cid, data, seeds, s_block } => Ok(Out::Zo(run_zo_client(
                        backend, global, cfg, cid, &data, seeds, s_block,
                    )?)),
                }
            })
        };

        // fold in sampled order (determinism rule 3)
        let mut contributions: Vec<ZoContribution> = Vec::new();
        let mut fo_updates: Vec<(ParamVec, f64)> = Vec::new();
        let mut train = LossSums::default();
        for r in results {
            match r? {
                Out::Fo { n, w, sums } => {
                    train.add(sums);
                    fo_updates.push((w, n as f64));
                }
                Out::Zo(c) => contributions.push(c),
            }
        }
        let fo_participants = fo_updates.len();

        // ZOUPDATE: reconstruct the aggregated step from (seed, ΔL) pairs.
        // Intermediate grad_steps blocks replay at lr_client (matching the
        // client's local trajectory); the server lr scales only the final
        // aggregated block; each contribution's explicit block map carries
        // its heterogeneous S_j and the configured variance guard rescales
        // weights / clamps outliers inside the fold. The weight-vector
        // pass shards across the same worker budget. The item list is the
        // single artifact shared with the checkpoint seed log: replaying
        // it reproduces this exact update bit for bit, guard and all.
        let eff_var = crate::zo::effective_variance(&contributions, &self.cfg.zo);
        let items = if two_tier {
            // two-tier topology: each edge folds its own survivors into a
            // partial fused artifact, and the root merges the partials in
            // edge-index order — bit-identical to the flat fold below
            // (see `zo_update_items_two_tier`'s bit-identity contract)
            let assign: Vec<usize> =
                contributions.iter().map(|c| self.edge_of(c.client)).collect();
            let (_partials, merged) = zo_update_items_two_tier(
                &contributions,
                None,
                &assign,
                self.cfg.edges,
                &self.cfg.zo,
                self.cfg.lr_client_zo,
                self.cfg.lr_server_zo,
            );
            merged
        } else {
            // flat topology: the literal historical code path
            zo_update_items(
                &contributions,
                &self.cfg.zo,
                self.cfg.lr_client_zo,
                self.cfg.lr_server_zo,
            )
        };
        perturb_axpy_many_sharded_kernel(
            &mut self.global.0,
            &items,
            self.cfg.zo.tau,
            self.cfg.zo.dist,
            workers,
            self.cfg.zo.kernel,
        );

        if !items.is_empty() || !fo_updates.is_empty() {
            // the global moved: bump the server's model-version counter
            // (identity rounds — all-drop, all-zero-weight — hold it flat)
            self.model_version += 1;
        }

        // mixed step-2: fold FO updates in afterwards (weighted FedAvg step)
        if !fo_updates.is_empty() {
            let avg = weighted_average(&fo_updates);
            let mut delta = avg;
            delta.axpy(-1.0, &self.global);
            // scale FO influence by its share of participants
            let share = fo_participants as f32 / q as f32;
            self.server_opt
                .apply(&mut self.global, &delta, self.cfg.lr_server_warm * share);
            // the FO fold is a full-weight update no seed list can
            // replay: snapshot after it. ZO survivors received the
            // (seed, ΔL) broadcast but NOT the fold, so their sync
            // ledger must NOT advance past this round — they stay at
            // `round` (full download) and pay the snapshot path next
            // time.
            self.ckpt.record_opaque(self.round, &self.global);
        } else {
            // seed-replayable round: the broadcast lets every ZO
            // survivor reconstruct the next round's global
            let survivors = std::mem::take(&mut zo_survivors);
            for cid in survivors {
                self.mark_synced(cid, self.round + 1);
            }
            self.ckpt.record_seed_round(self.round, items, &self.global);
        }

        // comm accounting: seed traffic is charged only to ZO
        // participants (partial transmissions for dropouts — catch-up
        // bytes included — and the end-of-round broadcast of surviving
        // (seed, ΔL) pairs only to survivors); FO participants exchange
        // full weights instead.
        let (up, down) = zo_round_ledger_outcomes(&zo_charges, fo_up, fo_down);
        self.ledger.record_round(up, down);
        self.ledger.record_catch_up(catch_up_down);
        self.ledger.record_seeds(seeds_issued as u64);
        if two_tier {
            // per-edge sub-attribution of the exact flat totals above
            // (catch-up served from each edge's local checkpoint cache)
            let per_edge = zo_round_ledger_outcomes_per_edge(
                &zo_charges,
                &charge_edges,
                self.cfg.edges,
                &fo_up_edge,
                &fo_down_edge,
            );
            for (e, &(eu, ed)) in per_edge.iter().enumerate() {
                self.ledger.record_edge_round(e, eu, ed);
            }
            for (e, &cb) in catch_edge.iter().enumerate() {
                if cb > 0 {
                    self.ledger.record_edge_catch_up(e, cb);
                }
            }
        }

        Ok(RoundSummary {
            train_signal: zo_train_signal(&contributions, &train),
            dropped,
            catch_up_down,
            seeds_issued,
            eff_var,
            staleness: 0.0,
            makespan_ms,
            edge_drops,
        })
    }

    /// Run one round (phase chosen by the pivot), with eval + logging.
    /// The warm phase always runs the synchronous barrier (its FedAvg
    /// fold needs every participant's full weights at one version); the
    /// ZO phase routes through the engine `--engine` selects.
    pub fn step(&mut self) -> anyhow::Result<()> {
        // detlint: allow(wall-clock) — feeds the wall_ms observability
        // column (f12), which every CI trace diff excludes by contract
        let t0 = Instant::now();
        let (phase, summary) = if self.round < self.cfg.pivot {
            (Phase::Warm, self.warm_round()?)
        } else if self.cfg.engine == EngineKind::Async {
            (Phase::Zo, self.async_zo_round()?)
        } else {
            (Phase::Zo, self.zo_round()?)
        };
        let do_eval = self.round % self.cfg.eval_every == 0
            || self.round + 1 == self.cfg.rounds_total
            || self.round + 1 == self.cfg.pivot;
        let (test_acc, test_loss) = if do_eval {
            let e = self.eval()?;
            (e.accuracy(), e.mean_loss())
        } else {
            (f64::NAN, f64::NAN)
        };
        let (up, down) = *self.ledger.per_round.last().unwrap_or(&(0, 0));
        self.log.push(RoundRecord {
            round: self.round,
            phase,
            train_loss: summary.train_signal,
            test_acc,
            test_loss,
            bytes_up: up,
            bytes_down: down,
            dropped: summary.dropped,
            catch_up_down: summary.catch_up_down,
            seeds_issued: summary.seeds_issued,
            eff_var: summary.eff_var,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            staleness: summary.staleness,
            model_version: self.model_version,
            makespan_ms: summary.makespan_ms,
            edge_drops: summary.edge_drops,
        });
        self.round += 1;
        Ok(())
    }

    /// Run to completion.
    pub fn run(&mut self) -> anyhow::Result<()> {
        while self.round < self.cfg.rounds_total {
            self.step()?;
        }
        Ok(())
    }
}

/// ZO-phase training signal for one round: mean |ΔL| over every
/// contribution (the SPSA progress proxy); a mixed round with no ZO
/// contributions falls back to the FO participants' mean loss; a fully
/// empty round reports 0.0. Always finite — the signal is logged as the
/// round's `train_loss` and must never poison the CSV with NaN.
pub fn zo_train_signal(contributions: &[ZoContribution], fo_train: &LossSums) -> f64 {
    let (sum, n) = contributions
        .iter()
        .flat_map(|c| c.delta_l.iter())
        .fold((0.0f64, 0usize), |(s, k), d| (s + d.abs(), k + 1));
    let v = if n > 0 {
        sum / n as f64
    } else if fo_train.count > 0.0 {
        fo_train.mean_loss()
    } else {
        0.0
    };
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// One ZO participant's local computation: evaluate the issued seed
/// block against a global snapshot and return the ΔL contribution. A
/// pure function of its inputs (no shared mutable state), shared verbatim
/// by the sync fan-out (`zo_round`) and the async event engine
/// (`fed::engine`) — both engines execute the byte-identical client path.
pub(crate) fn run_zo_client<B: ModelBackend>(
    backend: &B,
    global: &ParamVec,
    cfg: &FedConfig,
    cid: usize,
    data: &ClientData,
    seeds: Vec<u64>,
    s_block: usize,
) -> anyhow::Result<ZoContribution> {
    let groups = zo_step_chunks(data, backend.batch_size(), cfg.zo.grad_steps);
    // hard seed-block invariant: a mis-sized issue would silently
    // mis-split blocks in release (DESIGN.md §14 debug-assert rule)
    assert_eq!(groups.len() * s_block, seeds.len());
    // the client evaluates its own heterogeneous probe budget: same ZO
    // hyperparameters, its planned S_j
    let mut zcfg = cfg.zo;
    zcfg.s_seeds = s_block;
    let deltas = zoopt(backend, global, &groups, &seeds, &zcfg, cfg.lr_client_zo)?;
    Ok(ZoContribution {
        client: cid,
        seeds,
        delta_l: deltas,
        n_samples: data.n(),
        s_block,
    })
}

/// Build per-client shards from a Dirichlet partition over a source.
pub fn shards_from_partition(
    source: &Source,
    partition: &crate::data::dirichlet::Partition,
) -> Vec<ClientData> {
    partition
        .clients
        .iter()
        .map(|idx| ClientData {
            source: source.clone(),
            indices: idx.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dirichlet::dirichlet_split;
    use crate::data::synthetic::{train_test, SynthKind};
    use crate::model::backend::LinearBackend;
    use std::sync::Arc;

    fn build(cfg: FedConfig) -> (LinearBackend, Vec<ClientData>, Source) {
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
        (be, shards, Source::Image(Arc::new(test)))
    }

    fn smoke_cfg() -> FedConfig {
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg
    }

    #[test]
    fn resource_assignment_counts() {
        let r = assign_resources(20, 6, 0);
        assert_eq!(r.iter().filter(|&&x| x == Resource::High).count(), 6);
        assert_eq!(assign_resources(20, 6, 0), assign_resources(20, 6, 0));
        assert_ne!(assign_resources(20, 6, 0), assign_resources(20, 6, 1));
    }

    #[test]
    fn full_run_improves_over_random() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let acc = fed.log.final_accuracy();
        assert!(acc > 0.2, "final acc {acc} should beat random (0.1)");
        assert_eq!(fed.round, fed.cfg.rounds_total);
        // both phases logged
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Warm));
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Zo));
    }

    #[test]
    fn zo_phase_adds_accuracy_over_warm_only() {
        // the paper's core claim at miniature scale: continuing with ZO
        // (all clients) beats stopping at the pivot.
        let mut cfg = smoke_cfg();
        cfg.rounds_total = 30;
        cfg.pivot = 10;
        cfg.hi_frac = 0.25;
        cfg.eval_every = 1;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let curve = fed.log.accuracy_curve();
        let at_pivot = curve
            .iter()
            .find(|(r, _)| *r == fed.cfg.pivot - 1)
            .map(|(_, a)| *a)
            .unwrap();
        let final_acc = fed.log.final_accuracy();
        // SPSA is noisy at this miniature scale; assert no collapse here.
        // The paper's "ZO adds accuracy over High-Res-Only" claim is
        // validated at experiment scale in exp/table2 + integration tests.
        assert!(
            final_acc > at_pivot - 0.06,
            "ZO phase should not collapse: pivot {at_pivot} -> final {final_acc}"
        );
    }

    #[test]
    fn comm_costs_drop_after_pivot() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let warm_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Warm)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        let zo_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Zo)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        assert!(
            zo_up * 1000 < warm_up,
            "ZO up-link ({zo_up} B) must be orders below FO ({warm_up} B)"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = smoke_cfg();
        let run = |cfg: FedConfig| {
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log.final_accuracy())
        };
        let (g1, a1) = run(cfg.clone());
        let (g2, a2) = run(cfg);
        assert_eq!(g1, g2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // the engine's core guarantee: worker count is invisible in the
        // outputs — same final weights, same logs, bit for bit.
        let run_with = |threads: usize, mixed: bool| {
            let mut cfg = smoke_cfg();
            cfg.threads = threads;
            cfg.mixed_step2 = mixed;
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log)
        };
        for mixed in [false, true] {
            let (g1, log1) = run_with(1, mixed);
            let (g4, log4) = run_with(4, mixed);
            assert_eq!(g1, g4, "weights must not depend on threads (mixed={mixed})");
            assert_eq!(log1.rounds.len(), log4.rounds.len());
            for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
                assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
                assert_eq!(a.bytes_up, b.bytes_up);
                assert_eq!(a.bytes_down, b.bytes_down);
            }
        }
    }

    #[test]
    fn multi_step_run_stays_finite_with_server_lr() {
        // grad_steps=2 with lr_server_zo != 1 exercises the per-block
        // replay path end-to-end (the protocol-level regression lives in
        // zo::tests::multi_step_zoopt_consistency).
        let mut cfg = smoke_cfg();
        cfg.zo.grad_steps = 2;
        cfg.threads = 2;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.global.is_finite());
        assert!(fed.log.rounds.iter().all(|r| r.train_loss.is_finite()));
    }

    #[test]
    fn empty_round_signal_is_zero_not_nan() {
        // a ZO round with zero contributions and no FO updates must log a
        // finite 0.0 train signal, never NaN
        let s = zo_train_signal(&[], &LossSums::default());
        assert_eq!(s, 0.0);
        assert!(s.is_finite());
        // FO-only mixed round falls back to the FO mean loss
        let fo = LossSums {
            loss_sum: 6.0,
            correct: 1.0,
            count: 3.0,
        };
        assert_eq!(zo_train_signal(&[], &fo), 2.0);
        // non-finite inputs are clamped rather than logged
        let bad = LossSums {
            loss_sum: f64::NAN,
            correct: 0.0,
            count: 1.0,
        };
        assert_eq!(zo_train_signal(&[], &bad), 0.0);
    }

    #[test]
    fn binary_scenario_reproduces_legacy_resource_classes() {
        // the acceptance contract: a default (assign_resources-compatible)
        // config derives the exact same High/Low split through profile
        // sampling + cost-model thresholds.
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let fed = Federation::new(cfg.clone(), &be, shards, test, init).unwrap();
        let legacy = assign_resources(cfg.clients, cfg.hi_count(), cfg.seed);
        for (cid, l) in legacy.iter().enumerate() {
            assert_eq!(fed.pop.resource(cid, &fed.cost), *l, "client {cid}");
        }
        // every low client can still afford the ZO footprint
        for cid in 0..cfg.clients {
            assert!(fed.pop.profile(cid).zo_capable(&fed.cost));
        }
    }

    #[test]
    fn straggler_scenario_drops_and_stays_thread_invariant() {
        // the tentpole guarantee: a dropout/straggler fleet still yields
        // bit-identical weights, logs, AND ledgers for every worker count,
        // and actually drops someone.
        let run_with = |threads: usize| {
            let mut cfg = smoke_cfg();
            cfg.threads = threads;
            cfg.scenario = crate::sim::Scenario::preset("stragglers").unwrap();
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log, fed.ledger)
        };
        let (g1, log1, led1) = run_with(1);
        let (g4, log4, led4) = run_with(4);
        assert_eq!(g1, g4, "weights must not depend on threads under drops");
        assert_eq!(led1.up_total, led4.up_total);
        assert_eq!(led1.down_total, led4.down_total);
        assert_eq!(log1.rounds.len(), log4.rounds.len());
        for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.bytes_up, b.bytes_up);
            assert_eq!(a.bytes_down, b.bytes_down);
            assert_eq!(a.dropped, b.dropped);
        }
        let total_dropped: usize = log1.rounds.iter().map(|r| r.dropped).sum();
        assert!(total_dropped > 0, "straggler preset should drop someone");
        assert!(g1.is_finite());
    }

    #[test]
    fn dropouts_shrink_the_ledger_not_the_determinism() {
        // with drops, total bytes must be <= the binary (no-drop) run of
        // the same config — partial transmissions only ever remove bytes
        let base = {
            let cfg = smoke_cfg();
            let (be, shards, test) = build(cfg.clone());
            let mut fed =
                Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
            fed.run().unwrap();
            fed.ledger
        };
        let dropped = {
            let mut cfg = smoke_cfg();
            // binary fleet with a universal failure rate: same tiers, so
            // per-round plans match the binary run's
            cfg.scenario = crate::sim::Scenario::preset("flaky").unwrap();
            let (be, shards, test) = build(cfg.clone());
            let mut fed =
                Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
            fed.run().unwrap();
            fed.ledger
        };
        assert!(dropped.up_total <= base.up_total);
        assert!(dropped.down_total <= base.down_total);
        assert!(
            dropped.up_total < base.up_total,
            "a 25% drop rate over a full run should lose at least one upload"
        );
    }

    #[test]
    fn all_drop_warm_round_leaves_params_untouched() {
        // a warm round where every picked client misses the deadline must
        // log a finite 0.0 signal, skip the server step, and charge only
        // the partial downloads
        let mut cfg = smoke_cfg();
        cfg.scenario = crate::sim::Scenario::load(
            r#"{"name": "warm-all-drop", "deadline_ms": 0.0001,
                "tiers": [{"frac": 1.0, "mem": "backprop",
                           "up_mbps": 0.001, "down_mbps": 0.001, "compute": 0.001}]}"#,
        )
        .unwrap();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init.clone()).unwrap();
        let summary = fed.warm_round().unwrap();
        assert_eq!(summary.train_signal, 0.0);
        assert!(summary.dropped > 0);
        assert_eq!(fed.global, init, "no survivors => no server step");
        let (up, _down) = *fed.ledger.per_round.last().unwrap();
        assert_eq!(up, 0, "cut during download charges zero uplink");
    }

    #[test]
    fn default_config_keeps_checkpointing_inert() {
        // acceptance: ckpt_every = 0 (the default) is byte-inert — no
        // snapshots, no log, no catch-up charges — so seed-era traces
        // (incl. the golden fixture) are reproduced unchanged.
        let cfg = smoke_cfg();
        assert_eq!(cfg.ckpt_every, 0);
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(!fed.ckpt.enabled());
        assert_eq!(fed.ckpt.tail_rounds(), 0);
        assert_eq!(fed.ledger.catch_up_down_total, 0);
        assert!(fed.log.rounds.iter().all(|r| r.catch_up_down == 0));
    }

    #[test]
    fn churn_fleet_charges_catch_up_and_stays_thread_invariant() {
        // the tentpole guarantee under churn: late joiners / absences /
        // rejoins with checkpointing enabled yield bit-identical weights,
        // logs AND catch-up ledgers for every worker count, and the
        // catch-up downlink is actually exercised.
        let run_with = |threads: usize| {
            let mut cfg = smoke_cfg();
            cfg.threads = threads;
            cfg.ckpt_every = 2;
            cfg.scenario = crate::sim::Scenario::preset("churn").unwrap();
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log, fed.ledger)
        };
        let (g1, log1, led1) = run_with(1);
        let (g4, log4, led4) = run_with(4);
        assert_eq!(g1, g4, "weights must not depend on threads under churn");
        assert_eq!(led1.catch_up_down_total, led4.catch_up_down_total);
        assert_eq!((led1.up_total, led1.down_total), (led4.up_total, led4.down_total));
        for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.catch_up_down, b.catch_up_down);
            assert_eq!(
                (a.bytes_up, a.bytes_down, a.dropped),
                (b.bytes_up, b.bytes_down, b.dropped)
            );
        }
        assert!(
            led1.catch_up_down_total > 0,
            "the churn fleet must pay catch-up downlink somewhere"
        );
        assert!(
            led1.catch_up_down_total <= led1.down_total,
            "catch-up is an attribution of the downlink, not extra bytes"
        );
        let absent: usize = log1.rounds.iter().map(|r| r.dropped).sum();
        assert!(absent > 0, "churn should keep someone out of some round");
        assert!(g1.is_finite());
    }

    #[test]
    fn mixed_round_does_not_oversync_zo_survivors() {
        // regression: a mixed_step2 round with surviving FO participants
        // is opaque — its FO fold cannot be reached from the (seed, ΔL)
        // broadcast — so ZO survivors must NOT be marked synced past it,
        // or their next catch-up would skip the snapshot they need.
        let mk = |mixed: bool| {
            let mut cfg = smoke_cfg();
            cfg.pivot = 0;
            cfg.rounds_total = 1;
            cfg.sample_zo = cfg.clients; // sample everyone: FO + ZO mix
            cfg.mixed_step2 = mixed;
            cfg.ckpt_every = 1;
            let (be, shards, test) = build(cfg.clone());
            let mut fed =
                Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
            fed.step().unwrap();
            fed
        };
        // pure ZO round: every survivor receives the broadcast and syncs
        // to round 1
        let fed = mk(false);
        let dense = fed.synced.to_dense(fed.cfg.clients);
        assert!(dense.iter().all(|&s| s == 1), "{dense:?}");
        // mixed round (binary fleet: half the clients run FO): opaque —
        // nobody may claim the post-fold state
        let fed = mk(true);
        assert_eq!(fed.ckpt.tail_rounds(), 0, "mixed round must be opaque");
        assert_eq!(fed.ckpt.base_round(), 1);
        let dense = fed.synced.to_dense(fed.cfg.clients);
        assert!(
            dense.iter().all(|&s| s == 0),
            "oversynced past an opaque round: {dense:?}"
        );
    }

    #[test]
    fn sparse_synced_reproduces_dense_ledger_on_churn() {
        // satellite: the sparse sync ledger's folds reproduce the dense
        // Vec ledger they replaced, on the preset that actually exercises
        // staleness (late joiners, whole-round absences, rejoins) — the
        // cfg(test) mirror applies the identical max-fold at every site.
        let mut cfg = smoke_cfg();
        cfg.ckpt_every = 2;
        cfg.scenario = crate::sim::Scenario::preset("churn").unwrap();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg.clone(), &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let mirror = fed
            .synced_dense_mirror
            .as_ref()
            .expect("materialized federation keeps the dense mirror");
        assert_eq!(
            &fed.synced.to_dense(cfg.clients),
            mirror,
            "sparse fold diverged from the dense ledger"
        );
        // staleness really occurred, and the ledger stayed sparse: only
        // clients that deviated from the init default occupy memory
        assert!(fed.ledger.catch_up_down_total > 0);
        assert!(fed.synced.deviated() <= cfg.clients);
        let defaults = fed
            .synced_dense_mirror
            .iter()
            .filter(|&&s| s == 0)
            .count();
        assert_eq!(
            fed.synced.deviated(),
            cfg.clients - defaults,
            "exactly the non-default clients may hold entries"
        );
    }

    #[test]
    fn adaptive_off_issues_uniform_budgets_and_counts_them() {
        // default: the planner is a constant function and the new
        // accounting columns reproduce the uniform protocol's arithmetic
        let cfg = smoke_cfg();
        assert!(!cfg.zo.adaptive_s);
        let (be, shards, test) = build(cfg.clone());
        let mut fed =
            Federation::new(cfg.clone(), &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        let all: Vec<usize> = (0..cfg.clients).collect();
        for (_, s) in fed.planned_seed_counts(&all) {
            assert_eq!(s, cfg.zo.s_seeds);
        }
        fed.run().unwrap();
        // binary fleet, no drops: every ZO round issues Q · S · steps
        // seeds (steps = 1 at grad_steps = 1), warm rounds none
        for r in &fed.log.rounds {
            match r.phase {
                Phase::Warm => assert_eq!(r.seeds_issued, 0),
                Phase::Zo => {
                    assert_eq!(r.seeds_issued, cfg.sample_zo * cfg.zo.s_seeds)
                }
            }
            assert!(r.eff_var.is_finite());
        }
        assert_eq!(
            fed.ledger.seeds_total as usize,
            fed.log.total_seeds_issued()
        );
        let zo_rounds = cfg.rounds_total - cfg.pivot;
        assert_eq!(
            fed.ledger.seeds_total as usize,
            zo_rounds * cfg.sample_zo * cfg.zo.s_seeds
        );
    }

    #[test]
    fn adaptive_budgets_track_capability_and_fill_the_envelope() {
        // under a capability spread with no deadline, the planner hands
        // every candidate at least the uniform S (the slowest sampled
        // client defines the envelope at exactly that S) and the strong
        // tiers strictly more
        let mut cfg = smoke_cfg();
        cfg.zo.adaptive_s = true;
        cfg.zo.s_min = 1;
        cfg.zo.s_max = 16;
        cfg.scenario = crate::sim::Scenario::preset("edge-spectrum").unwrap();
        let (be, shards, test) = build(cfg.clone());
        let fed =
            Federation::new(cfg.clone(), &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
        let all: Vec<usize> = (0..cfg.clients).collect();
        let counts = fed.planned_seed_counts(&all);
        assert_eq!(counts.len(), cfg.clients, "every tier is ZO-capable");
        for &(cid, s) in &counts {
            assert!((1..=16).contains(&s), "client {cid}: S={s} out of range");
            assert!(
                s >= cfg.zo.s_seeds,
                "client {cid}: the envelope guarantees at least uniform S, got {s}"
            );
        }
        // acceptance: budgets differ across clients and across tiers.
        // (The per-probe cost mixes tier capability with shard size, so
        // compare tier means, not hand-picked tier pairs.)
        let distinct: std::collections::BTreeSet<usize> =
            counts.iter().map(|&(_, s)| s).collect();
        assert!(
            distinct.len() > 1,
            "edge-spectrum must yield heterogeneous budgets: {counts:?}"
        );
        let mut tier_means: Vec<(String, f64)> = Vec::new();
        for &(cid, s) in &counts {
            let tier = fed.pop.profile(cid).tier;
            match tier_means.iter_mut().find(|(t, _)| *t == tier) {
                Some((_, m)) => *m += s as f64,
                None => tier_means.push((tier, s as f64)),
            }
        }
        for (tier, m) in tier_means.iter_mut() {
            let n = (0..cfg.clients)
                .filter(|&cid| fed.pop.profile(cid).tier == *tier)
                .count();
            *m /= n as f64;
        }
        let hi = tier_means.iter().map(|(_, m)| *m).fold(f64::MIN, f64::max);
        let lo = tier_means.iter().map(|(_, m)| *m).fold(f64::MAX, f64::min);
        assert!(
            hi > lo,
            "acceptance: issued budgets must differ across tiers: {tier_means:?}"
        );
    }

    #[test]
    fn adaptive_run_is_thread_invariant_and_outprobes_uniform() {
        // the tentpole e2e guarantee: heterogeneous S with a variance
        // guard stays bit-identical across worker counts, and issues
        // strictly more probes than the uniform run on the same fleet
        let run_with = |threads: usize, adaptive: bool| {
            let mut cfg = smoke_cfg();
            cfg.threads = threads;
            cfg.zo.adaptive_s = adaptive;
            cfg.zo.guard = crate::config::VarianceGuard::InvVar;
            cfg.scenario = crate::sim::Scenario::preset("edge-spectrum").unwrap();
            let (be, shards, test) = build(cfg.clone());
            let mut fed =
                Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log, fed.ledger)
        };
        let (g1, log1, led1) = run_with(1, true);
        let (g4, log4, led4) = run_with(4, true);
        assert_eq!(g1, g4, "adaptive weights must not depend on threads");
        assert_eq!(led1.seeds_total, led4.seeds_total);
        assert_eq!((led1.up_total, led1.down_total), (led4.up_total, led4.down_total));
        for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eff_var.to_bits(), b.eff_var.to_bits());
            assert_eq!(a.seeds_issued, b.seeds_issued);
            assert_eq!((a.bytes_up, a.bytes_down), (b.bytes_up, b.bytes_down));
        }
        let (_, _, led_uniform) = run_with(1, false);
        assert!(
            led1.seeds_total > led_uniform.seeds_total,
            "adaptive ({}) must out-probe uniform ({})",
            led1.seeds_total,
            led_uniform.seeds_total
        );
        assert!(g1.is_finite());
    }

    #[test]
    fn lazy_fleet_federation_runs_both_phases_thread_invariant() {
        // the fleet-scale path at test scale: lazy population, warm phase
        // sampling the thin backbone by rejection, ZO phase over keyed
        // shards — deterministic, thread-invariant, O(1) population state
        let run_with = |threads: usize| {
            let mut cfg = smoke_cfg();
            cfg.clients = 512;
            cfg.sample_zo = 8;
            cfg.threads = threads;
            cfg.population = crate::config::PopulationMode::Lazy;
            cfg.scenario = crate::sim::Scenario::preset("fleet").unwrap();
            let (train, test) =
                crate::data::synthetic::train_test(SynthKind::Synth10, 400, 120, cfg.seed);
            let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new_lazy(
                cfg,
                &be,
                Source::Image(Arc::new(train)),
                Source::Image(Arc::new(test)),
                init,
            )
            .unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log, fed.pop.approx_state_bytes())
        };
        let (g1, log1, bytes1) = run_with(1);
        let (g4, log4, bytes4) = run_with(4);
        assert_eq!(g1, g4, "lazy-population weights must not depend on threads");
        assert_eq!(log1.rounds.len(), log4.rounds.len());
        for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(
                (a.bytes_up, a.bytes_down, a.dropped),
                (b.bytes_up, b.bytes_down, b.dropped)
            );
        }
        assert!(g1.is_finite());
        assert!(log1.rounds.iter().any(|r| r.phase == Phase::Warm));
        assert!(log1.rounds.iter().any(|r| r.phase == Phase::Zo));
        // no per-client vector anywhere: the population descriptor is
        // hundreds of bytes regardless of N
        assert_eq!(bytes1, bytes4);
        assert!(bytes1 < 4096, "lazy population state is {bytes1} B");
    }

    #[test]
    fn mixed_step2_also_runs() {
        let mut cfg = smoke_cfg();
        cfg.mixed_step2 = true;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.final_accuracy() > 0.15);
    }

    #[test]
    fn high_res_only_is_pivot_equals_total() {
        let mut cfg = smoke_cfg();
        cfg.pivot = cfg.rounds_total;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.rounds.iter().all(|r| r.phase == Phase::Warm));
    }
}
