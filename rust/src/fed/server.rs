//! The federated coordinator: Algorithm 1's two-phase loop.
//!
//! Phase 1 (rounds 0..pivot): FedAvg/FedAdam over high-resource clients
//! only — the warm-up that makes from-scratch ZO training feasible.
//! Phase 2 (rounds pivot..total): the seed-based SPSA protocol over *all*
//! clients (optionally mixed with continued FO updates for the §A.4
//! ablation).
//!
//! ## Threading model
//!
//! Client-local work inside a round is embarrassingly parallel, so both
//! round kinds fan the sampled clients out over a scoped thread pool
//! ([`crate::util::pool::parallel_map_n`]). The engine guarantees results
//! **bit-identical to the sequential path for every worker count**:
//!
//! 1. every per-client random input (local-SGD RNG, issued seed block) is
//!    derived *before* the fan-out from `(master seed, round, client id)`
//!    or the stateless [`SeedIssuer`], never from shared mutable RNG state
//!    inside a job;
//! 2. jobs are pure `Send` functions of `(global weights, shard, inputs)`
//!    — all mutation of the federation (ledger, server optimizer, log)
//!    happens after the join;
//! 3. contributions fold back in sampled-client order, and the fused
//!    ZOUPDATE applies them in one order-canonicalized pass
//!    (`perturb_axpy_many_sharded`, itself sharded across the same worker
//!    budget with bit-exact stream fast-forwarding).
//!
//! Worker count comes from `FedConfig::threads` (`0` = auto: the
//! `ZOWARMUP_THREADS` env override, else available parallelism).

use std::time::Instant;

use crate::comm::CommLedger;
use crate::config::FedConfig;
use crate::data::loader::{eval_chunks, ClientData, Source};
use crate::fed::aggregate::{weighted_average, ServerOptState};
use crate::fed::client::{
    round_client_rng, warm_local_train, zo_step_chunks, zo_step_count, ClientState, Resource,
};
use crate::metrics::{Phase, RoundRecord, RunLog};
use crate::model::backend::{LossSums, ModelBackend};
use crate::model::params::ParamVec;
use crate::util::pool::{parallel_map_n, resolve_workers};
use crate::util::rng::Xoshiro256;
use crate::zo::{apply_zo_update_sharded, zo_round_ledger, zoopt, SeedIssuer, ZoContribution};

/// Full federation state for one training run.
pub struct Federation<'b, B: ModelBackend> {
    pub cfg: FedConfig,
    pub backend: &'b B,
    pub clients: Vec<ClientState>,
    pub test: Source,
    pub global: ParamVec,
    pub round: usize,
    pub log: RunLog,
    pub ledger: CommLedger,
    server_opt: ServerOptState,
    issuer: SeedIssuer,
    rng: Xoshiro256,
}

/// Assign resource classes: the first `hi_count` of a seed-shuffled client
/// order are high-resource ("clients are randomly assigned", §4).
pub fn assign_resources(k: usize, hi_count: usize, seed: u64) -> Vec<Resource> {
    let mut rng = Xoshiro256::seed_from(seed ^ 0x4E50_11);
    let mut order: Vec<usize> = (0..k).collect();
    rng.shuffle(&mut order);
    let mut out = vec![Resource::Low; k];
    for &i in order.iter().take(hi_count.min(k)) {
        out[i] = Resource::High;
    }
    out
}

impl<'b, B: ModelBackend> Federation<'b, B> {
    /// Build a federation from per-client shards and a test source.
    /// `init` seeds the global weights (callers init via manifest He-init
    /// for XLA backends, zeros for the linear probe).
    pub fn new(
        cfg: FedConfig,
        backend: &'b B,
        shards: Vec<ClientData>,
        test: Source,
        init: ParamVec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(shards.len() == cfg.clients, "shard count != clients");
        anyhow::ensure!(init.dim() == backend.dim(), "init dim mismatch");
        let classes = assign_resources(cfg.clients, cfg.hi_count(), cfg.seed);
        let clients = shards
            .into_iter()
            .zip(classes)
            .enumerate()
            .map(|(id, (data, resource))| ClientState { id, data, resource })
            .collect();
        let server_opt = ServerOptState::new(cfg.server_opt, backend.dim());
        let issuer = SeedIssuer::new(cfg.seed ^ 0x5EED_1557);
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xFED_0_FED);
        Ok(Self {
            cfg,
            backend,
            clients,
            test,
            global: init,
            round: 0,
            log: RunLog::default(),
            ledger: CommLedger::default(),
            server_opt,
            issuer,
            rng,
        })
    }

    pub fn high_ids(&self) -> Vec<usize> {
        self.clients
            .iter()
            .filter(|c| c.is_high())
            .map(|c| c.id)
            .collect()
    }

    /// Evaluate the current global weights on the server's test set.
    pub fn eval(&self) -> anyhow::Result<LossSums> {
        let mut sums = LossSums::default();
        for b in eval_chunks(&self.test, self.backend.batch_size()) {
            sums.add(self.backend.fwd_loss(&self.global, &b)?);
        }
        Ok(sums)
    }

    /// Per-(round, client) local RNG (see [`round_client_rng`]).
    fn client_rng(&self, cid: usize) -> Xoshiro256 {
        round_client_rng(self.cfg.seed, 0, self.round, cid)
    }

    /// Effective worker count for this run (see module docs).
    pub fn workers(&self) -> usize {
        resolve_workers(self.cfg.threads)
    }

    /// One warm round (Algorithm 1 lines 2-8). Sampled clients train in
    /// parallel; see the module-level threading model for the
    /// determinism argument.
    pub fn warm_round(&mut self) -> anyhow::Result<f64> {
        let hi = self.high_ids();
        anyhow::ensure!(!hi.is_empty(), "no high-resource clients to warm up");
        let p = self.cfg.sample_warm.clamp(1, hi.len());
        let picked: Vec<usize> = self
            .rng
            .choose(hi.len(), p)
            .into_iter()
            .map(|i| hi[i])
            .collect();

        // derive each client's RNG before the fan-out (determinism rule 1)
        let jobs: Vec<(usize, Xoshiro256)> = picked
            .iter()
            .map(|&cid| (cid, self.client_rng(cid)))
            .collect();
        let workers = self.workers();
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let clients = &self.clients;
            let cfg = &self.cfg;
            parallel_map_n(workers, jobs, move |(cid, mut crng)| {
                warm_local_train(backend, global, &clients[cid].data, cfg, &mut crng)
                    .map(|out| (cid, out))
            })
        };

        // fold in sampled order (determinism rule 3)
        let mut updates: Vec<(ParamVec, f64)> = Vec::with_capacity(p);
        let mut train = LossSums::default();
        for r in results {
            let (cid, (w, sums)) = r?;
            train.add(sums);
            updates.push((w, self.clients[cid].n() as f64));
        }
        let avg = weighted_average(&updates);
        let mut delta = avg;
        delta.axpy(-1.0, &self.global);
        self.server_opt
            .apply(&mut self.global, &delta, self.cfg.lr_server_warm);

        // full weights both ways, per participating client
        let d4 = (self.backend.dim() * 4) as u64;
        self.ledger.record_round(d4 * p as u64, d4 * p as u64);
        Ok(train.mean_loss())
    }

    /// One ZO round (Algorithm 1 lines 11-21). Sampled clients evaluate
    /// their seed blocks (or, with `mixed_step2`, run FO locally) in
    /// parallel; every random input is pre-derived and the fold-back is
    /// order-canonical, so the round is bit-identical for any worker
    /// count (see module docs).
    pub fn zo_round(&mut self) -> anyhow::Result<f64> {
        // Q ⊆ K — all resource classes participate in step 2. With
        // mixed_step2 (§A.4 ablation) the sampled high-res clients do FO
        // updates instead.
        let q = self.cfg.sample_zo.clamp(1, self.cfg.clients);
        let picked = self.rng.choose(self.cfg.clients, q);

        enum Job {
            Fo { cid: usize, rng: Xoshiro256 },
            Zo { cid: usize, seeds: Vec<u64> },
        }
        enum Out {
            Fo { cid: usize, w: ParamVec, sums: LossSums },
            Zo(ZoContribution),
        }

        // pre-derive every per-client random input (determinism rule 1):
        // the FO local RNG and the issued seed block are both pure
        // functions of (master seed, round, client id).
        let jobs: Vec<Job> = picked
            .iter()
            .map(|&cid| {
                let client = &self.clients[cid];
                if self.cfg.mixed_step2 && client.is_high() {
                    Job::Fo { cid, rng: self.client_rng(cid) }
                } else {
                    let steps = zo_step_count(client.n(), self.cfg.zo.grad_steps);
                    let seeds = self
                        .issuer
                        .seeds_for(self.round, cid, self.cfg.zo.s_seeds * steps);
                    Job::Zo { cid, seeds }
                }
            })
            .collect();

        let workers = self.workers();
        let results = {
            let backend = self.backend;
            let global = &self.global;
            let clients = &self.clients;
            let cfg = &self.cfg;
            parallel_map_n(workers, jobs, move |job| -> anyhow::Result<Out> {
                match job {
                    Job::Fo { cid, mut rng } => {
                        let (w, sums) = warm_local_train(
                            backend,
                            global,
                            &clients[cid].data,
                            cfg,
                            &mut rng,
                        )?;
                        Ok(Out::Fo { cid, w, sums })
                    }
                    Job::Zo { cid, seeds } => {
                        let client = &clients[cid];
                        let groups = zo_step_chunks(
                            &client.data,
                            backend.batch_size(),
                            cfg.zo.grad_steps,
                        );
                        debug_assert_eq!(groups.len() * cfg.zo.s_seeds, seeds.len());
                        let deltas = zoopt(
                            backend,
                            global,
                            &groups,
                            &seeds,
                            &cfg.zo,
                            cfg.lr_client_zo,
                        )?;
                        Ok(Out::Zo(ZoContribution {
                            client: cid,
                            seeds,
                            delta_l: deltas,
                            n_samples: client.n(),
                        }))
                    }
                }
            })
        };

        // fold in sampled order (determinism rule 3)
        let mut contributions: Vec<ZoContribution> = Vec::new();
        let mut fo_updates: Vec<(ParamVec, f64)> = Vec::new();
        let mut train = LossSums::default();
        for r in results {
            match r? {
                Out::Fo { cid, w, sums } => {
                    train.add(sums);
                    fo_updates.push((w, self.clients[cid].n() as f64));
                }
                Out::Zo(c) => contributions.push(c),
            }
        }
        let fo_participants = fo_updates.len();

        // ZOUPDATE: reconstruct the aggregated step from (seed, ΔL) pairs.
        // Intermediate grad_steps blocks replay at lr_client (matching the
        // client's local trajectory); the server lr scales only the final
        // aggregated block. The weight-vector pass shards across the same
        // worker budget.
        apply_zo_update_sharded(
            &mut self.global,
            &contributions,
            &self.cfg.zo,
            self.cfg.lr_client_zo,
            self.cfg.lr_server_zo,
            workers,
        );

        // mixed step-2: fold FO updates in afterwards (weighted FedAvg step)
        if !fo_updates.is_empty() {
            let avg = weighted_average(&fo_updates);
            let mut delta = avg;
            delta.axpy(-1.0, &self.global);
            // scale FO influence by its share of participants
            let share = fo_participants as f32 / q as f32;
            self.server_opt
                .apply(&mut self.global, &delta, self.cfg.lr_server_warm * share);
        }

        // comm accounting: seed traffic is charged only to ZO
        // participants (and only for the seeds actually issued — small
        // clients run fewer grad_steps blocks); FO participants exchange
        // full weights instead.
        let total_seeds: usize = contributions.iter().map(|c| c.seeds.len()).sum();
        let (up, down) = zo_round_ledger(
            total_seeds,
            contributions.len(),
            fo_participants,
            (self.backend.dim() * 4) as u64,
        );
        self.ledger.record_round(up, down);

        Ok(zo_train_signal(&contributions, &train))
    }

    /// Run one round (phase chosen by the pivot), with eval + logging.
    pub fn step(&mut self) -> anyhow::Result<()> {
        let t0 = Instant::now();
        let (phase, train_signal) = if self.round < self.cfg.pivot {
            (Phase::Warm, self.warm_round()?)
        } else {
            (Phase::Zo, self.zo_round()?)
        };
        let do_eval = self.round % self.cfg.eval_every == 0
            || self.round + 1 == self.cfg.rounds_total
            || self.round + 1 == self.cfg.pivot;
        let (test_acc, test_loss) = if do_eval {
            let e = self.eval()?;
            (e.accuracy(), e.mean_loss())
        } else {
            (f64::NAN, f64::NAN)
        };
        let (up, down) = *self.ledger.per_round.last().unwrap_or(&(0, 0));
        self.log.push(RoundRecord {
            round: self.round,
            phase,
            train_loss: train_signal,
            test_acc,
            test_loss,
            bytes_up: up,
            bytes_down: down,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        self.round += 1;
        Ok(())
    }

    /// Run to completion.
    pub fn run(&mut self) -> anyhow::Result<()> {
        while self.round < self.cfg.rounds_total {
            self.step()?;
        }
        Ok(())
    }
}

/// ZO-phase training signal for one round: mean |ΔL| over every
/// contribution (the SPSA progress proxy); a mixed round with no ZO
/// contributions falls back to the FO participants' mean loss; a fully
/// empty round reports 0.0. Always finite — the signal is logged as the
/// round's `train_loss` and must never poison the CSV with NaN.
pub fn zo_train_signal(contributions: &[ZoContribution], fo_train: &LossSums) -> f64 {
    let (sum, n) = contributions
        .iter()
        .flat_map(|c| c.delta_l.iter())
        .fold((0.0f64, 0usize), |(s, k), d| (s + d.abs(), k + 1));
    let v = if n > 0 {
        sum / n as f64
    } else if fo_train.count > 0.0 {
        fo_train.mean_loss()
    } else {
        0.0
    };
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Build per-client shards from a Dirichlet partition over a source.
pub fn shards_from_partition(
    source: &Source,
    partition: &crate::data::dirichlet::Partition,
) -> Vec<ClientData> {
    partition
        .clients
        .iter()
        .map(|idx| ClientData {
            source: source.clone(),
            indices: idx.clone(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dirichlet::dirichlet_split;
    use crate::data::synthetic::{train_test, SynthKind};
    use crate::model::backend::LinearBackend;
    use std::sync::Arc;

    fn build(cfg: FedConfig) -> (LinearBackend, Vec<ClientData>, Source) {
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
        (be, shards, Source::Image(Arc::new(test)))
    }

    fn smoke_cfg() -> FedConfig {
        let mut cfg = FedConfig::default().smoke_scale();
        cfg.lr_client_warm = 0.06;
        cfg.lr_client_zo = 1.0;
        cfg.lr_server_zo = 0.01;
        cfg.zo.eps = 1e-3;
        cfg
    }

    #[test]
    fn resource_assignment_counts() {
        let r = assign_resources(20, 6, 0);
        assert_eq!(r.iter().filter(|&&x| x == Resource::High).count(), 6);
        assert_eq!(assign_resources(20, 6, 0), assign_resources(20, 6, 0));
        assert_ne!(assign_resources(20, 6, 0), assign_resources(20, 6, 1));
    }

    #[test]
    fn full_run_improves_over_random() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let acc = fed.log.final_accuracy();
        assert!(acc > 0.2, "final acc {acc} should beat random (0.1)");
        assert_eq!(fed.round, fed.cfg.rounds_total);
        // both phases logged
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Warm));
        assert!(fed.log.rounds.iter().any(|r| r.phase == Phase::Zo));
    }

    #[test]
    fn zo_phase_adds_accuracy_over_warm_only() {
        // the paper's core claim at miniature scale: continuing with ZO
        // (all clients) beats stopping at the pivot.
        let mut cfg = smoke_cfg();
        cfg.rounds_total = 30;
        cfg.pivot = 10;
        cfg.hi_frac = 0.25;
        cfg.eval_every = 1;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let curve = fed.log.accuracy_curve();
        let at_pivot = curve
            .iter()
            .find(|(r, _)| *r == fed.cfg.pivot - 1)
            .map(|(_, a)| *a)
            .unwrap();
        let final_acc = fed.log.final_accuracy();
        // SPSA is noisy at this miniature scale; assert no collapse here.
        // The paper's "ZO adds accuracy over High-Res-Only" claim is
        // validated at experiment scale in exp/table2 + integration tests.
        assert!(
            final_acc > at_pivot - 0.06,
            "ZO phase should not collapse: pivot {at_pivot} -> final {final_acc}"
        );
    }

    #[test]
    fn comm_costs_drop_after_pivot() {
        let cfg = smoke_cfg();
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        let warm_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Warm)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        let zo_up: u64 = fed
            .log
            .rounds
            .iter()
            .filter(|r| r.phase == Phase::Zo)
            .map(|r| r.bytes_up)
            .max()
            .unwrap();
        assert!(
            zo_up * 1000 < warm_up,
            "ZO up-link ({zo_up} B) must be orders below FO ({warm_up} B)"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = smoke_cfg();
        let run = |cfg: FedConfig| {
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log.final_accuracy())
        };
        let (g1, a1) = run(cfg.clone());
        let (g2, a2) = run(cfg);
        assert_eq!(g1, g2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // the engine's core guarantee: worker count is invisible in the
        // outputs — same final weights, same logs, bit for bit.
        let run_with = |threads: usize, mixed: bool| {
            let mut cfg = smoke_cfg();
            cfg.threads = threads;
            cfg.mixed_step2 = mixed;
            let (be, shards, test) = build(cfg.clone());
            let init = ParamVec::zeros(be.dim());
            let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
            fed.run().unwrap();
            (fed.global.clone(), fed.log)
        };
        for mixed in [false, true] {
            let (g1, log1) = run_with(1, mixed);
            let (g4, log4) = run_with(4, mixed);
            assert_eq!(g1, g4, "weights must not depend on threads (mixed={mixed})");
            assert_eq!(log1.rounds.len(), log4.rounds.len());
            for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
                assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
                assert_eq!(a.bytes_up, b.bytes_up);
                assert_eq!(a.bytes_down, b.bytes_down);
            }
        }
    }

    #[test]
    fn multi_step_run_stays_finite_with_server_lr() {
        // grad_steps=2 with lr_server_zo != 1 exercises the per-block
        // replay path end-to-end (the protocol-level regression lives in
        // zo::tests::multi_step_zoopt_consistency).
        let mut cfg = smoke_cfg();
        cfg.zo.grad_steps = 2;
        cfg.threads = 2;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.global.is_finite());
        assert!(fed.log.rounds.iter().all(|r| r.train_loss.is_finite()));
    }

    #[test]
    fn empty_round_signal_is_zero_not_nan() {
        // a ZO round with zero contributions and no FO updates must log a
        // finite 0.0 train signal, never NaN
        let s = zo_train_signal(&[], &LossSums::default());
        assert_eq!(s, 0.0);
        assert!(s.is_finite());
        // FO-only mixed round falls back to the FO mean loss
        let fo = LossSums {
            loss_sum: 6.0,
            correct: 1.0,
            count: 3.0,
        };
        assert_eq!(zo_train_signal(&[], &fo), 2.0);
        // non-finite inputs are clamped rather than logged
        let bad = LossSums {
            loss_sum: f64::NAN,
            correct: 0.0,
            count: 1.0,
        };
        assert_eq!(zo_train_signal(&[], &bad), 0.0);
    }

    #[test]
    fn mixed_step2_also_runs() {
        let mut cfg = smoke_cfg();
        cfg.mixed_step2 = true;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.final_accuracy() > 0.15);
    }

    #[test]
    fn high_res_only_is_pivot_equals_total() {
        let mut cfg = smoke_cfg();
        cfg.pivot = cfg.rounds_total;
        let (be, shards, test) = build(cfg.clone());
        let init = ParamVec::zeros(be.dim());
        let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
        fed.run().unwrap();
        assert!(fed.log.rounds.iter().all(|r| r.phase == Phase::Warm));
    }
}
