//! The population layer: who the clients *are*, at O(sampled) cost.
//!
//! `Federation::new` historically materialized a `Vec<ClientState>` — a
//! capability profile and a data shard for every client in the
//! population — so memory and setup scaled O(N) even though a round only
//! ever touches the K sampled clients. A [`Population`] abstracts that
//! away behind per-client accessors with two backing modes:
//!
//! * **Materialized** — the seed-era `Vec<ClientState>` built from
//!   Dirichlet shards and the shuffle-based `sample_profiles` stream.
//!   Bit-compatible with every historical trace; the default below
//!   [`crate::config::LAZY_AUTO_THRESHOLD`] clients.
//! * **Lazy** — nothing per-client is stored. A client's
//!   [`CapabilityProfile`] derives on demand from
//!   `(scenario, seed, cid)` ([`crate::sim::Scenario::profile_of`]) and
//!   its data shard is drawn on demand from the shared source by a keyed
//!   sparse Fisher-Yates ([`SHARD_SALT`]). A federation over 10^7
//!   clients costs O(K sampled per round), never O(N).
//!
//! The same O(sampled) discipline applies to per-client *state*: the
//! server's sync ledger is a [`SparseSync`] map recording only clients
//! that ever deviated from the population default (synced-to-0), so
//! million-client churn bookkeeping stays proportional to participation.

use crate::comm::CostModel;
use crate::data::loader::{ClientData, Source};
use crate::fed::client::{ClientState, Resource};
use crate::sim::{CapabilityProfile, Scenario};
use crate::util::rng::{SplitMix64, Xoshiro256};

/// Stream salt of the lazy per-client shard draw — re-exported from the
/// central registry (`util::rng::salts`, DESIGN.md §14); its own domain,
/// decorrelated from the profile draw (`sim::PROFILE_SALT`) and every
/// round trace.
pub use crate::util::rng::salts::SHARD_SALT;

/// Samples each lazy client holds (clamped to the source size): the
/// cross-device regime's "small local dataset" — fixed and documented so
/// lazy shard cost is O(1) per sampled client regardless of N.
pub const LAZY_SHARD_SAMPLES: usize = 64;

/// Rejection-sampling attempt budget per warm pick in lazy mode, as a
/// multiple of the expected `1 / fo_frac` draws — a deterministic
/// termination guard, not a tuning knob.
const WARM_REJECTION_SLACK: usize = 64;

/// Absolute ceiling on warm rejection draws, so a pathological scenario
/// (an FO tier with a vanishingly small but positive fraction) fails
/// fast with a clear error instead of spinning for `1 / frac` draws.
const WARM_REJECTION_CAP: usize = 1 << 20;

/// Below this population size, lazy warm sampling enumerates the
/// FO-capable sub-population exactly (one O(n) profile scan) instead of
/// rejection-sampling: at small n an O(n) pass is not the cost this
/// layer exists to remove, and it makes small lazy fleets behave like
/// the materialized path — `min(want, |H|)` picks, and a clean error
/// when the tier mass realized zero FO clients (which at small n is a
/// real possibility, e.g. 0.98^20 ≈ 67% for a 2% tier over 20 ids).
const WARM_ENUM_THRESHOLD: usize = 1 << 13;

/// A lazily-derived population: per-client profiles and shards are pure
/// functions of the fields here — O(1) state for any N.
pub struct LazyPopulation {
    pub n: usize,
    pub hi_count: usize,
    pub seed: u64,
    pub scenario: Scenario,
    pub cost: CostModel,
    pub source: Source,
    /// samples per lazy shard (`LAZY_SHARD_SAMPLES` clamped to the source)
    pub shard_n: usize,
}

/// The federation's client population (see module docs).
pub enum Population {
    Materialized(Vec<ClientState>),
    Lazy(LazyPopulation),
}

impl Population {
    /// Wrap a fully materialized client list (the seed-era path).
    pub fn materialized(clients: Vec<ClientState>) -> Self {
        Population::Materialized(clients)
    }

    /// Build a lazy population over `n` clients drawing shards from
    /// `source`. Allocates O(1) — the acceptance contract of the
    /// fleet-scale layer. Errors on an empty source (a shard draw from
    /// it could only panic later).
    pub fn lazy(
        n: usize,
        hi_count: usize,
        seed: u64,
        scenario: Scenario,
        cost: CostModel,
        source: Source,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!source.is_empty(), "lazy population needs a non-empty source");
        let shard_n = LAZY_SHARD_SAMPLES.min(source.len());
        Ok(Population::Lazy(LazyPopulation {
            n,
            hi_count,
            seed,
            scenario,
            cost,
            source,
            shard_n,
        }))
    }

    pub fn len(&self) -> usize {
        match self {
            Population::Materialized(c) => c.len(),
            Population::Lazy(l) => l.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self, Population::Lazy(_))
    }

    /// The client's capability profile (derived on demand in lazy mode).
    pub fn profile(&self, cid: usize) -> CapabilityProfile {
        match self {
            Population::Materialized(c) => c[cid].profile.clone(),
            Population::Lazy(l) => {
                l.scenario.profile_of(l.n, l.hi_count, l.seed, cid, &l.cost)
            }
        }
    }

    /// The client's legacy FO/ZO resource class under `cost` — identical
    /// to the materialized `ClientState::resource` derivation.
    pub fn resource(&self, cid: usize, cost: &CostModel) -> Resource {
        match self {
            Population::Materialized(c) => c[cid].resource,
            Population::Lazy(_) => {
                if self.profile(cid).fo_capable(cost) {
                    Resource::High
                } else {
                    Resource::Low
                }
            }
        }
    }

    pub fn is_high(&self, cid: usize, cost: &CostModel) -> bool {
        self.resource(cid, cost) == Resource::High
    }

    /// The client's local sample count, without materializing the shard.
    pub fn n_samples(&self, cid: usize) -> usize {
        match self {
            Population::Materialized(c) => c[cid].n(),
            Population::Lazy(l) => l.shard_n,
        }
    }

    /// The client's data shard. Materialized mode clones the stored view
    /// — a deliberate copy of the index list (a few KB per survivor,
    /// noise next to the training job it feeds) so jobs own their inputs
    /// uniformly across both modes; lazy mode draws `shard_n` distinct
    /// sample indices from a keyed per-client stream — deterministic, and
    /// only ever evaluated for sampled survivors.
    pub fn data(&self, cid: usize) -> ClientData {
        match self {
            Population::Materialized(c) => c[cid].data.clone(),
            Population::Lazy(l) => {
                let mut h = SplitMix64(cid as u64);
                let mut rng = Xoshiro256::seed_from(l.seed ^ SHARD_SALT ^ h.next_u64());
                let indices = rng.choose(l.source.len(), l.shard_n);
                ClientData {
                    source: l.source.clone(),
                    indices,
                }
            }
        }
    }

    /// Expected FO-capable share of the population under `cost`: the
    /// exact count in materialized mode, the tier draw mass in lazy mode.
    pub fn fo_share(&self, cost: &CostModel) -> f64 {
        match self {
            Population::Materialized(c) => {
                if c.is_empty() {
                    0.0
                } else {
                    c.iter().filter(|x| x.is_high()).count() as f64 / c.len() as f64
                }
            }
            Population::Lazy(l) => l.scenario.fo_tier_frac(l.n, l.hi_count, cost),
        }
    }

    /// Whether warm-phase sampling can succeed at all: any FO-capable
    /// client (materialized: an O(N) scan, done once at construction;
    /// lazy: any FO-capable tier with positive draw mass).
    pub fn any_fo_capable(&self, cost: &CostModel) -> bool {
        match self {
            Population::Materialized(c) => c.iter().any(|x| x.is_high()),
            Population::Lazy(_) => self.fo_share(cost) > 0.0,
        }
    }

    /// Sample `want` warm-phase participants from the FO-capable
    /// sub-population, drawing from `rng`.
    ///
    /// Materialized mode reproduces the seed repo's stream exactly: one
    /// `choose(|H|, p)` over the high-id list, `p = want.clamp(1, |H|)`.
    /// Lazy mode cannot enumerate H, so it rejection-samples distinct ids
    /// against the on-demand profile — deterministic (all draws come from
    /// the caller's `rng`), terminating in expectation `want / fo_frac`
    /// draws, with a hard attempt budget as the pathological-scenario
    /// guard.
    pub fn sample_high(
        &self,
        rng: &mut Xoshiro256,
        want: usize,
        cost: &CostModel,
    ) -> anyhow::Result<Vec<usize>> {
        match self {
            Population::Materialized(c) => {
                let hi: Vec<usize> =
                    c.iter().filter(|x| x.is_high()).map(|x| x.id).collect();
                anyhow::ensure!(!hi.is_empty(), "no FO-capable clients to warm up");
                let p = want.clamp(1, hi.len());
                Ok(rng.choose(hi.len(), p).into_iter().map(|i| hi[i]).collect())
            }
            Population::Lazy(l) => {
                let frac = self.fo_share(cost);
                anyhow::ensure!(frac > 0.0, "no FO-capable clients to warm up");
                if l.n <= WARM_ENUM_THRESHOLD {
                    // small fleet: enumerate H exactly — materialized
                    // semantics (min(want, |H|) picks, clean error when
                    // the tier mass realized no FO client at all)
                    let hi: Vec<usize> = (0..l.n)
                        .filter(|&cid| self.profile(cid).fo_capable(cost))
                        .collect();
                    anyhow::ensure!(
                        !hi.is_empty(),
                        "scenario {:?} realized no FO-capable client over {} ids \
                         (fo share {frac:.4})",
                        l.scenario.name(),
                        l.n
                    );
                    let p = want.clamp(1, hi.len());
                    return Ok(rng.choose(hi.len(), p).into_iter().map(|i| hi[i]).collect());
                }
                let p = want.clamp(1, l.n);
                // expected draws plus generous slack, hard-capped so a
                // vanishingly-thin FO tier errors fast instead of
                // spinning — and memory stays O(p), never O(draws)
                let budget = ((p as f64 / frac) as usize + p)
                    .saturating_mul(WARM_REJECTION_SLACK)
                    .min(WARM_REJECTION_CAP);
                let mut picked: Vec<usize> = Vec::with_capacity(p);
                for _ in 0..budget {
                    if picked.len() == p {
                        break;
                    }
                    let cid = rng.below(l.n);
                    // p is tens at most: a linear dedup scan beats
                    // holding every rejected id in a set
                    if picked.contains(&cid) {
                        continue;
                    }
                    if self.profile(cid).fo_capable(cost) {
                        picked.push(cid);
                    }
                }
                anyhow::ensure!(
                    !picked.is_empty(),
                    "warm sampling found no FO-capable client in {budget} draws \
                     (scenario {:?}, fo share {frac:.4})",
                    l.scenario.name()
                );
                if picked.len() < p {
                    // the round proceeds with a smaller cohort, but never
                    // silently: a thin FO tier exhausting the draw budget
                    // is an operator-visible signal
                    eprintln!(
                        "[population] warm cohort short: {}/{p} FO-capable \
                         clients found in {budget} draws (fo share {frac:.6})",
                        picked.len()
                    );
                }
                Ok(picked)
            }
        }
    }

    /// Approximate resident bytes of the population's per-client state —
    /// the peak-RSS proxy of `exp fleet` and the O(N)-avoidance
    /// acceptance test. Materialized mode sums the real storage
    /// (profiles, tier strings, shard index lists); lazy mode is the
    /// O(1) descriptor.
    pub fn approx_state_bytes(&self) -> usize {
        match self {
            Population::Materialized(c) => {
                c.iter()
                    .map(|x| {
                        std::mem::size_of::<ClientState>()
                            + x.profile.tier.len()
                            + x.data.indices.len() * std::mem::size_of::<usize>()
                    })
                    .sum()
            }
            Population::Lazy(l) => {
                std::mem::size_of::<LazyPopulation>()
                    + match &l.scenario {
                        Scenario::Binary => 0,
                        Scenario::Custom(s) => s
                            .tiers
                            .iter()
                            .map(|t| std::mem::size_of_val(t) + t.name.len())
                            .sum(),
                    }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// sparse per-client ledgers
// ---------------------------------------------------------------------------

/// Sparse per-client sync ledger: `get(cid)` is the round whose entering
/// global the client can reconstruct (default 0 = init weights, the
/// population-wide starting state). Only clients that ever *deviated*
/// from the default occupy memory, so the ledger is O(participants), not
/// O(N) — the fold (`advance` = pointwise max) reproduces the dense
/// `Vec<usize>` it replaced bit-for-bit
/// (`prop_sparse_sync_folds_match_dense` + the churn-preset mirror test
/// in `fed::server`).
#[derive(Debug, Clone, Default)]
pub struct SparseSync {
    // detlint: allow(hash-iter) — keyed get/insert/len only, never
    // iterated, so the map's nondeterministic order cannot reach any
    // fold or trace (to_dense walks 0..n by index, not the map)
    map: std::collections::HashMap<usize, usize>,
}

impl SparseSync {
    /// Round the client is synced to (0 = the population default).
    pub fn get(&self, cid: usize) -> usize {
        self.map.get(&cid).copied().unwrap_or(0)
    }

    /// Fold `synced[cid] = max(synced[cid], round)` — the dense ledger's
    /// update, recording an entry only on actual deviation.
    pub fn advance(&mut self, cid: usize, round: usize) {
        if round > self.get(cid) {
            self.map.insert(cid, round);
        }
    }

    /// Clients holding a non-default entry (bounded by total distinct
    /// participants, never by N).
    pub fn deviated(&self) -> usize {
        self.map.len()
    }

    /// Materialize the dense equivalent (reference/testing only).
    pub fn to_dense(&self, n: usize) -> Vec<usize> {
        (0..n).map(|cid| self.get(cid)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, GenConfig, SynthKind};
    use std::sync::Arc;

    fn src(n: usize) -> Source {
        Source::Image(Arc::new(generate(SynthKind::Synth10, n, GenConfig::default())))
    }

    fn probe_cost() -> CostModel {
        CostModel::generic(7690, 32)
    }

    fn fleet_pop(n: usize) -> Population {
        Population::lazy(
            n,
            0,
            7,
            Scenario::preset("fleet").unwrap(),
            probe_cost(),
            src(200),
        )
        .unwrap()
    }

    #[test]
    fn lazy_population_state_is_o1_in_n() {
        let small = fleet_pop(1_000);
        let huge = fleet_pop(10_000_000);
        assert_eq!(small.approx_state_bytes(), huge.approx_state_bytes());
        assert!(huge.approx_state_bytes() < 4096, "{}", huge.approx_state_bytes());
        assert_eq!(huge.len(), 10_000_000);
        assert!(huge.is_lazy());
    }

    #[test]
    fn lazy_shards_are_deterministic_distinct_views() {
        let pop = fleet_pop(10_000_000);
        let a = pop.data(9_999_999);
        let b = pop.data(9_999_999);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.n(), pop.n_samples(9_999_999));
        assert_eq!(a.n(), LAZY_SHARD_SAMPLES.min(200));
        // indices are distinct and in range
        let mut sorted = a.indices.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), a.n());
        assert!(sorted.iter().all(|&i| i < 200));
        // a different client draws a different shard
        let c = pop.data(42);
        assert_ne!(a.indices, c.indices);
        // an empty source is rejected at construction, not at first draw
        assert!(
            Population::lazy(10, 0, 7, Scenario::Binary, probe_cost(), src(0)).is_err()
        );
    }

    #[test]
    fn lazy_profiles_and_resources_agree_with_scenario_derivation() {
        let pop = fleet_pop(1_000);
        let cost = probe_cost();
        let scenario = Scenario::preset("fleet").unwrap();
        for cid in [0usize, 1, 999] {
            let p = pop.profile(cid);
            assert_eq!(p, scenario.profile_of(1_000, 0, 7, cid, &cost));
            assert_eq!(
                pop.is_high(cid, &cost),
                p.fo_capable(&cost),
                "cid {cid}"
            );
        }
        let share = pop.fo_share(&cost);
        assert!((0.0..0.1).contains(&share), "{share}");
        assert!(pop.any_fo_capable(&cost));
    }

    #[test]
    fn lazy_warm_sampling_finds_the_backbone_deterministically() {
        let pop = fleet_pop(1_000_000);
        let cost = probe_cost();
        let mut r1 = Xoshiro256::seed_from(5);
        let mut r2 = Xoshiro256::seed_from(5);
        let a = pop.sample_high(&mut r1, 5, &cost).unwrap();
        let b = pop.sample_high(&mut r2, 5, &cost).unwrap();
        assert_eq!(a, b, "same rng stream, same picks");
        assert_eq!(a.len(), 5);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5, "distinct picks");
        for &cid in &a {
            assert!(pop.is_high(cid, &cost), "cid {cid} is not FO-capable");
        }
        // small fleets take the exact-enumeration path: picks are
        // min(want, |H|), distinct, FO-capable, deterministic
        let small = fleet_pop(2_000);
        let hi_n = (0..2_000).filter(|&c| small.is_high(c, &cost)).count();
        assert!(hi_n > 0, "2% tier over 2000 ids should realize someone");
        let mut r = Xoshiro256::seed_from(9);
        let picks = small.sample_high(&mut r, 5_000, &cost).unwrap();
        assert_eq!(picks.len(), 5_000usize.clamp(1, hi_n));
        for &cid in &picks {
            assert!(small.is_high(cid, &cost));
        }
        // an all-FO scenario reports full FO mass...
        let all_fo = Population::lazy(
            1_000,
            0,
            7,
            Scenario::preset("uniform-high").unwrap(),
            probe_cost(),
            src(100),
        )
        .unwrap();
        assert!(all_fo.any_fo_capable(&probe_cost()));
        // ...and a ZO-only scenario refuses instead of spinning
        let no_fo = Population::lazy(
            1_000,
            0,
            7,
            Scenario::load(r#"{"tiers": [{"frac": 1.0, "mem": "zo"}]}"#).unwrap(),
            probe_cost(),
            src(100),
        )
        .unwrap();
        assert!(!no_fo.any_fo_capable(&probe_cost()));
        let mut r = Xoshiro256::seed_from(0);
        assert!(no_fo.sample_high(&mut r, 3, &probe_cost()).is_err());
    }

    #[test]
    fn sparse_sync_defaults_advances_and_counts_deviations() {
        let mut s = SparseSync::default();
        assert_eq!(s.get(123_456_789), 0, "default is the init state");
        assert_eq!(s.deviated(), 0);
        s.advance(7, 0); // advancing to the default records nothing
        assert_eq!(s.deviated(), 0);
        s.advance(7, 3);
        s.advance(7, 2); // regressions are ignored (max fold)
        assert_eq!(s.get(7), 3);
        s.advance(9_999_999, 1);
        assert_eq!(s.deviated(), 2);
        assert_eq!(s.to_dense(10)[7], 3);
        assert_eq!(s.to_dense(10)[0], 0);
    }

    #[test]
    fn prop_sparse_sync_folds_match_dense() {
        // satellite: random advance streams — the sparse fold reproduces
        // the dense Vec ledger exactly, and memory stays bounded by the
        // distinct clients touched
        crate::util::prop::run_prop("sparse_sync_fold", 80, |g| {
            let mut rng = g.rng();
            let n = 2 + rng.below(g.size.max(1) * 4);
            let ops = rng.below(g.size.max(1) * 8);
            let mut dense = vec![0usize; n];
            let mut sparse = SparseSync::default();
            let mut touched = std::collections::BTreeSet::new();
            for _ in 0..ops {
                let cid = rng.below(n);
                let round = rng.below(30);
                touched.insert(cid);
                dense[cid] = dense[cid].max(round);
                sparse.advance(cid, round);
            }
            if sparse.to_dense(n) != dense {
                return Err("sparse fold diverged from dense ledger".into());
            }
            if sparse.deviated() > touched.len() {
                return Err(format!(
                    "{} entries for {} touched clients",
                    sparse.deviated(),
                    touched.len()
                ));
            }
            Ok(())
        });
    }
}
