//! Server-side aggregation: n-weighted FedAvg and the FedAdam server
//! optimizer (Reddi et al., 2020; the paper's §4.4 comparison).

use crate::config::ServerOpt;
use crate::model::params::ParamVec;

/// n-weighted average of client weight vectors (Algorithm 1 line 7).
pub fn weighted_average(updates: &[(ParamVec, f64)]) -> ParamVec {
    assert!(!updates.is_empty(), "no updates to aggregate");
    let dim = updates[0].0.dim();
    let total: f64 = updates.iter().map(|(_, w)| *w).sum();
    assert!(total > 0.0, "zero total weight");
    let mut out = ParamVec::zeros(dim);
    for (p, w) in updates {
        assert_eq!(p.dim(), dim, "dim mismatch in aggregation");
        out.axpy((w / total) as f32, p);
    }
    out
}

/// Server optimizer state: consumes the aggregated *pseudo-gradient*
/// Δ = avg(w_i) − w_global and steps the global weights.
#[derive(Debug, Clone)]
pub enum ServerOptState {
    Sgd,
    Adam {
        beta1: f64,
        beta2: f64,
        eps: f64,
        m: Vec<f64>,
        v: Vec<f64>,
        t: u64,
    },
}

impl ServerOptState {
    pub fn new(opt: ServerOpt, dim: usize) -> Self {
        match opt {
            ServerOpt::Sgd => ServerOptState::Sgd,
            ServerOpt::Adam { beta1, beta2, eps } => ServerOptState::Adam {
                beta1,
                beta2,
                eps,
                m: vec![0.0; dim],
                v: vec![0.0; dim],
                t: 0,
            },
        }
    }

    /// global ← global + step(lr, Δ). For SGD this is `global += lr·Δ`
    /// (lr = 1 recovers plain FedAvg); for Adam, Δ plays the role of the
    /// negative gradient as in Reddi et al.
    pub fn apply(&mut self, global: &mut ParamVec, delta: &ParamVec, lr: f32) {
        match self {
            ServerOptState::Sgd => global.axpy(lr, delta),
            ServerOptState::Adam {
                beta1,
                beta2,
                eps,
                m,
                v,
                t,
            } => {
                *t += 1;
                let bc1 = 1.0 - beta1.powi(*t as i32);
                let bc2 = 1.0 - beta2.powi(*t as i32);
                for i in 0..global.dim() {
                    let g = delta.0[i] as f64;
                    m[i] = *beta1 * m[i] + (1.0 - *beta1) * g;
                    v[i] = *beta2 * v[i] + (1.0 - *beta2) * g * g;
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    global.0[i] += (lr as f64 * mhat / (vhat.sqrt() + *eps)) as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_respects_weights() {
        let a = ParamVec(vec![0.0, 0.0]);
        let b = ParamVec(vec![4.0, 8.0]);
        let avg = weighted_average(&[(a, 3.0), (b, 1.0)]);
        assert_eq!(avg.0, vec![1.0, 2.0]);
    }

    #[test]
    fn single_update_is_identity() {
        let a = ParamVec(vec![1.5, -2.0]);
        let avg = weighted_average(&[(a.clone(), 7.0)]);
        assert_eq!(avg, a);
    }

    #[test]
    #[should_panic(expected = "no updates")]
    fn empty_aggregation_panics() {
        weighted_average(&[]);
    }

    #[test]
    fn sgd_server_is_fedavg_at_lr1() {
        let mut opt = ServerOptState::new(ServerOpt::Sgd, 2);
        let mut global = ParamVec(vec![1.0, 1.0]);
        let delta = ParamVec(vec![0.5, -0.5]); // avg(w_i) − w
        opt.apply(&mut global, &delta, 1.0);
        assert_eq!(global.0, vec![1.5, 0.5]);
    }

    #[test]
    fn adam_steps_toward_delta_sign() {
        let mut opt = ServerOptState::new(ServerOpt::adam(), 3);
        let mut global = ParamVec(vec![0.0; 3]);
        let delta = ParamVec(vec![1.0, -1.0, 0.0]);
        for _ in 0..10 {
            opt.apply(&mut global, &delta, 0.01);
        }
        assert!(global.0[0] > 0.0);
        assert!(global.0[1] < 0.0);
        assert_eq!(global.0[2], 0.0);
        // Adam normalizes magnitudes: |step| ≈ lr per iteration
        assert!((global.0[0] - 0.1).abs() < 0.02, "{}", global.0[0]);
    }

    #[test]
    fn adam_state_persists_momentum() {
        let mut opt = ServerOptState::new(ServerOpt::adam(), 1);
        let mut g1 = ParamVec(vec![0.0]);
        opt.apply(&mut g1, &ParamVec(vec![1.0]), 0.1);
        // after a +1 delta, a zero delta still moves (momentum)
        let before = g1.0[0];
        opt.apply(&mut g1, &ParamVec(vec![0.0]), 0.1);
        assert!(g1.0[0] > before);
    }
}
