//! Communication & memory cost accounting (Table 1).
//!
//! Two complementary views:
//! * [`CostModel`] — the paper's *analytic* formulas (§A.3): per-round
//!   up/down-link bytes and the eq. 4/5 on-device memory footprints,
//!   parameterized by model size and activation sizes. Evaluated both at
//!   our models' manifest sizes and at the paper's true ResNet18 numbers.
//! * [`CommLedger`] — *measured* bytes actually "transmitted" by the
//!   simulated protocol, accumulated per round by the federation loop.

use crate::model::manifest::ModelEntry;

/// Bytes per f32/i64 on the wire.
const F32: u64 = 4;
const SEED: u64 = 8;

/// Analytic per-client, per-round costs (§A.3.1-§A.3.2).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// model parameter count P
    pub params: u64,
    /// Σ_ℓ N_ℓ·W_ℓ·H_ℓ — total stored activations per example (eq. 4)
    pub act_sum: u64,
    /// max_ℓ N_ℓ·W_ℓ·H_ℓ — the largest single activation (eq. 5)
    pub act_max: u64,
    /// batch size BS
    pub batch: u64,
}

impl CostModel {
    pub fn from_manifest(entry: &ModelEntry) -> Self {
        Self {
            params: entry.dim as u64,
            act_sum: entry.act.sum as u64,
            act_max: entry.act.max as u64,
            batch: entry.batch as u64,
        }
    }

    /// The paper's ResNet18 on CIFAR-10 (torchinfo, Fig. 8): 11,173,962
    /// params; Σ activations solved from the paper's reported 533.2 MB at
    /// BS=64 via eq. 4 (≈1.73M elements/example, consistent with
    /// torchinfo's 9.83 MB fwd+bwd pass size); largest activation is the
    /// stem output 64×32×32.
    pub fn paper_resnet18() -> Self {
        Self {
            params: 11_173_962,
            act_sum: 1_733_626,
            act_max: 64 * 32 * 32,
            batch: 64,
        }
    }

    // ----- communication (§A.3.1) ---------------------------------------

    /// FedAvg up-link: full weights. `comm_full = P * 4` bytes.
    pub fn fedavg_uplink_bytes(&self) -> u64 {
        self.params * F32
    }

    /// FedAvg down-link: full weights.
    pub fn fedavg_downlink_bytes(&self) -> u64 {
        self.params * F32
    }

    /// ZO up-link: S scalars.
    pub fn zo_uplink_bytes(&self, s: u64) -> u64 {
        s * F32
    }

    /// ZO down-link: all S·K (seed, ΔL) pairs broadcast to each client
    /// (the paper counts `SK * 4e-6` MB — ΔL floats only; we also count
    /// the 8-byte seeds for the honest total).
    pub fn zo_downlink_bytes(&self, s: u64, k: u64) -> u64 {
        s * SEED + s * k * (F32 + SEED)
    }

    /// The paper's own down-link accounting (ΔL floats only), for the
    /// exact Table 1 reproduction.
    pub fn zo_downlink_bytes_paper(&self, s: u64, k: u64) -> u64 {
        s * k * F32
    }

    // ----- memory (§A.3.2) ----------------------------------------------

    /// eq. 4: backprop memory = (2P + BS·Σ acts) · 4 bytes
    /// (weights + gradients + all stored activations).
    pub fn backprop_mem_bytes(&self) -> u64 {
        (2 * self.params + self.batch * self.act_sum) * F32
    }

    /// eq. 5: ZO memory = (2P + BS·max act) · 4 bytes (two weight copies —
    /// w and w±εz — plus only the largest live activation).
    pub fn zo_mem_bytes(&self) -> u64 {
        (2 * self.params + self.batch * self.act_max) * F32
    }

    /// Memory a device needs before it can run backprop-based training —
    /// the FO-eligibility threshold of the `sim` scenario engine. Eq. 4
    /// strictly dominates eq. 5 for any multi-layer model; the `max`
    /// keeps the threshold strictly above the ZO footprint even for
    /// degenerate single-activation models, so the FO/ZO class split is
    /// always well-defined.
    pub fn fo_threshold_bytes(&self) -> u64 {
        self.backprop_mem_bytes().max(self.zo_mem_bytes() + 1)
    }

    /// Synthetic cost profile for backends without a compiled-model
    /// manifest (the linear probe): activations are modeled as fixed
    /// fractions of the parameter count, keeping eq. 4 > eq. 5 strictly
    /// at every dim so capability thresholds stay ordered.
    pub fn generic(params: u64, batch: u64) -> Self {
        Self {
            params,
            act_sum: (params / 4).max(2),
            act_max: (params / 16).max(1),
            batch: batch.max(1),
        }
    }

    /// The paper's own Table 1 ZO figure, 89.4 MB = 2P·4: the activation
    /// term is dropped (it is <20% of 2P for ResNet18 and the table tracks
    /// the parameter-dominated footprint).
    pub fn zo_mem_bytes_paper(&self) -> u64 {
        2 * self.params * F32
    }

    /// Table 1's headline ratio (≈6× for ResNet18).
    pub fn mem_savings_ratio(&self) -> f64 {
        self.backprop_mem_bytes() as f64 / self.zo_mem_bytes() as f64
    }
}

/// Measured byte counters, accumulated by the federation loop.
#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    pub up_total: u64,
    pub down_total: u64,
    /// per-round (up, down) history
    pub per_round: Vec<(u64, u64)>,
    /// of `down_total`, the bytes spent on catch-up (snapshot/tail
    /// replay downloads for stale clients — the `ckpt` subsystem's
    /// `min(snapshot_bytes, tail_seed_bytes)` charges, measured with
    /// partial transmissions). 0 when `ckpt_every = 0`.
    pub catch_up_down_total: u64,
    /// total probes issued across every ZO round (the adaptive-S
    /// accounting counterpart of the byte totals: uniform runs issue
    /// `rounds · Q · S · steps`, adaptive runs whatever the per-client
    /// planner affords)
    pub seeds_total: u64,
    /// per-edge attribution under the two-tier topology (`--edges E`):
    /// indexed by edge, grown on demand, empty for flat runs. Every byte
    /// here is a *sub-attribution* of the flat totals above — the sums
    /// over edges reduce to `up_total` / `down_total` /
    /// `catch_up_down_total` bit-exactly (all-integer arithmetic; pinned
    /// by the `zo_ledger_additivity` property).
    pub per_edge: Vec<EdgeLedger>,
}

/// One edge aggregator's slice of the round traffic: what crossed *its*
/// backhaul, including the catch-up payloads served from its local
/// checkpoint cache (charged at edge rates by the `sim` layer).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EdgeLedger {
    pub up: u64,
    pub down: u64,
    pub catch_up_down: u64,
}

impl CommLedger {
    pub fn record_round(&mut self, up: u64, down: u64) {
        self.up_total += up;
        self.down_total += down;
        self.per_round.push((up, down));
    }

    /// Attribute `bytes` of already-recorded downlink to catch-up.
    pub fn record_catch_up(&mut self, bytes: u64) {
        self.catch_up_down_total += bytes;
    }

    /// Count probes issued this round (seed derivations, not bytes).
    pub fn record_seeds(&mut self, seeds: u64) {
        self.seeds_total += seeds;
    }

    /// Attribute `(up, down)` of already-recorded round traffic to
    /// `edge`, growing the per-edge table on demand. Does NOT touch the
    /// flat totals — callers book the flat round once via
    /// [`record_round`](Self::record_round) and then split it here.
    pub fn record_edge_round(&mut self, edge: usize, up: u64, down: u64) {
        self.edge_mut(edge).up += up;
        self.edge_mut(edge).down += down;
    }

    /// Attribute `bytes` of already-recorded catch-up downlink to the
    /// edge whose local checkpoint cache served it.
    pub fn record_edge_catch_up(&mut self, edge: usize, bytes: u64) {
        self.edge_mut(edge).catch_up_down += bytes;
    }

    fn edge_mut(&mut self, edge: usize) -> &mut EdgeLedger {
        if edge >= self.per_edge.len() {
            self.per_edge.resize(edge + 1, EdgeLedger::default());
        }
        &mut self.per_edge[edge]
    }

    /// Sum of the per-edge attributions `(up, down, catch_up_down)` —
    /// equals the flat totals whenever the caller attributed every round
    /// (i.e. any two-tier run; flat runs leave the table empty).
    pub fn edge_totals(&self) -> (u64, u64, u64) {
        self.per_edge.iter().fold((0, 0, 0), |acc, e| {
            (acc.0 + e.up, acc.1 + e.down, acc.2 + e.catch_up_down)
        })
    }

    pub fn rounds(&self) -> usize {
        self.per_round.len()
    }
}

pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_fedavg_numbers() {
        // Table 1: FedAvg 44.7 MB up/down for ResNet18, 533.2 MB on-device.
        let m = CostModel::paper_resnet18();
        let up = mb(m.fedavg_uplink_bytes());
        assert!((up - 44.7).abs() < 0.1, "uplink {up} MB");
        let mem = mb(m.backprop_mem_bytes());
        assert!(
            (mem - 533.2).abs() < 1.0,
            "backprop mem {mem} MB (paper 533.2)"
        );
        let zo_mem = mb(m.zo_mem_bytes_paper());
        assert!((zo_mem - 89.4).abs() < 0.5, "zo mem {zo_mem} MB (paper 89.4)");
        // the honest eq. 5 value (incl. the live activation) stays the
        // same order of magnitude
        assert!(mb(m.zo_mem_bytes()) < 120.0);
    }

    #[test]
    fn table1_zo_numbers() {
        // ZO up-link: S·4e-6 MB — i.e. 12 bytes for S=3.
        let m = CostModel::paper_resnet18();
        assert_eq!(m.zo_uplink_bytes(3), 12);
        assert_eq!(m.zo_downlink_bytes_paper(3, 10), 120);
        // honest accounting is larger but still ~10^6 smaller than FedAvg
        let honest = m.zo_downlink_bytes(3, 10);
        assert!(honest < m.fedavg_downlink_bytes() / 10_000);
    }

    #[test]
    fn memory_ratio_matches_paper_magnitude() {
        // "one round of ZO saves ≈ 6× the memory of FedAvg" (§A.3.2)
        let m = CostModel::paper_resnet18();
        let r = m.backprop_mem_bytes() as f64 / m.zo_mem_bytes_paper() as f64;
        assert!((5.0..7.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn generic_cost_model_orders_thresholds() {
        // the scenario engine's contract: ZO footprint strictly below the
        // FO threshold at every dim, including tiny test models
        for params in [1u64, 6, 15, 16, 17, 7690, 175_258, 11_173_962] {
            for batch in [1u64, 16, 64] {
                let m = CostModel::generic(params, batch);
                assert!(
                    m.zo_mem_bytes() < m.fo_threshold_bytes(),
                    "params={params} batch={batch}"
                );
                assert!(m.fo_threshold_bytes() >= m.backprop_mem_bytes());
            }
        }
        // the real ResNet18 numbers: eq. 4 already dominates, so the
        // threshold IS the backprop footprint
        let m = CostModel::paper_resnet18();
        assert_eq!(m.fo_threshold_bytes(), m.backprop_mem_bytes());
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        l.record_round(10, 20);
        l.record_round(1, 2);
        assert_eq!(l.up_total, 11);
        assert_eq!(l.down_total, 22);
        assert_eq!(l.rounds(), 2);
        // catch-up is a sub-attribution of down, not extra bytes
        assert_eq!(l.catch_up_down_total, 0);
        l.record_catch_up(5);
        l.record_catch_up(2);
        assert_eq!(l.catch_up_down_total, 7);
        assert_eq!(l.down_total, 22);
        // issued-seed accounting is a separate counter, not bytes
        assert_eq!(l.seeds_total, 0);
        l.record_seeds(12);
        l.record_seeds(9);
        assert_eq!(l.seeds_total, 21);
        assert_eq!((l.up_total, l.down_total), (11, 22));
    }

    #[test]
    fn per_edge_attribution_grows_and_reduces() {
        let mut l = CommLedger::default();
        // flat runs never touch the table
        l.record_round(10, 20);
        assert!(l.per_edge.is_empty());
        assert_eq!(l.edge_totals(), (0, 0, 0));
        // two-tier: the flat round is split across edges out of order,
        // growing the table on demand and leaving gaps zeroed
        l.record_edge_round(2, 6, 15);
        l.record_edge_round(0, 4, 5);
        assert_eq!(l.per_edge.len(), 3);
        assert_eq!(l.per_edge[1], EdgeLedger::default());
        assert_eq!(l.edge_totals(), (10, 20, 0));
        // edge attribution is a split, not extra bytes
        assert_eq!((l.up_total, l.down_total), (10, 20));
        // catch-up sub-attributes the same way
        l.record_catch_up(7);
        l.record_edge_catch_up(2, 7);
        assert_eq!(l.edge_totals(), (10, 20, 7));
        assert_eq!(l.edge_totals().2, l.catch_up_down_total);
    }
}
