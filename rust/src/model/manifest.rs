//! `artifacts/manifest.json` loader: the contract between the Python
//! compile path and the Rust runtime.
//!
//! The manifest describes, per model variant: the flat parameter layout
//! (named tensors with offsets — what HeteroFL slicing and He-init need),
//! the AOT batch/input shapes, activation-size summaries for the eq. 4/5
//! memory model, and the artifact file per entry point.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub fan_in: usize,
    pub kind: String,
    pub fill: f32,
}

/// Activation summary (elements per example) for the memory cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActSummary {
    pub sum: usize,
    pub max: usize,
}

/// One model variant's full description.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub dim: usize,
    pub batch: usize,
    pub kind: String, // "image" | "lm"
    pub classes: usize,
    pub input_shape: Vec<usize>,
    pub mask_shape: Vec<usize>,
    pub act: ActSummary,
    pub params: Vec<TensorSpec>,
    /// entry point -> artifact file name (relative to the artifacts dir)
    pub artifacts: BTreeMap<String, String>,
}

impl ModelEntry {
    pub fn tensor(&self, name: &str) -> Option<&TensorSpec> {
        self.params.iter().find(|t| t.name == name)
    }

    /// Samples per artifact invocation (mask elements = loss rows).
    pub fn mask_len(&self) -> usize {
        self.mask_shape.iter().product()
    }

    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn artifact_path(&self, dir: &Path, entry: &str) -> anyhow::Result<PathBuf> {
        let f = self
            .artifacts
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("model {} has no artifact {entry:?}", self.name))?;
        Ok(dir.join(f))
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(
            root.req("version")?.as_usize() == Some(1),
            "unsupported manifest version"
        );
        let mut models = BTreeMap::new();
        for (name, m) in root
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models not an object"))?
        {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest"))
    }

    /// Validate internal consistency (offsets contiguous, dims add up,
    /// artifact files present on disk). Called by `zowarmup check`.
    pub fn validate(&self) -> anyhow::Result<()> {
        for m in self.models.values() {
            let mut offset = 0;
            for t in &m.params {
                anyhow::ensure!(
                    t.offset == offset,
                    "{}: tensor {} offset {} != expected {}",
                    m.name,
                    t.name,
                    t.offset,
                    offset
                );
                anyhow::ensure!(
                    t.size == t.shape.iter().product::<usize>(),
                    "{}: tensor {} size mismatch",
                    m.name,
                    t.name
                );
                offset += t.size;
            }
            anyhow::ensure!(
                offset == m.dim,
                "{}: params sum {} != dim {}",
                m.name,
                offset,
                m.dim
            );
            for entry in m.artifacts.keys() {
                let p = m.artifact_path(&self.dir, entry)?;
                anyhow::ensure!(p.exists(), "missing artifact file {p:?}");
            }
        }
        Ok(())
    }
}

fn parse_model(name: &str, m: &Json) -> anyhow::Result<ModelEntry> {
    let usize_of = |j: &Json, k: &str| -> anyhow::Result<usize> {
        j.req(k)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("{name}: {k} not a number"))
    };
    let vec_of = |j: &Json, k: &str| -> anyhow::Result<Vec<usize>> {
        Ok(j.req(k)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("{name}: {k} not an array"))?
            .iter()
            .map(|x| x.as_usize().unwrap_or(0))
            .collect())
    };
    let mut params = Vec::new();
    for p in m
        .req("params")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{name}: params not an array"))?
    {
        params.push(TensorSpec {
            name: p
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("param name"))?
                .to_string(),
            shape: vec_of(p, "shape")?,
            offset: usize_of(p, "offset")?,
            size: usize_of(p, "size")?,
            fan_in: usize_of(p, "fan_in")?,
            kind: p
                .req("kind")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("param kind"))?
                .to_string(),
            fill: p.req("fill")?.as_f64().unwrap_or(0.0) as f32,
        });
    }
    let act = m.req("act")?;
    let mut artifacts = BTreeMap::new();
    if let Some(obj) = m.req("artifacts")?.as_obj() {
        for (k, v) in obj {
            artifacts.insert(
                k.clone(),
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifact path"))?
                    .to_string(),
            );
        }
    }
    Ok(ModelEntry {
        name: name.to_string(),
        dim: usize_of(m, "dim")?,
        batch: usize_of(m, "batch")?,
        kind: m
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("{name}: kind"))?
            .to_string(),
        classes: usize_of(m, "classes")?,
        input_shape: vec_of(m, "input_shape")?,
        mask_shape: vec_of(m, "mask_shape")?,
        act: ActSummary {
            sum: usize_of(act, "sum")?,
            max: usize_of(act, "max")?,
        },
        params,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const MINI: &str = r#"{
      "version": 1,
      "models": {
        "tiny": {
          "dim": 6, "batch": 2, "kind": "image", "classes": 2,
          "input_shape": [2, 1, 1, 1], "mask_shape": [2],
          "act": {"sum": 10, "max": 4},
          "params": [
            {"name": "w", "shape": [1, 4], "offset": 0, "size": 4,
             "fan_in": 1, "kind": "dense", "fill": 0.0},
            {"name": "b", "shape": [2], "offset": 4, "size": 2,
             "fan_in": 0, "kind": "bias", "fill": 0.5}
          ],
          "artifacts": {}
        }
      }
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI, PathBuf::from("/tmp")).unwrap();
        let t = m.model("tiny").unwrap();
        assert_eq!(t.dim, 6);
        assert_eq!(t.params.len(), 2);
        assert_eq!(t.tensor("b").unwrap().fill, 0.5);
        assert_eq!(t.mask_len(), 2);
        m.validate().unwrap();
    }

    #[test]
    fn validate_catches_gaps() {
        let bad = MINI.replace("\"offset\": 4", "\"offset\": 5");
        let m = Manifest::parse(&bad, PathBuf::from("/tmp")).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_model_is_error() {
        let m = Manifest::parse(MINI, PathBuf::from("/tmp")).unwrap();
        assert!(m.model("nope").is_err());
    }
}
