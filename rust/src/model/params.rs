//! Flat parameter vector: init, axpy, and the seeded-perturbation ops that
//! implement the ZOUPDATE reconstruction of Algorithm 1.

use crate::model::manifest::ModelEntry;
use crate::util::rng::{Distribution, PerturbStream, Xoshiro256};

/// The global model state: a single flat `f32` vector whose layout is
/// defined by the manifest. All federated arithmetic happens here.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec(pub Vec<f32>);

impl ParamVec {
    pub fn zeros(dim: usize) -> Self {
        ParamVec(vec![0.0; dim])
    }

    /// He-init per tensor (std = sqrt(2/fan_in)); constant `fill` tensors
    /// (norm scales/biases, biases) are set exactly. Mirrors
    /// `python/compile/models/common.py::init_flat` in spirit — bitwise
    /// parity is not required (each run owns its init).
    pub fn he_init(entry: &ModelEntry, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from(seed ^ 0x1417_5EED);
        let mut v = vec![0.0f32; entry.dim];
        for t in &entry.params {
            let part = &mut v[t.offset..t.offset + t.size];
            if t.fan_in == 0 {
                part.fill(t.fill);
            } else {
                let std = (2.0 / t.fan_in as f64).sqrt();
                for x in part {
                    *x = (rng.normal() * std) as f32;
                }
            }
        }
        ParamVec(v)
    }

    pub fn dim(&self) -> usize {
        self.0.len()
    }

    /// self += alpha * other  (FedAvg accumulation, server opt steps)
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.0 {
            *a *= alpha;
        }
    }

    /// self += coeff * z(seed)  — the ZOUPDATE hot loop. z is regenerated
    /// from the seed (never stored/transmitted), matching the paper's
    /// S·4-byte up-link. coeff already folds η, ΔL/(2ε), weighting and the
    /// sign, so one call applies one (seed, ΔL) pair.
    pub fn perturb_axpy(&mut self, seed: u64, tau: f32, dist: Distribution, coeff: f32) {
        let mut stream = PerturbStream::new(seed, tau, dist);
        perturb_axpy_slice(&mut self.0, &mut stream, coeff);
    }

    /// out = self + coeff*z(seed) without touching self (SPSA's w ± εz).
    pub fn perturbed(&self, seed: u64, tau: f32, dist: Distribution, coeff: f32) -> ParamVec {
        let mut out = self.clone();
        out.perturb_axpy(seed, tau, dist, coeff);
        out
    }

    pub fn l2(&self) -> f64 {
        self.0.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.0.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }
}

/// Streaming axpy kernel over a slice (also used by the in-place two-sided
/// flip: w+εz -> w−εz is one axpy with −2εz). Delegates to the stream's
/// branchless fast path (§Perf L3: 350 M/s → memory-bound after the
/// bit-XOR rewrite; see EXPERIMENTS.md §Perf).
#[inline]
pub fn perturb_axpy_slice(w: &mut [f32], stream: &mut PerturbStream, coeff: f32) {
    stream.axpy(w, coeff);
}

/// Fused multi-seed axpy: `w += Σ_k coeff_k · z(seed_k)` in a SINGLE pass
/// over `w`, interleaving all perturbation streams per 64-element block so
/// the weight vector is read/written once instead of once per seed
/// (§Perf L3: a ZOUPDATE applies Q·S = 30+ seeds per round; this cuts its
/// memory traffic by that factor). Bit consumption per stream is identical
/// to [`PerturbStream::axpy`] (LSB-first, one u64 per 64-block), so the
/// result equals the sequential application up to f32 addition order.
pub fn perturb_axpy_many(w: &mut [f32], items: &[(u64, f32)], tau: f32, dist: Distribution) {
    if items.is_empty() {
        return;
    }
    if dist != Distribution::Rademacher || items.len() == 1 {
        for &(seed, coeff) in items {
            let mut stream = PerturbStream::new(seed, tau, dist);
            stream.axpy(w, coeff);
        }
        return;
    }
    let mut streams = rademacher_streams(items, tau, 0);
    fused_rademacher_axpy(w, &mut streams);
}

/// Build the interleaved stream set for the fused Rademacher pass, with
/// each stream fast-forwarded by `skip_blocks` u64 draws (= `skip_blocks`
/// 64-element weight blocks — the shard-offset contract of
/// [`Xoshiro256::discard`]).
fn rademacher_streams(
    items: &[(u64, f32)],
    tau: f32,
    skip_blocks: u64,
) -> Vec<(Xoshiro256, u32)> {
    items
        .iter()
        .map(|&(seed, coeff)| {
            let mut rng = Xoshiro256::seed_from(seed);
            rng.discard(skip_blocks);
            (rng, (coeff * tau).to_bits())
        })
        .collect()
}

/// The fused inner kernel: per 64-element block, draw one u64 from every
/// stream and apply the signed constant branchlessly. Consumes bits
/// LSB-first, one u64 per stream per block — identical bit consumption to
/// [`PerturbStream::axpy`], which is what makes block-aligned sharding
/// ([`perturb_axpy_many_sharded`]) bit-exact.
fn fused_rademacher_axpy(w: &mut [f32], streams: &mut [(Xoshiro256, u32)]) {
    for chunk in w.chunks_mut(64) {
        for (rng, ct_bits) in streams.iter_mut() {
            let mut bits = rng.next_u64();
            let ct = *ct_bits;
            for x in chunk.iter_mut() {
                *x += f32::from_bits(ct ^ (((bits & 1) as u32) << 31));
                bits >>= 1;
            }
        }
    }
}

/// Below this many weights the per-thread setup (spawn + stream
/// fast-forward) outweighs the memory-bandwidth win; fall back to the
/// single-threaded fused pass.
const SHARD_MIN_DIM: usize = 1 << 14;

/// Sharded variant of [`perturb_axpy_many`]: split `w` into `workers`
/// disjoint 64-aligned chunks and apply the fused pass to each on its own
/// scoped thread. Each worker rebuilds every perturbation stream from its
/// seed and fast-forwards it by `chunk_offset / 64` u64 draws, preserving
/// the LSB-first one-u64-per-64-block consumption contract — so the
/// result is **bit-identical** to the unsharded fused pass (each weight
/// element sees the same additions in the same order) for every worker
/// count. At ResNet scale this takes ZOUPDATE from single-core
/// memory-bound to parallel across the weight vector.
///
/// Gaussian streams consume a data-dependent number of draws per value
/// (Box-Muller rejection), so they cannot be fast-forwarded by counting;
/// that distribution falls back to the sequential path unchanged.
pub fn perturb_axpy_many_sharded(
    w: &mut [f32],
    items: &[(u64, f32)],
    tau: f32,
    dist: Distribution,
    workers: usize,
) {
    if workers <= 1
        || items.len() <= 1
        || dist != Distribution::Rademacher
        || w.len() < SHARD_MIN_DIM
    {
        return perturb_axpy_many(w, items, tau, dist);
    }
    let blocks = w.len().div_ceil(64);
    let shards = workers.min(blocks);
    // ceil so every worker gets a whole number of 64-blocks and the chunk
    // boundaries stay 64-aligned (the last chunk absorbs the remainder).
    let blocks_per = blocks.div_ceil(shards);
    let chunk_len = blocks_per * 64;
    std::thread::scope(|scope| {
        for (i, chunk) in w.chunks_mut(chunk_len).enumerate() {
            scope.spawn(move || {
                let skip = (i * blocks_per) as u64;
                let mut streams = rademacher_streams(items, tau, skip);
                fused_rademacher_axpy(chunk, &mut streams);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    fn mini_entry() -> ModelEntry {
        let src = r#"{
          "version": 1,
          "models": {"t": {
            "dim": 6, "batch": 1, "kind": "image", "classes": 2,
            "input_shape": [1], "mask_shape": [1],
            "act": {"sum": 1, "max": 1},
            "params": [
              {"name": "w", "shape": [4], "offset": 0, "size": 4,
               "fan_in": 4, "kind": "dense", "fill": 0.0},
              {"name": "b", "shape": [2], "offset": 4, "size": 2,
               "fan_in": 0, "kind": "norm_scale", "fill": 1.0}
            ],
            "artifacts": {}
          }}}"#;
        Manifest::parse(src, PathBuf::from("/tmp"))
            .unwrap()
            .model("t")
            .unwrap()
            .clone()
    }

    #[test]
    fn he_init_fills_and_randomizes() {
        let e = mini_entry();
        let p = ParamVec::he_init(&e, 0);
        assert_eq!(p.dim(), 6);
        assert_eq!(&p.0[4..], &[1.0, 1.0]); // fill tensor exact
        assert!(p.0[..4].iter().any(|&x| x != 0.0));
        // deterministic per seed
        assert_eq!(p, ParamVec::he_init(&e, 0));
        assert_ne!(p, ParamVec::he_init(&e, 1));
    }

    #[test]
    fn he_init_std_matches_fan_in() {
        // large synthetic tensor to check the law
        let src = r#"{
          "version": 1,
          "models": {"t": {
            "dim": 100000, "batch": 1, "kind": "image", "classes": 2,
            "input_shape": [1], "mask_shape": [1],
            "act": {"sum": 1, "max": 1},
            "params": [{"name": "w", "shape": [100000], "offset": 0,
              "size": 100000, "fan_in": 50, "kind": "dense", "fill": 0.0}],
            "artifacts": {}
          }}}"#;
        let e = Manifest::parse(src, PathBuf::from("/tmp"))
            .unwrap()
            .model("t")
            .unwrap()
            .clone();
        let p = ParamVec::he_init(&e, 7);
        let mean: f64 = p.0.iter().map(|&x| x as f64).sum::<f64>() / p.dim() as f64;
        let var: f64 =
            p.0.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / p.dim() as f64;
        let want = 2.0 / 50.0;
        assert!((var - want).abs() / want < 0.05, "var {var} want {want}");
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = ParamVec(vec![1.0, 2.0]);
        let b = ParamVec(vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.0, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.0, vec![12.0, 24.0]);
    }

    #[test]
    fn perturb_round_trip_cancels() {
        // w + c*z then + (-c)*z with the same seed must restore w exactly
        // (Rademacher: c*z is ±c·τ, exactly representable cancellation).
        let mut p = ParamVec(vec![0.25; 1000]);
        let orig = p.clone();
        p.perturb_axpy(99, 0.75, Distribution::Rademacher, 0.5);
        assert_ne!(p, orig);
        p.perturb_axpy(99, 0.75, Distribution::Rademacher, -0.5);
        assert_eq!(p, orig);
    }

    #[test]
    fn two_sided_spsa_brackets() {
        // (w+εz) and (w−εz) average back to w
        let w = ParamVec(vec![1.0; 512]);
        let plus = w.perturbed(5, 0.75, Distribution::Rademacher, 1e-2);
        let minus = w.perturbed(5, 0.75, Distribution::Rademacher, -1e-2);
        for i in 0..512 {
            let mid = (plus.0[i] + minus.0[i]) / 2.0;
            assert!((mid - 1.0).abs() < 1e-6);
            assert!((plus.0[i] - 1.0).abs() > 0.0);
        }
    }

    #[test]
    fn different_seeds_different_directions() {
        let w = ParamVec::zeros(4096);
        let a = w.perturbed(1, 1.0, Distribution::Rademacher, 1.0);
        let b = w.perturbed(2, 1.0, Distribution::Rademacher, 1.0);
        let agree = a.0.iter().zip(&b.0).filter(|(x, y)| x == y).count();
        // ~50% agreement expected for independent Rademacher vectors
        assert!((agree as f64 / 4096.0 - 0.5).abs() < 0.1);
    }

    #[test]
    fn perturb_axpy_many_matches_sequential() {
        let items: Vec<(u64, f32)> = (0..7).map(|i| (100 + i, 0.01 * (i as f32 - 3.0))).collect();
        for d in [1usize, 63, 64, 65, 1000, 4097] {
            let mut fused = vec![0.5f32; d];
            perturb_axpy_many(&mut fused, &items, 0.75, Distribution::Rademacher);
            let mut seq = vec![0.5f32; d];
            for &(seed, coeff) in &items {
                let mut s = PerturbStream::new(seed, 0.75, Distribution::Rademacher);
                s.axpy(&mut seq, coeff);
            }
            for (a, b) in fused.iter().zip(&seq) {
                assert!((a - b).abs() < 1e-6, "d={d}: {a} vs {b}");
            }
        }
        // gaussian falls back to the sequential path exactly
        let mut fused = vec![0.0f32; 130];
        perturb_axpy_many(&mut fused, &items, 0.5, Distribution::Gaussian);
        let mut seq = vec![0.0f32; 130];
        for &(seed, coeff) in &items {
            let mut s = PerturbStream::new(seed, 0.5, Distribution::Gaussian);
            s.axpy(&mut seq, coeff);
        }
        assert_eq!(fused, seq);
    }

    #[test]
    fn sharded_matches_fused_across_boundaries() {
        // property: for dims straddling shard boundaries and any worker
        // count, the sharded pass is bit-identical to the unsharded fused
        // pass. Dims below SHARD_MIN_DIM exercise the fallback; dims above
        // exercise real sharding with non-aligned remainders.
        let items: Vec<(u64, f32)> =
            (0..9).map(|i| (777 + i, 2e-3 * (i as f32 - 4.0))).collect();
        let dims = [
            1usize,
            63,
            64,
            65,
            SHARD_MIN_DIM - 1,
            SHARD_MIN_DIM,
            SHARD_MIN_DIM + 1,
            SHARD_MIN_DIM + 63,
            SHARD_MIN_DIM + 64,
            3 * SHARD_MIN_DIM + 17,
        ];
        for &d in &dims {
            let mut base = vec![0.25f32; d];
            perturb_axpy_many(&mut base, &items, 0.75, Distribution::Rademacher);
            for workers in [1usize, 2, 3, 4, 7, 64] {
                let mut sharded = vec![0.25f32; d];
                perturb_axpy_many_sharded(
                    &mut sharded,
                    &items,
                    0.75,
                    Distribution::Rademacher,
                    workers,
                );
                assert_eq!(sharded, base, "d={d} workers={workers}");
            }
        }
        // gaussian falls back to the sequential path bit-exactly
        let mut a = vec![0.1f32; SHARD_MIN_DIM + 5];
        let mut b = a.clone();
        perturb_axpy_many(&mut a, &items, 0.5, Distribution::Gaussian);
        perturb_axpy_many_sharded(&mut b, &items, 0.5, Distribution::Gaussian, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn norms() {
        let p = ParamVec(vec![3.0, 4.0]);
        assert!((p.l2() - 5.0).abs() < 1e-12);
        assert_eq!(p.max_abs(), 4.0);
        assert!(p.is_finite());
        assert!(!ParamVec(vec![f32::NAN]).is_finite());
    }
}
