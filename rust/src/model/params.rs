//! Flat parameter vector: init, axpy, and the seeded-perturbation ops that
//! implement the ZOUPDATE reconstruction of Algorithm 1.

use crate::config::KernelKind;
use crate::model::manifest::ModelEntry;
use crate::util::rng::{lane_keys, Distribution, PerturbStream, Xoshiro256};

/// The global model state: a single flat `f32` vector whose layout is
/// defined by the manifest. All federated arithmetic happens here.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamVec(pub Vec<f32>);

impl ParamVec {
    pub fn zeros(dim: usize) -> Self {
        ParamVec(vec![0.0; dim])
    }

    /// He-init per tensor (std = sqrt(2/fan_in)); constant `fill` tensors
    /// (norm scales/biases, biases) are set exactly. Mirrors
    /// `python/compile/models/common.py::init_flat` in spirit — bitwise
    /// parity is not required (each run owns its init).
    pub fn he_init(entry: &ModelEntry, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from(seed ^ 0x1417_5EED);
        let mut v = vec![0.0f32; entry.dim];
        for t in &entry.params {
            let part = &mut v[t.offset..t.offset + t.size];
            if t.fan_in == 0 {
                part.fill(t.fill);
            } else {
                let std = (2.0 / t.fan_in as f64).sqrt();
                for x in part {
                    *x = (rng.normal() * std) as f32;
                }
            }
        }
        ParamVec(v)
    }

    pub fn dim(&self) -> usize {
        self.0.len()
    }

    /// self += alpha * other  (FedAvg accumulation, server opt steps)
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        debug_assert_eq!(self.dim(), other.dim());
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.0 {
            *a *= alpha;
        }
    }

    /// self += coeff * z(seed)  — the ZOUPDATE hot loop. z is regenerated
    /// from the seed (never stored/transmitted), matching the paper's
    /// S·4-byte up-link. coeff already folds η, ΔL/(2ε), weighting and the
    /// sign, so one call applies one (seed, ΔL) pair.
    pub fn perturb_axpy(&mut self, seed: u64, tau: f32, dist: Distribution, coeff: f32) {
        let mut stream = PerturbStream::new(seed, tau, dist);
        perturb_axpy_slice(&mut self.0, &mut stream, coeff);
    }

    /// Kernel-aware single-seed axpy: the client-side twin of the server's
    /// fused fold. Both protocol sides must generate the *same* z(seed) —
    /// the client measures ΔL against it, the server replays it — so
    /// `zoopt`/`apply_seed_block` route through this with the run's
    /// [`KernelKind`]. `Scalar` is byte-identical to [`Self::perturb_axpy`].
    pub fn perturb_axpy_kernel(
        &mut self,
        seed: u64,
        tau: f32,
        dist: Distribution,
        coeff: f32,
        kernel: KernelKind,
    ) {
        match kernel {
            KernelKind::Scalar => self.perturb_axpy(seed, tau, dist, coeff),
            KernelKind::Lanes => {
                debug_assert_eq!(
                    dist,
                    Distribution::Rademacher,
                    "--kernel lanes is Rademacher-only (config validation enforces this)"
                );
                perturb_axpy_many_lanes(&mut self.0, &[(seed, coeff)], tau, LANES_DEFAULT);
            }
        }
    }

    /// out = self + coeff*z(seed) without touching self (SPSA's w ± εz).
    pub fn perturbed(&self, seed: u64, tau: f32, dist: Distribution, coeff: f32) -> ParamVec {
        let mut out = self.clone();
        out.perturb_axpy(seed, tau, dist, coeff);
        out
    }

    pub fn l2(&self) -> f64 {
        self.0.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// NaN-propagating max |w_i|: a blown-up model must read as NaN, not
    /// as the "healthy" 0.0 that a plain `f32::max` fold reports (IEEE max
    /// discards NaN operands, so an all-NaN vector used to fold to the
    /// 0.0 init — divergence monitoring never saw it).
    pub fn max_abs(&self) -> f32 {
        self.0.iter().fold(0.0f32, |m, &x| {
            if m.is_nan() || x.is_nan() {
                f32::NAN
            } else {
                m.max(x.abs())
            }
        })
    }

    pub fn is_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }
}

/// Streaming axpy kernel over a slice (also used by the in-place two-sided
/// flip: w+εz -> w−εz is one axpy with −2εz). Delegates to the stream's
/// branchless fast path (§Perf L3: 350 M/s → memory-bound after the
/// bit-XOR rewrite; see EXPERIMENTS.md §Perf).
#[inline]
pub fn perturb_axpy_slice(w: &mut [f32], stream: &mut PerturbStream, coeff: f32) {
    stream.axpy(w, coeff);
}

/// Fused multi-seed axpy: `w += Σ_k coeff_k · z(seed_k)` in a SINGLE pass
/// over `w`, interleaving all perturbation streams per 64-element block so
/// the weight vector is read/written once instead of once per seed
/// (§Perf L3: a ZOUPDATE applies Q·S = 30+ seeds per round; this cuts its
/// memory traffic by that factor). Bit consumption per stream is identical
/// to [`PerturbStream::axpy`] (LSB-first, one u64 per 64-block), so the
/// result equals the sequential application up to f32 addition order.
pub fn perturb_axpy_many(w: &mut [f32], items: &[(u64, f32)], tau: f32, dist: Distribution) {
    if items.is_empty() {
        return;
    }
    if dist != Distribution::Rademacher || items.len() == 1 {
        for &(seed, coeff) in items {
            let mut stream = PerturbStream::new(seed, tau, dist);
            stream.axpy(w, coeff);
        }
        return;
    }
    let mut streams = rademacher_streams(items, tau, 0);
    fused_rademacher_axpy(w, &mut streams);
}

/// Build the interleaved stream set for the fused Rademacher pass, with
/// each stream fast-forwarded by `skip_blocks` u64 draws (= `skip_blocks`
/// 64-element weight blocks — the shard-offset contract of
/// [`Xoshiro256::discard`]).
fn rademacher_streams(
    items: &[(u64, f32)],
    tau: f32,
    skip_blocks: u64,
) -> Vec<(Xoshiro256, u32)> {
    items
        .iter()
        .map(|&(seed, coeff)| {
            let mut rng = Xoshiro256::seed_from(seed);
            rng.discard(skip_blocks);
            (rng, (coeff * tau).to_bits())
        })
        .collect()
}

/// The fused inner kernel: per 64-element block, draw one u64 from every
/// stream and apply the signed constant branchlessly. Consumes bits
/// LSB-first, one u64 per stream per block — identical bit consumption to
/// [`PerturbStream::axpy`], which is what makes block-aligned sharding
/// ([`perturb_axpy_many_sharded`]) bit-exact.
fn fused_rademacher_axpy(w: &mut [f32], streams: &mut [(Xoshiro256, u32)]) {
    for chunk in w.chunks_mut(64) {
        for (rng, ct_bits) in streams.iter_mut() {
            let mut bits = rng.next_u64();
            let ct = *ct_bits;
            for x in chunk.iter_mut() {
                *x += f32::from_bits(ct ^ (((bits & 1) as u32) << 31));
                bits >>= 1;
            }
        }
    }
}

/// Below this many weights the per-thread setup (spawn + stream
/// fast-forward) outweighs the memory-bandwidth win; fall back to the
/// single-threaded fused pass.
const SHARD_MIN_DIM: usize = 1 << 14;

/// Sharded variant of [`perturb_axpy_many`]: split `w` into `workers`
/// disjoint 64-aligned chunks and apply the fused pass to each on its own
/// scoped thread. Each worker rebuilds every perturbation stream from its
/// seed and fast-forwards it by `chunk_offset / 64` u64 draws, preserving
/// the LSB-first one-u64-per-64-block consumption contract — so the
/// result is **bit-identical** to the unsharded fused pass (each weight
/// element sees the same additions in the same order) for every worker
/// count. At ResNet scale this takes ZOUPDATE from single-core
/// memory-bound to parallel across the weight vector.
///
/// Gaussian streams consume a data-dependent number of draws per value
/// (Box-Muller rejection), so they cannot be fast-forwarded by counting;
/// that distribution falls back to the sequential path unchanged.
pub fn perturb_axpy_many_sharded(
    w: &mut [f32],
    items: &[(u64, f32)],
    tau: f32,
    dist: Distribution,
    workers: usize,
) {
    // NB: single-item calls shard too — the block-aligned `discard`
    // contract makes sharding bit-exact for one stream exactly as for
    // many, and d=11M single-item applies (one-survivor async folds,
    // single-seed ckpt tail replays) are worth parallelizing. An earlier
    // `items.len() <= 1` guard silently serialized them.
    if workers <= 1
        || items.is_empty()
        || dist != Distribution::Rademacher
        || w.len() < SHARD_MIN_DIM
    {
        return perturb_axpy_many(w, items, tau, dist);
    }
    let blocks = w.len().div_ceil(64);
    let shards = workers.min(blocks);
    // ceil so every worker gets a whole number of 64-blocks and the chunk
    // boundaries stay 64-aligned (the last chunk absorbs the remainder).
    let blocks_per = blocks.div_ceil(shards);
    let chunk_len = blocks_per * 64;
    std::thread::scope(|scope| {
        for (i, chunk) in w.chunks_mut(chunk_len).enumerate() {
            scope.spawn(move || {
                let skip = (i * blocks_per) as u64;
                let mut streams = rademacher_streams(items, tau, skip);
                fused_rademacher_axpy(chunk, &mut streams);
            });
        }
    });
}

/// Lane count of the `--kernel lanes` mode. Fixed, not a knob: the lane
/// count is part of the stream definition (block b is served by lane
/// `b % LANES_DEFAULT`), so changing it would define a third kernel, not
/// tune this one. The kernel internals are parametric over the count
/// (the tail-block property tests also run 8 lanes).
pub const LANES_DEFAULT: usize = 4;

/// One item's lane-split stream state for the lanes kernel: `rngs[j]`
/// serves exactly the absolute 64-element blocks `b` with
/// `b % lanes == j`, drawing one u64 per owned block.
struct LaneStreams {
    rngs: Vec<Xoshiro256>,
    ct_bits: u32,
}

/// Build the lane-split stream set for the fused lanes pass, with every
/// lane fast-forwarded to absolute block `start_block` (64-aligned shard
/// offsets only, like the scalar kernel's `skip_blocks`). Lane keys come
/// from [`lane_keys`] — the seed → per-lane-key derivation mirroring the
/// Pallas kernel's seed → PRNGKey → bits flow. Lane j owns every
/// `lanes`-th block, so among blocks `[0, start_block)` it has drawn
/// `start_block / lanes` u64s, plus one if the remainder has passed its
/// slot — a worker-count-independent closed form, which is what makes
/// 64-block-aligned sharding bit-exact within the mode.
fn rademacher_lane_streams(
    items: &[(u64, f32)],
    tau: f32,
    lanes: usize,
    start_block: u64,
) -> Vec<LaneStreams> {
    let l = lanes as u64;
    items
        .iter()
        .map(|&(seed, coeff)| {
            let rngs = lane_keys(seed, lanes)
                .iter()
                .enumerate()
                .map(|(j, &key)| {
                    let mut rng = Xoshiro256::seed_from(key);
                    let owned = start_block / l + u64::from(start_block % l > j as u64);
                    rng.discard(owned);
                    rng
                })
                .collect();
            LaneStreams {
                rngs,
                ct_bits: (coeff * tau).to_bits(),
            }
        })
        .collect()
}

/// The fused lanes inner kernel: per 64-element block, each stream draws
/// one u64 from the block's *owning lane* (`(start_block + k) % lanes`)
/// and applies the signed constant branchlessly, LSB-first — the same
/// inner loop as [`fused_rademacher_axpy`], but consecutive blocks pull
/// from independent generators, breaking the serial state-update
/// dependency chain that caps the scalar kernel's throughput when few
/// streams are in flight (the single-seed replay case).
fn fused_rademacher_axpy_lanes(
    w: &mut [f32],
    streams: &mut [LaneStreams],
    start_block: u64,
    lanes: usize,
) {
    for (k, chunk) in w.chunks_mut(64).enumerate() {
        let lane = ((start_block + k as u64) % lanes as u64) as usize;
        for st in streams.iter_mut() {
            let mut bits = st.rngs[lane].next_u64();
            let ct = st.ct_bits;
            for x in chunk.iter_mut() {
                *x += f32::from_bits(ct ^ (((bits & 1) as u32) << 31));
                bits >>= 1;
            }
        }
    }
}

/// Unsharded lanes-kernel fold: `w += Σ_k coeff_k · z_lanes(seed_k)` in
/// one pass. This is the reference the sharded variant and the
/// single-seed client path ([`ParamVec::perturb_axpy_kernel`]) are
/// bit-identical to. Rademacher-only by construction (config validation
/// rejects `--kernel lanes --dist gaussian`).
pub fn perturb_axpy_many_lanes(w: &mut [f32], items: &[(u64, f32)], tau: f32, lanes: usize) {
    if items.is_empty() {
        return;
    }
    let mut streams = rademacher_lane_streams(items, tau, lanes, 0);
    fused_rademacher_axpy_lanes(w, &mut streams, 0, lanes);
}

/// Sharded lanes-kernel fold: the same 64-block-aligned chunking as
/// [`perturb_axpy_many_sharded`], with each worker fast-forwarding every
/// lane of every stream to its chunk's start block. Bit-identical to
/// [`perturb_axpy_many_lanes`] for every worker count (the lanes golden
/// trace pins this end to end).
pub fn perturb_axpy_many_lanes_sharded(
    w: &mut [f32],
    items: &[(u64, f32)],
    tau: f32,
    lanes: usize,
    workers: usize,
) {
    if workers <= 1 || items.is_empty() || w.len() < SHARD_MIN_DIM {
        return perturb_axpy_many_lanes(w, items, tau, lanes);
    }
    let blocks = w.len().div_ceil(64);
    let shards = workers.min(blocks);
    let blocks_per = blocks.div_ceil(shards);
    let chunk_len = blocks_per * 64;
    std::thread::scope(|scope| {
        for (i, chunk) in w.chunks_mut(chunk_len).enumerate() {
            scope.spawn(move || {
                let start_block = (i * blocks_per) as u64;
                let mut streams = rademacher_lane_streams(items, tau, lanes, start_block);
                fused_rademacher_axpy_lanes(chunk, &mut streams, start_block, lanes);
            });
        }
    });
}

/// The kernel dispatcher every replay path calls — live fold
/// (`fed::server::zo_round`, `fed::engine`), catch-up replay and
/// checkpoint reconstruction (`ckpt::CheckpointStore::reconstruct`) all
/// route their fused (seed, coeff) items through here with the run's
/// [`KernelKind`], so one `--kernel` flag switches the whole protocol.
pub fn perturb_axpy_many_sharded_kernel(
    w: &mut [f32],
    items: &[(u64, f32)],
    tau: f32,
    dist: Distribution,
    workers: usize,
    kernel: KernelKind,
) {
    match kernel {
        KernelKind::Scalar => perturb_axpy_many_sharded(w, items, tau, dist, workers),
        KernelKind::Lanes => {
            debug_assert_eq!(
                dist,
                Distribution::Rademacher,
                "--kernel lanes is Rademacher-only (config validation enforces this)"
            );
            perturb_axpy_many_lanes_sharded(w, items, tau, LANES_DEFAULT, workers);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    fn mini_entry() -> ModelEntry {
        let src = r#"{
          "version": 1,
          "models": {"t": {
            "dim": 6, "batch": 1, "kind": "image", "classes": 2,
            "input_shape": [1], "mask_shape": [1],
            "act": {"sum": 1, "max": 1},
            "params": [
              {"name": "w", "shape": [4], "offset": 0, "size": 4,
               "fan_in": 4, "kind": "dense", "fill": 0.0},
              {"name": "b", "shape": [2], "offset": 4, "size": 2,
               "fan_in": 0, "kind": "norm_scale", "fill": 1.0}
            ],
            "artifacts": {}
          }}}"#;
        Manifest::parse(src, PathBuf::from("/tmp"))
            .unwrap()
            .model("t")
            .unwrap()
            .clone()
    }

    #[test]
    fn he_init_fills_and_randomizes() {
        let e = mini_entry();
        let p = ParamVec::he_init(&e, 0);
        assert_eq!(p.dim(), 6);
        assert_eq!(&p.0[4..], &[1.0, 1.0]); // fill tensor exact
        assert!(p.0[..4].iter().any(|&x| x != 0.0));
        // deterministic per seed
        assert_eq!(p, ParamVec::he_init(&e, 0));
        assert_ne!(p, ParamVec::he_init(&e, 1));
    }

    #[test]
    fn he_init_std_matches_fan_in() {
        // large synthetic tensor to check the law
        let src = r#"{
          "version": 1,
          "models": {"t": {
            "dim": 100000, "batch": 1, "kind": "image", "classes": 2,
            "input_shape": [1], "mask_shape": [1],
            "act": {"sum": 1, "max": 1},
            "params": [{"name": "w", "shape": [100000], "offset": 0,
              "size": 100000, "fan_in": 50, "kind": "dense", "fill": 0.0}],
            "artifacts": {}
          }}}"#;
        let e = Manifest::parse(src, PathBuf::from("/tmp"))
            .unwrap()
            .model("t")
            .unwrap()
            .clone();
        let p = ParamVec::he_init(&e, 7);
        let mean: f64 = p.0.iter().map(|&x| x as f64).sum::<f64>() / p.dim() as f64;
        let var: f64 =
            p.0.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / p.dim() as f64;
        let want = 2.0 / 50.0;
        assert!((var - want).abs() / want < 0.05, "var {var} want {want}");
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = ParamVec(vec![1.0, 2.0]);
        let b = ParamVec(vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.0, vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.0, vec![12.0, 24.0]);
    }

    #[test]
    fn perturb_round_trip_cancels() {
        // w + c*z then + (-c)*z with the same seed must restore w exactly
        // (Rademacher: c*z is ±c·τ, exactly representable cancellation).
        let mut p = ParamVec(vec![0.25; 1000]);
        let orig = p.clone();
        p.perturb_axpy(99, 0.75, Distribution::Rademacher, 0.5);
        assert_ne!(p, orig);
        p.perturb_axpy(99, 0.75, Distribution::Rademacher, -0.5);
        assert_eq!(p, orig);
    }

    #[test]
    fn two_sided_spsa_brackets() {
        // (w+εz) and (w−εz) average back to w
        let w = ParamVec(vec![1.0; 512]);
        let plus = w.perturbed(5, 0.75, Distribution::Rademacher, 1e-2);
        let minus = w.perturbed(5, 0.75, Distribution::Rademacher, -1e-2);
        for i in 0..512 {
            let mid = (plus.0[i] + minus.0[i]) / 2.0;
            assert!((mid - 1.0).abs() < 1e-6);
            assert!((plus.0[i] - 1.0).abs() > 0.0);
        }
    }

    #[test]
    fn different_seeds_different_directions() {
        let w = ParamVec::zeros(4096);
        let a = w.perturbed(1, 1.0, Distribution::Rademacher, 1.0);
        let b = w.perturbed(2, 1.0, Distribution::Rademacher, 1.0);
        let agree = a.0.iter().zip(&b.0).filter(|(x, y)| x == y).count();
        // ~50% agreement expected for independent Rademacher vectors
        assert!((agree as f64 / 4096.0 - 0.5).abs() < 0.1);
    }

    #[test]
    fn perturb_axpy_many_matches_sequential() {
        let items: Vec<(u64, f32)> = (0..7).map(|i| (100 + i, 0.01 * (i as f32 - 3.0))).collect();
        for d in [1usize, 63, 64, 65, 1000, 4097] {
            let mut fused = vec![0.5f32; d];
            perturb_axpy_many(&mut fused, &items, 0.75, Distribution::Rademacher);
            let mut seq = vec![0.5f32; d];
            for &(seed, coeff) in &items {
                let mut s = PerturbStream::new(seed, 0.75, Distribution::Rademacher);
                s.axpy(&mut seq, coeff);
            }
            for (a, b) in fused.iter().zip(&seq) {
                assert!((a - b).abs() < 1e-6, "d={d}: {a} vs {b}");
            }
        }
        // gaussian falls back to the sequential path exactly
        let mut fused = vec![0.0f32; 130];
        perturb_axpy_many(&mut fused, &items, 0.5, Distribution::Gaussian);
        let mut seq = vec![0.0f32; 130];
        for &(seed, coeff) in &items {
            let mut s = PerturbStream::new(seed, 0.5, Distribution::Gaussian);
            s.axpy(&mut seq, coeff);
        }
        assert_eq!(fused, seq);
    }

    #[test]
    fn sharded_matches_fused_across_boundaries() {
        // property: for dims straddling shard boundaries and any worker
        // count, the sharded pass is bit-identical to the unsharded fused
        // pass. Dims below SHARD_MIN_DIM exercise the fallback; dims above
        // exercise real sharding with non-aligned remainders.
        let items: Vec<(u64, f32)> =
            (0..9).map(|i| (777 + i, 2e-3 * (i as f32 - 4.0))).collect();
        let dims = [
            1usize,
            63,
            64,
            65,
            SHARD_MIN_DIM - 1,
            SHARD_MIN_DIM,
            SHARD_MIN_DIM + 1,
            SHARD_MIN_DIM + 63,
            SHARD_MIN_DIM + 64,
            3 * SHARD_MIN_DIM + 17,
        ];
        for &d in &dims {
            let mut base = vec![0.25f32; d];
            perturb_axpy_many(&mut base, &items, 0.75, Distribution::Rademacher);
            for workers in [1usize, 2, 3, 4, 7, 64] {
                let mut sharded = vec![0.25f32; d];
                perturb_axpy_many_sharded(
                    &mut sharded,
                    &items,
                    0.75,
                    Distribution::Rademacher,
                    workers,
                );
                assert_eq!(sharded, base, "d={d} workers={workers}");
            }
        }
        // gaussian falls back to the sequential path bit-exactly
        let mut a = vec![0.1f32; SHARD_MIN_DIM + 5];
        let mut b = a.clone();
        perturb_axpy_many(&mut a, &items, 0.5, Distribution::Gaussian);
        perturb_axpy_many_sharded(&mut b, &items, 0.5, Distribution::Gaussian, 4);
        assert_eq!(a, b);
        // single-item lists shard too (one-survivor async folds,
        // single-seed ckpt tail replays): the sharded pass must stay
        // bit-identical to the sequential single-stream apply, which used
        // to be guaranteed only by falling back to it
        let one = &items[..1];
        for &d in &dims {
            let mut base = vec![0.25f32; d];
            perturb_axpy_many(&mut base, one, 0.75, Distribution::Rademacher);
            for workers in [1usize, 2, 3, 4, 7, 64] {
                let mut sharded = vec![0.25f32; d];
                perturb_axpy_many_sharded(&mut sharded, one, 0.75, Distribution::Rademacher, workers);
                assert_eq!(sharded, base, "single item d={d} workers={workers}");
            }
        }
    }

    #[test]
    fn norms() {
        let p = ParamVec(vec![3.0, 4.0]);
        assert!((p.l2() - 5.0).abs() < 1e-12);
        assert_eq!(p.max_abs(), 4.0);
        assert!(p.is_finite());
        assert!(!ParamVec(vec![f32::NAN]).is_finite());
    }

    #[test]
    fn max_abs_propagates_nan() {
        // the divergence-monitoring regression: IEEE max discards NaN, so
        // the old fold read an all-NaN (blown-up) model as a healthy 0.0
        assert!(ParamVec(vec![f32::NAN; 8]).max_abs().is_nan(), "all-NaN");
        assert!(
            ParamVec(vec![1.0, f32::NAN, -7.0]).max_abs().is_nan(),
            "mixed NaN, interior"
        );
        assert!(
            ParamVec(vec![f32::NAN, 3.0]).max_abs().is_nan(),
            "mixed NaN, leading"
        );
        // non-NaN behavior unchanged (negatives, infinities, empty)
        assert_eq!(ParamVec(vec![-9.0, 2.0]).max_abs(), 9.0);
        assert_eq!(ParamVec(vec![f32::NEG_INFINITY]).max_abs(), f32::INFINITY);
        assert_eq!(ParamVec(Vec::new()).max_abs(), 0.0);
    }

    #[test]
    fn lanes_sharded_matches_unsharded_across_boundaries() {
        // the lanes-kernel bit-identity contract: for dims with tail
        // blocks (d % 64 != 0) and lane-misaligned block counts
        // (d % (64·lanes) != 0), every worker count reproduces the
        // unsharded lanes reference bit for bit, at 4 and 8 lanes.
        let items: Vec<(u64, f32)> =
            (0..9).map(|i| (777 + i, 2e-3 * (i as f32 - 4.0))).collect();
        for &lanes in &[4usize, 8] {
            let dims = [
                1usize,
                63,                      // d % 64 != 0, single partial block
                64,
                65,
                64 * lanes,              // exactly one lane cycle
                64 * lanes + 32,         // tail block, partial lane cycle
                SHARD_MIN_DIM - 1,       // fallback edge
                SHARD_MIN_DIM + 63,      // sharded, tail block
                SHARD_MIN_DIM + 64 * 5,  // sharded, blocks % lanes != 0
                3 * SHARD_MIN_DIM + 17,  // multi-shard, tail block
            ];
            for &d in &dims {
                let mut base = vec![0.25f32; d];
                perturb_axpy_many_lanes(&mut base, &items, 0.75, lanes);
                for workers in [1usize, 2, 4, 7] {
                    let mut sharded = vec![0.25f32; d];
                    perturb_axpy_many_lanes_sharded(&mut sharded, &items, 0.75, lanes, workers);
                    assert_eq!(sharded, base, "lanes={lanes} d={d} workers={workers}");
                }
                // single item too (the client-side single-seed shape)
                let mut base1 = vec![0.25f32; d];
                perturb_axpy_many_lanes(&mut base1, &items[..1], 0.75, lanes);
                for workers in [2usize, 7] {
                    let mut sharded = vec![0.25f32; d];
                    perturb_axpy_many_lanes_sharded(&mut sharded, &items[..1], 0.75, lanes, workers);
                    assert_eq!(sharded, base1, "lanes={lanes} d={d} workers={workers} single");
                }
            }
        }
    }

    #[test]
    fn lanes_stream_is_valid_and_distinct_from_scalar() {
        // z_lanes is a proper Rademacher perturbation: entries are ±c·τ,
        // roughly balanced, deterministic per seed, sign-exact under
        // cancellation — and a *different* stream than the scalar kernel's
        // (which is why the mode is opt-in with its own golden trace).
        let d = 4096;
        let mut z = vec![0.0f32; d];
        perturb_axpy_many_lanes(&mut z, &[(42, 1.0)], 1.0, LANES_DEFAULT);
        assert!(z.iter().all(|&v| v == 1.0 || v == -1.0));
        let mean: f64 = z.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        let mut z2 = vec![0.0f32; d];
        perturb_axpy_many_lanes(&mut z2, &[(42, 1.0)], 1.0, LANES_DEFAULT);
        assert_eq!(z, z2, "deterministic per seed");
        let mut scalar = vec![0.0f32; d];
        perturb_axpy_many(&mut scalar, &[(42, 1.0)], 1.0, Distribution::Rademacher);
        assert_ne!(z, scalar, "lanes must not alias the scalar stream");
        // round-trip cancellation (exactly representable ±c·τ)
        let mut p = ParamVec(vec![0.25f32; 1000]);
        let orig = p.clone();
        p.perturb_axpy_kernel(99, 0.75, Distribution::Rademacher, 0.5, KernelKind::Lanes);
        assert_ne!(p, orig);
        p.perturb_axpy_kernel(99, 0.75, Distribution::Rademacher, -0.5, KernelKind::Lanes);
        assert_eq!(p, orig);
    }

    #[test]
    fn lanes_fused_matches_sequential_single_seed_applies() {
        // protocol self-consistency: the server's fused multi-item lanes
        // fold applies, per element, the same additions in the same order
        // as the client's one-seed-at-a-time applies
        // (ParamVec::perturb_axpy_kernel) — bit-identical, so client ΔL
        // measurement and server replay see the same z under lanes.
        let items: Vec<(u64, f32)> = (0..7).map(|i| (100 + i, 0.01 * (i as f32 - 3.0))).collect();
        for d in [1usize, 63, 64, 65, 1000, 4097] {
            let mut fused = ParamVec(vec![0.5f32; d]);
            perturb_axpy_many_lanes(&mut fused.0, &items, 0.75, LANES_DEFAULT);
            let mut seq = ParamVec(vec![0.5f32; d]);
            for &(seed, coeff) in &items {
                seq.perturb_axpy_kernel(
                    seed,
                    0.75,
                    Distribution::Rademacher,
                    coeff,
                    KernelKind::Lanes,
                );
            }
            assert_eq!(fused.0, seq.0, "d={d}");
        }
    }

    #[test]
    fn kernel_dispatcher_routes_both_modes() {
        let items: Vec<(u64, f32)> = (0..5).map(|i| (50 + i, 1e-3 * (i as f32 + 1.0))).collect();
        let d = SHARD_MIN_DIM + 77;
        let mut scalar_direct = vec![0.1f32; d];
        perturb_axpy_many_sharded(&mut scalar_direct, &items, 0.75, Distribution::Rademacher, 4);
        let mut scalar_via = vec![0.1f32; d];
        perturb_axpy_many_sharded_kernel(
            &mut scalar_via,
            &items,
            0.75,
            Distribution::Rademacher,
            4,
            KernelKind::Scalar,
        );
        assert_eq!(scalar_via, scalar_direct);
        let mut lanes_direct = vec![0.1f32; d];
        perturb_axpy_many_lanes_sharded(&mut lanes_direct, &items, 0.75, LANES_DEFAULT, 4);
        let mut lanes_via = vec![0.1f32; d];
        perturb_axpy_many_sharded_kernel(
            &mut lanes_via,
            &items,
            0.75,
            Distribution::Rademacher,
            4,
            KernelKind::Lanes,
        );
        assert_eq!(lanes_via, lanes_direct);
        assert_ne!(lanes_via, scalar_via, "the two kernels are different streams");
    }
}
