//! `ModelBackend`: the uniform compute interface the federated layer
//! drives. Two implementations:
//!
//! * [`crate::runtime::XlaBackend`] — executes the AOT HLO artifacts on the
//!   PJRT CPU client (the production path).
//! * [`LinearBackend`] — an analytic softmax-regression model implemented
//!   host-side. Same trait, no artifacts: it makes the full federated stack
//!   (sampling, pivot, SPSA protocol, baselines) testable and lets the big
//!   experiment sweeps run at tractable wall-clock on a 1-core testbed
//!   (DESIGN.md §4; the e2e example and fig3 use the XLA CNN).
//!
//! All losses are *sums* over the batch (with a padding mask) so a client's
//! full dataset can be chunked through a fixed-batch backend exactly.

use crate::model::params::ParamVec;
use crate::util::rng::Distribution;

/// Input tensor for one padded batch. Image models consume `F32` (NHWC
/// flattened), the LM consumes `I32` token ids.
#[derive(Debug, Clone)]
pub enum BatchX {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchX {
    pub fn len(&self) -> usize {
        match self {
            BatchX::F32(v) => v.len(),
            BatchX::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One padded batch: exactly `backend.batch_size()` rows, with `mask`
/// zeroing the padding rows (mask may be per-sample or per-token).
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: BatchX,
    pub y: Vec<i32>,
    pub mask: Vec<f32>,
}

impl Batch {
    /// Number of real (unmasked) loss rows.
    pub fn real_count(&self) -> f64 {
        self.mask.iter().map(|&m| m as f64).sum()
    }
}

/// Loss/accuracy sums over one batch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LossSums {
    pub loss_sum: f64,
    pub correct: f64,
    pub count: f64,
}

impl LossSums {
    pub fn add(&mut self, other: LossSums) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.count += other.count;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.count > 0.0 {
            self.loss_sum / self.count
        } else {
            0.0
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.count > 0.0 {
            self.correct / self.count
        } else {
            0.0
        }
    }
}

/// The uniform compute interface (see module docs).
///
/// `Sync` is a supertrait: the federated round engines share one backend
/// reference across the worker threads of a round fan-out
/// (`fed::server`'s threading model), so every backend must be safe to
/// call concurrently through `&self`. Both implementations qualify —
/// [`LinearBackend`] is plain data, and the PJRT executables behind
/// `XlaBackend` are compiled once and reentrant at execute time.
pub trait ModelBackend: Sync {
    /// Flat parameter dimension.
    fn dim(&self) -> usize;

    /// Fixed batch size every call must be padded to.
    fn batch_size(&self) -> usize;

    /// Forward pass: masked loss/correct sums.
    fn fwd_loss(&self, params: &ParamVec, batch: &Batch) -> anyhow::Result<LossSums>;

    /// One SGD step on the masked *mean* loss; returns pre-step sums.
    fn sgd_step(
        &self,
        params: &mut ParamVec,
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<LossSums>;

    /// Analytic per-client cost profile (eq. 4/5) consulted by the `sim`
    /// capability engine to decide FO-vs-ZO eligibility and simulated
    /// round timing. Backends with a manifest override this with measured
    /// activation sizes; the default models activations as fixed
    /// fractions of the parameter count.
    fn cost_model(&self) -> crate::comm::CostModel {
        crate::comm::CostModel::generic(self.dim() as u64, self.batch_size() as u64)
    }

    /// SPSA numerator ΔL = L(w+cz) − L(w−cz) for z = dist(seed) (z carries
    /// τ via `tau`; `c = eps`). Default: host-side perturbation + two
    /// forward passes — the genuinely low-memory path (only one perturbed
    /// copy of w alive at a time). Backends may override with a fused
    /// in-graph version.
    fn zo_delta(
        &self,
        params: &ParamVec,
        batch: &Batch,
        seed: u64,
        eps: f32,
        tau: f32,
        dist: Distribution,
    ) -> anyhow::Result<f64> {
        let mut w = params.clone();
        w.perturb_axpy(seed, tau, dist, eps);
        let plus = self.fwd_loss(&w, batch)?;
        // flip to the minus side in-place: w + εz − 2εz = w − εz
        w.perturb_axpy(seed, tau, dist, -2.0 * eps);
        let minus = self.fwd_loss(&w, batch)?;
        Ok(plus.loss_sum - minus.loss_sum)
    }
}

/// Analytic softmax regression over flattened features (see module docs).
///
/// params layout: W [classes, features] row-major, then b [classes].
/// `row_stride` is the feature count carried by the batch layout;
/// `pool > 1` average-pools the raw NHWC row (assumed square, 3-channel)
/// before the dot product — shrinking `d` both speeds the sweeps and keeps
/// SPSA's √d noise in a regime comparable to the paper's tuned setup.
/// `features <= pooled_len` lets a width-sliced sub-network (HeteroFL's
/// half model) consume the same batches while using only a feature prefix.
pub struct LinearBackend {
    pub features: usize,
    pub row_stride: usize,
    pub classes: usize,
    pub batch: usize,
    pub pool: usize,
}

impl LinearBackend {
    pub fn new(features: usize, classes: usize, batch: usize) -> Self {
        Self {
            features,
            row_stride: features,
            classes,
            batch,
            pool: 1,
        }
    }

    /// Average-pooled probe over raw NHWC rows of `row_stride` floats
    /// (img×img×3): features = (img/pool)²·3.
    pub fn pooled(row_stride: usize, pool: usize, classes: usize, batch: usize) -> Self {
        let img = ((row_stride / 3) as f64).sqrt() as usize;
        assert_eq!(img * img * 3, row_stride, "row is not square NHWC");
        assert_eq!(img % pool, 0, "pool must divide img");
        let features = (img / pool) * (img / pool) * 3;
        Self {
            features,
            row_stride,
            classes,
            batch,
            pool,
        }
    }

    /// Width-sliced variant: consume only the first `features` of the
    /// (pooled) feature vector.
    pub fn sliced(base: &LinearBackend, features: usize) -> Self {
        assert!(features <= base.features);
        Self {
            features,
            row_stride: base.row_stride,
            classes: base.classes,
            batch: base.batch,
            pool: base.pool,
        }
    }

    /// Pooled feature view of one row (identity when pool == 1).
    fn features_of<'a>(&self, x: &'a [f32], row: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        let raw = &x[row * self.row_stride..(row + 1) * self.row_stride];
        if self.pool == 1 {
            return &raw[..self.features.min(raw.len())];
        }
        let img = ((self.row_stride / 3) as f64).sqrt() as usize;
        let out_img = img / self.pool;
        scratch.clear();
        scratch.resize(out_img * out_img * 3, 0.0);
        let inv = 1.0 / (self.pool * self.pool) as f32;
        for py in 0..img {
            for px in 0..img {
                let oy = py / self.pool;
                let ox = px / self.pool;
                for ch in 0..3 {
                    scratch[(oy * out_img + ox) * 3 + ch] +=
                        raw[(py * img + px) * 3 + ch] * inv;
                }
            }
        }
        &scratch[..self.features]
    }

    fn logits(&self, params: &ParamVec, x: &[f32], row: usize, scratch: &mut Vec<f32>) -> Vec<f64> {
        let (f, c) = (self.features, self.classes);
        let mut out = vec![0.0f64; c];
        let xs = self.features_of(x, row, scratch);
        for (k, o) in out.iter_mut().enumerate() {
            let wrow = &params.0[k * f..(k + 1) * f];
            let mut acc = 0.0f64;
            for (w, v) in wrow.iter().zip(xs) {
                acc += (*w as f64) * (*v as f64);
            }
            *o = acc + params.0[c * f + k] as f64;
        }
        out
    }
}

fn softmax_stats(logits: &[f64], y: i32) -> (f64, bool, Vec<f64>) {
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f64 = exps.iter().sum();
    let probs: Vec<f64> = exps.iter().map(|e| e / z).collect();
    let loss = z.ln() + max - logits[y as usize];
    // total_cmp, not partial_cmp().unwrap(): a NaN logit (diverged run)
    // must yield a deterministic argmax, not a panic (DESIGN.md §14)
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    (loss, argmax == y as usize, probs)
}

impl ModelBackend for LinearBackend {
    fn dim(&self) -> usize {
        self.classes * self.features + self.classes
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn fwd_loss(&self, params: &ParamVec, batch: &Batch) -> anyhow::Result<LossSums> {
        let x = match &batch.x {
            BatchX::F32(v) => v,
            _ => anyhow::bail!("LinearBackend expects f32 features"),
        };
        let mut out = LossSums::default();
        let mut scratch = Vec::new();
        for row in 0..batch.mask.len() {
            let m = batch.mask[row] as f64;
            if m == 0.0 {
                continue;
            }
            let logits = self.logits(params, x, row, &mut scratch);
            let (loss, correct, _) = softmax_stats(&logits, batch.y[row]);
            out.loss_sum += m * loss;
            out.correct += m * if correct { 1.0 } else { 0.0 };
            out.count += m;
        }
        Ok(out)
    }

    fn sgd_step(
        &self,
        params: &mut ParamVec,
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<LossSums> {
        let x = match &batch.x {
            BatchX::F32(v) => v,
            _ => anyhow::bail!("LinearBackend expects f32 features"),
        };
        let (f, c) = (self.features, self.classes);
        let mut grad = vec![0.0f64; self.dim()];
        let mut sums = LossSums::default();
        let mut scratch = Vec::new();
        for row in 0..batch.mask.len() {
            let m = batch.mask[row] as f64;
            if m == 0.0 {
                continue;
            }
            let logits = self.logits(params, x, row, &mut scratch);
            let (loss, correct, probs) = softmax_stats(&logits, batch.y[row]);
            sums.loss_sum += m * loss;
            sums.correct += m * if correct { 1.0 } else { 0.0 };
            sums.count += m;
            let mut scratch2 = Vec::new();
            let xs = self.features_of(x, row, &mut scratch2);
            for k in 0..c {
                let coef = m * (probs[k] - if k == batch.y[row] as usize { 1.0 } else { 0.0 });
                let g = &mut grad[k * f..(k + 1) * f];
                for (gi, v) in g.iter_mut().zip(xs) {
                    *gi += coef * *v as f64;
                }
                grad[c * f + k] += coef;
            }
        }
        let denom = sums.count.max(1.0);
        for (p, g) in params.0.iter_mut().zip(&grad) {
            *p -= lr * (*g / denom) as f32;
        }
        Ok(sums)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn toy_batch(b: usize, f: usize, seed: u64) -> Batch {
        // two linearly separable clusters
        let mut rng = Xoshiro256::seed_from(seed);
        let mut x = Vec::with_capacity(b * f);
        let mut y = Vec::with_capacity(b);
        for i in 0..b {
            let cls = (i % 2) as i32;
            y.push(cls);
            for j in 0..f {
                let center = if cls == 0 { -1.0 } else { 1.0 };
                let jitter = (rng.next_f32() - 0.5) * 0.2;
                x.push(if j % 2 == 0 { center + jitter } else { jitter });
            }
        }
        Batch {
            x: BatchX::F32(x),
            y,
            mask: vec![1.0; b],
        }
    }

    #[test]
    fn linear_learns_separable_data() {
        let be = LinearBackend::new(8, 2, 16);
        let mut params = ParamVec::zeros(be.dim());
        let batch = toy_batch(16, 8, 0);
        let before = be.fwd_loss(&params, &batch).unwrap();
        assert!((before.mean_loss() - (2.0f64).ln()).abs() < 1e-9);
        for _ in 0..50 {
            be.sgd_step(&mut params, &batch, 0.5).unwrap();
        }
        let after = be.fwd_loss(&params, &batch).unwrap();
        assert!(after.mean_loss() < 0.1, "loss {}", after.mean_loss());
        assert_eq!(after.accuracy(), 1.0);
    }

    #[test]
    fn softmax_argmax_is_deterministic_under_nan_logits() {
        // a diverged run can surface NaN logits; argmax must stay a
        // deterministic total-order pick, never a panic (DESIGN.md §14)
        let logits = [0.5, f64::NAN, -1.0];
        let (_, correct, probs) = softmax_stats(&logits, 1);
        // total_cmp places NaN above every real, so index 1 wins
        assert!(correct);
        assert_eq!(probs.len(), 3);
        let again = softmax_stats(&logits, 1);
        assert_eq!(correct, again.1);
        // all-finite ties keep max_by's last-maximum convention
        let (_, last_wins, _) = softmax_stats(&[2.0, 2.0, 0.0], 1);
        assert!(last_wins);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let be = LinearBackend::new(3, 2, 4);
        let batch = toy_batch(4, 3, 1);
        let mut params = ParamVec::zeros(be.dim());
        let mut rng = Xoshiro256::seed_from(2);
        for p in &mut params.0 {
            *p = (rng.next_f32() - 0.5) * 0.5;
        }
        // analytic step with lr so that delta = -lr * grad/count
        let lr = 1e-3f32;
        let mut stepped = params.clone();
        be.sgd_step(&mut stepped, &batch, lr).unwrap();
        let count = batch.real_count();
        for i in 0..be.dim() {
            let eps = 1e-4f32;
            let mut pp = params.clone();
            pp.0[i] += eps;
            let lp = be.fwd_loss(&pp, &batch).unwrap().loss_sum;
            pp.0[i] -= 2.0 * eps;
            let lm = be.fwd_loss(&pp, &batch).unwrap().loss_sum;
            let fd = (lp - lm) / (2.0 * eps as f64) / count;
            let analytic = ((params.0[i] - stepped.0[i]) / lr) as f64;
            assert!(
                (fd - analytic).abs() < 1e-2 * fd.abs().max(1.0),
                "param {i}: fd {fd} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn masked_rows_do_not_contribute() {
        let be = LinearBackend::new(4, 2, 4);
        let mut b1 = toy_batch(4, 4, 3);
        b1.mask = vec![1.0, 1.0, 0.0, 0.0];
        // corrupt masked rows
        let mut b2 = b1.clone();
        if let BatchX::F32(x) = &mut b2.x {
            for v in &mut x[8..] {
                *v = 1e6;
            }
        }
        b2.y[2] = 1;
        let params = ParamVec::zeros(be.dim());
        assert_eq!(
            be.fwd_loss(&params, &b1).unwrap(),
            be.fwd_loss(&params, &b2).unwrap()
        );
        let mut p1 = params.clone();
        let mut p2 = params.clone();
        be.sgd_step(&mut p1, &b1, 0.1).unwrap();
        be.sgd_step(&mut p2, &b2, 0.1).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn default_zo_delta_is_antisymmetric_in_coeff_sign() {
        // ΔL(seed) with z and −z must negate: L(w+cz)−L(w−cz)
        let be = LinearBackend::new(4, 2, 8);
        let batch = toy_batch(8, 4, 4);
        let mut params = ParamVec::zeros(be.dim());
        params.0[0] = 0.3;
        let d1 = be
            .zo_delta(&params, &batch, 11, 1e-3, 0.75, Distribution::Rademacher)
            .unwrap();
        // same seed, eps negated == swap the two sides
        let d2 = be
            .zo_delta(&params, &batch, 11, -1e-3, 0.75, Distribution::Rademacher)
            .unwrap();
        assert!((d1 + d2).abs() < 1e-9, "{d1} vs {d2}");
        assert!(d1 != 0.0);
    }

    #[test]
    fn zo_delta_tracks_gradient_direction() {
        // SPSA estimate must have positive expected alignment with -grad:
        // stepping w -= lr * (ΔL/2ε) z should reduce loss for small lr.
        let be = LinearBackend::new(8, 2, 16);
        let batch = toy_batch(16, 8, 5);
        let mut params = ParamVec::zeros(be.dim());
        let l0 = be.fwd_loss(&params, &batch).unwrap().mean_loss();
        let (eps, tau) = (1e-3, 1.0);
        for seed in 0..20u64 {
            let dl = be
                .zo_delta(&params, &batch, seed, eps, tau, Distribution::Rademacher)
                .unwrap();
            let ghat = dl / (2.0 * eps as f64) / batch.real_count();
            params.perturb_axpy(seed, tau, Distribution::Rademacher, (-0.05 * ghat) as f32);
        }
        let l1 = be.fwd_loss(&params, &batch).unwrap().mean_loss();
        assert!(l1 < l0, "ZO-SGD should reduce loss: {l0} -> {l1}");
    }

    #[test]
    fn loss_sums_arithmetic() {
        let mut a = LossSums {
            loss_sum: 2.0,
            correct: 1.0,
            count: 2.0,
        };
        a.add(LossSums {
            loss_sum: 4.0,
            correct: 2.0,
            count: 2.0,
        });
        assert_eq!(a.mean_loss(), 1.5);
        assert_eq!(a.accuracy(), 0.75);
        assert_eq!(LossSums::default().accuracy(), 0.0);
    }
}
