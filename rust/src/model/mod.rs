//! Model state: flat parameter vectors, the manifest contract with the
//! Python compile path, and the `ModelBackend` compute interface.

pub mod backend;
pub mod manifest;
pub mod params;

pub use backend::{Batch, BatchX, LinearBackend, LossSums, ModelBackend};
pub use manifest::{Manifest, ModelEntry, TensorSpec};
pub use params::ParamVec;
