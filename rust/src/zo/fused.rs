//! Graph-mode SPSA path: the AOT `zo_delta` artifact evaluates ΔL with the
//! perturbation generated *inside* the HLO graph (threefry bits → fused
//! Pallas Rademacher-axpy kernel).
//!
//! Its z differs from the host `PerturbStream` (different PRNG), so a
//! graph-computed ΔL must pair with a graph-side update. This module is
//! used by the §Perf graph-vs-host comparison benches; the default
//! protocol stays host-side (DESIGN.md §6).

use crate::model::backend::Batch;
use crate::model::params::ParamVec;
use crate::runtime::XlaBackend;

/// ΔL over a chunked dataset via the fused artifact, normalized to mean
/// loss difference (same convention as `zo::zoopt`).
pub fn zo_delta_fused_chunked(
    backend: &XlaBackend,
    params: &ParamVec,
    chunks: &[Batch],
    seed: i32,
    coeff: f32,
) -> anyhow::Result<f64> {
    let mut delta = 0.0f64;
    let mut count = 0.0f64;
    for b in chunks {
        delta += backend.zo_delta_fused(params, b, seed, coeff)?;
        count += b.real_count();
    }
    Ok(if count > 0.0 { delta / count } else { 0.0 })
}
