//! Zeroth-order core: SPSA seed protocol (§3.1) and update reconstruction.
//!
//! Round protocol (Algorithm 1, step 2):
//! 1. the server derives `S` seeds per sampled client from its root seed
//!    ([`SeedIssuer`]) and sends them down (8 bytes each);
//! 2. each client evaluates ΔL_s = L(w+εz_s) − L(w−εz_s) on its *entire*
//!    local dataset (one gradient step per round) and uploads `S` f32
//!    scalars ([`ZoContribution`]);
//! 3. the server broadcasts the collected `(seed, ΔL, n)` list; every
//!    participant — and the server — reconstructs the identical update via
//!    [`apply_zo_update`], regenerating each z from its seed. No gradient
//!    or weight vector ever crosses the network.

pub mod fused;

use crate::config::{VarianceGuard, ZoConfig};
use crate::model::backend::{Batch, ModelBackend};
use crate::model::params::ParamVec;
use crate::util::rng::SplitMix64;
use crate::util::stats;

/// Deterministic per-(round, client, s) seed derivation: SplitMix64 over a
/// unique packed index.
///
/// The packing is `round << 40 | client << 16 | s`, which gives each field
/// a hard width: `round < 2^24`, `client < 2^24`, `s < 2^16`. Exceeding a
/// field would silently alias a *different* (round, client, s) triple —
/// e.g. `s = 2^16` collides with `(round, client + 1, 0)` — so the bounds
/// are asserted here and mirrored in `FedConfig::validate` (which caps
/// `clients` and `s_seeds * grad_steps`, the per-round `s` range).
#[derive(Debug, Clone)]
pub struct SeedIssuer {
    pub root: u64,
}

/// Field widths of the packed seed index (documented protocol limits).
/// `MAX_CLIENTS` bounds the *compact* packing only: clients at or above
/// it derive through the wide fleet path ([`SeedIssuer::seed`]), bounded
/// by `fed::client::MAX_FLEET_CLIENTS` instead.
pub const MAX_ROUNDS: usize = 1 << 24;
pub const MAX_CLIENTS: usize = 1 << 24;
pub const MAX_SEEDS_PER_ROUND: usize = 1 << 16;

// Domain salt of the wide (fleet-scale) seed derivation, keeping it off
// every value the compact 24/24/16 packing can produce. Defined in the
// central registry (`util::rng::salts`, DESIGN.md §14).
use crate::util::rng::salts::WIDE_ISSUER_SALT;

impl SeedIssuer {
    pub fn new(root: u64) -> Self {
        Self { root }
    }

    /// Pack an in-bounds (round, client, s) triple into its unique 64-bit
    /// index (24/24/16-bit fields).
    pub fn pack(round: usize, client: usize, s: usize) -> u64 {
        // hard bounds (not debug_assert): an overflowing field would
        // silently alias another (round, client, s) seed in release and
        // corrupt the replay protocol — the PR-4 precedent, now pinned
        // by detlint's debug-assert rule (DESIGN.md §14)
        assert!(round < MAX_ROUNDS, "round {round} overflows the 24-bit field");
        assert!(client < MAX_CLIENTS, "client {client} overflows the 24-bit field");
        assert!(s < MAX_SEEDS_PER_ROUND, "seed index {s} overflows the 16-bit field");
        (round as u64) << 40 | (client as u64) << 16 | s as u64
    }

    /// Inverse of [`Self::pack`] for in-bounds triples.
    pub fn unpack(packed: u64) -> (usize, usize, usize) {
        (
            (packed >> 40) as usize,
            ((packed >> 16) & 0xFF_FFFF) as usize,
            (packed & 0xFFFF) as usize,
        )
    }

    /// Derive the (round, client, s) seed. Clients inside the 24-bit
    /// compact field use the historical packed-index hash unchanged (so
    /// every pre-fleet trace reproduces); clients at or above it — the
    /// fleet-scale id space — first hash the client id through
    /// [`SplitMix64`] and fold it into a salted root, keeping the
    /// (round, s) packing intact. Both domains are pure functions of
    /// their inputs, so the protocol's regenerate-from-seed contract is
    /// untouched.
    pub fn seed(&self, round: usize, client: usize, s: usize) -> u64 {
        if client < MAX_CLIENTS {
            let packed = Self::pack(round, client, s);
            let mut sm = SplitMix64(self.root ^ packed.wrapping_mul(0xA24B_AED4_963E_E407));
            return sm.next_u64();
        }
        assert!(
            client < crate::fed::client::MAX_FLEET_CLIENTS,
            "client {client} overflows the 40-bit fleet field"
        );
        assert!(round < MAX_ROUNDS, "round {round} overflows the 24-bit field");
        assert!(
            s < MAX_SEEDS_PER_ROUND,
            "seed index {s} overflows the 16-bit field"
        );
        let mut ch = SplitMix64((client as u64) ^ WIDE_ISSUER_SALT);
        let client_hash = ch.next_u64();
        let rs = ((round as u64) << 16) | s as u64;
        let mut sm =
            SplitMix64(self.root ^ client_hash ^ rs.wrapping_mul(0xA24B_AED4_963E_E407));
        sm.next_u64()
    }

    pub fn seeds_for(&self, round: usize, client: usize, s_count: usize) -> Vec<u64> {
        (0..s_count).map(|s| self.seed(round, client, s)).collect()
    }
}

/// One client's round-t contribution: the seeds it was issued, its ΔL per
/// seed, its sample count (for n_j/n_Q weighting), and its **block map**.
///
/// `s_block` is the per-step probe count S_j this client was issued: its
/// `seeds`/`delta_l` lists are exactly `seeds.len() / s_block` consecutive
/// blocks of `s_block` (one per local `grad_steps` step, the last block
/// being the round's aggregated-gradient block). The block structure is
/// carried **explicitly** because S_j is heterogeneous under
/// `ZoConfig::adaptive_s` — the old implicit "every client runs
/// `cfg.s_seeds` per block" inference would silently mis-split adaptive
/// contributions, and even uniform runs only `debug_assert`ed the
/// invariant. [`zo_update_items`] now hard-enforces it in release builds.
#[derive(Debug, Clone)]
pub struct ZoContribution {
    pub client: usize,
    pub seeds: Vec<u64>,
    pub delta_l: Vec<f64>,
    pub n_samples: usize,
    /// per-step probe count S_j (the explicit block size of `seeds`)
    pub s_block: usize,
}

/// Client-side ZOOPT: evaluate ΔL for each issued seed over the client's
/// full dataset (chunked exactly via loss-sum accumulation). ΔL is
/// normalized to the *mean* loss difference so client size does not scale
/// the estimate (weighting happens server-side).
///
/// With `cfg.grad_steps > 1` (Table 3 ablation) the dataset is split into
/// `grad_steps` groups; each group gets its own seed block and the client
/// applies its own update locally between steps — the server replays the
/// identical sequence, so global state stays consistent.
pub fn zoopt<B: ModelBackend>(
    backend: &B,
    global: &ParamVec,
    chunks_per_step: &[Vec<Batch>],
    seeds: &[u64],
    cfg: &ZoConfig,
    lr_client: f32,
) -> anyhow::Result<Vec<f64>> {
    let s_per_step = cfg.s_seeds;
    anyhow::ensure!(
        seeds.len() == s_per_step * chunks_per_step.len(),
        "seed count {} != S({}) * steps({})",
        seeds.len(),
        s_per_step,
        chunks_per_step.len()
    );
    let mut w = global.clone();
    let mut out = Vec::with_capacity(seeds.len());
    for (step, chunks) in chunks_per_step.iter().enumerate() {
        let step_seeds = &seeds[step * s_per_step..(step + 1) * s_per_step];
        let mut step_deltas = Vec::with_capacity(s_per_step);
        for &seed in step_seeds {
            let mut count = 0.0f64;
            let mut delta = 0.0f64;
            // w + εz — through the run's kernel: the client must measure
            // ΔL against the exact z the server's fold will replay
            let mut wp = w.clone();
            wp.perturb_axpy_kernel(seed, cfg.tau, cfg.dist, cfg.eps, cfg.kernel);
            for b in chunks {
                let s = backend.fwd_loss(&wp, b)?;
                delta += s.loss_sum;
                count += s.count;
            }
            // flip to w − εz in place
            wp.perturb_axpy_kernel(seed, cfg.tau, cfg.dist, -2.0 * cfg.eps, cfg.kernel);
            for b in chunks {
                let s = backend.fwd_loss(&wp, b)?;
                delta -= s.loss_sum;
            }
            step_deltas.push(if count > 0.0 { delta / count } else { 0.0 });
        }
        // local replay of this step's update (no-op for the final step's
        // visible effect on the *returned* ΔLs, but required so later
        // steps evaluate at the locally-updated weights — Table 3).
        if step + 1 < chunks_per_step.len() {
            apply_seed_block(&mut w, step_seeds, &step_deltas, cfg, lr_client);
        }
        out.extend(step_deltas);
    }
    Ok(out)
}

/// Apply one S-seed block: w ← w − (η/S)·Σ_s (ΔL_s / 2ε) · z_s.
fn apply_seed_block(w: &mut ParamVec, seeds: &[u64], deltas: &[f64], cfg: &ZoConfig, lr: f32) {
    for (&seed, &dl) in seeds.iter().zip(deltas) {
        let ghat = dl / (2.0 * cfg.eps as f64);
        let coeff = -(lr as f64) * ghat / seeds.len() as f64;
        w.perturb_axpy_kernel(seed, cfg.tau, cfg.dist, coeff as f32, cfg.kernel);
    }
}

/// Server/participant-side ZOUPDATE: fold every contribution into the
/// global parameters, weighting client j by n_j / n_Q (eq. 1's weighting
/// carried into the ZO phase; Algorithm 1 line 31-32 with the evident
/// descent sign).
///
/// ## Multi-step replay consistency (`grad_steps > 1`)
///
/// A client running `grad_steps` local steps applies every *intermediate*
/// seed block to its own weights at `lr_client` ([`zoopt`]), then measures
/// the next block's ΔL at that updated point. The server's replay must
/// honor the same per-block learning rates or it reconstructs a
/// trajectory the client never followed: replaying *every* block at
/// `lr_client · lr_server` (the pre-fix behavior) lands the global far
/// from the points where the later blocks' ΔLs were actually measured
/// whenever `lr_server != 1`. The fix: intermediate blocks replay at
/// exactly `lr_client` (matching the client's local trajectory), and the
/// server learning rate scales only the final aggregated gradient block.
/// With `grad_steps = 1` (the paper's method) there is a single final
/// block and this reduces bit-exactly to the old `lr_client · lr_server`
/// behavior.
pub fn apply_zo_update(
    global: &mut ParamVec,
    contributions: &[ZoContribution],
    cfg: &ZoConfig,
    lr_client: f32,
    lr_server: f32,
) {
    apply_zo_update_sharded(global, contributions, cfg, lr_client, lr_server, 1)
}

/// [`apply_zo_update`] with the weight vector sharded across `workers`
/// threads through the run's kernel
/// (`model::params::perturb_axpy_many_sharded_kernel`). Bit-identical to
/// the single-threaded path for every worker count, within either kernel
/// mode.
pub fn apply_zo_update_sharded(
    global: &mut ParamVec,
    contributions: &[ZoContribution],
    cfg: &ZoConfig,
    lr_client: f32,
    lr_server: f32,
    workers: usize,
) {
    let items = zo_update_items(contributions, cfg, lr_client, lr_server);
    crate::model::params::perturb_axpy_many_sharded_kernel(
        &mut global.0,
        &items,
        cfg.tau,
        cfg.dist,
        workers,
        cfg.kernel,
    );
}

/// Quantile of |ΔL| the `Clip` variance guard clamps every probe to.
pub const GUARD_CLIP_QUANTILE: f64 = 0.95;

/// Relative variance floor of the `InvVar` guard: this fraction of the
/// fleet-mean squared ghat is added to every contribution's variance
/// before inversion, so a zero-variance contribution cannot absorb the
/// whole update.
pub const GUARD_VAR_FLOOR_REL: f64 = 1e-3;

/// The `Clip` guard's |ΔL| threshold: the fleet's
/// [`GUARD_CLIP_QUANTILE`] magnitude quantile over every probe
/// (`f64::INFINITY` when there are none). NaN probes are filtered by the
/// quantile, not propagated.
fn clip_threshold(contributions: &[ZoContribution]) -> f64 {
    let mags: Vec<f64> = contributions
        .iter()
        .flat_map(|c| c.delta_l.iter().map(|d| d.abs()))
        .collect();
    if mags.is_empty() {
        f64::INFINITY
    } else {
        stats::percentile(&mags, GUARD_CLIP_QUANTILE)
    }
}

/// Sample variance of a contribution's **final-block** ghat estimates
/// (ΔL/(2ε) over its last `s_block` probes, each |ΔL| clamped to `clip`
/// first — pass `f64::INFINITY` for the unguarded view) — the per-client
/// noise level the `InvVar` guard inverts and the `eff_var` metric
/// aggregates. `None` when fewer than 2 probes make the variance
/// undefined.
fn final_block_ghat_var(c: &ZoContribution, eps: f32, clip: f64) -> Option<f64> {
    if c.s_block < 2 || c.delta_l.len() < c.s_block {
        return None;
    }
    let start = c.delta_l.len() - c.s_block;
    let ghats: Vec<f64> = c.delta_l[start..]
        .iter()
        .map(|d| d.clamp(-clip, clip) / (2.0 * eps as f64))
        .collect();
    let sd = stats::std_dev(&ghats);
    Some(sd * sd)
}

/// The per-contribution aggregation weights of one ZOUPDATE: the base
/// n_j/n_Q data weighting, optionally rescaled by the configured
/// [`VarianceGuard`]. With `Off` (the default) this is exactly the seed
/// repo's weighting, bit for bit; `InvVar` multiplies each weight by the
/// floored inverse of that contribution's final-block ghat variance and
/// renormalizes (contributions too small to define a variance use the
/// fleet-mean variance); `Clip` leaves weights alone (it clamps ΔL
/// instead — see [`zo_update_items`]). Weights always sum to 1 over the
/// sample-carrying contributions, so the guard redistributes trust
/// without changing the update's overall scale. Deterministic — every
/// participant recomputing the broadcast reaches the identical list.
pub fn contribution_weights(contributions: &[ZoContribution], cfg: &ZoConfig) -> Vec<f64> {
    let n_total: f64 = contributions.iter().map(|c| c.n_samples as f64).sum();
    if n_total == 0.0 {
        return vec![0.0; contributions.len()];
    }
    let base: Vec<f64> = contributions
        .iter()
        .map(|c| c.n_samples as f64 / n_total)
        .collect();
    if cfg.guard != VarianceGuard::InvVar {
        return base;
    }
    let vars: Vec<Option<f64>> = contributions
        .iter()
        .map(|c| final_block_ghat_var(c, cfg.eps, f64::INFINITY))
        .collect();
    let defined: Vec<f64> = vars.iter().filter_map(|v| *v).collect();
    if defined.is_empty() {
        return base; // nobody ran enough probes to estimate noise
    }
    let fallback = stats::mean(&defined);
    // floor relative to the fleet's ghat magnitude so the guard is
    // scale-invariant and a zero-variance client stays bounded
    let mean_sq = {
        let (mut sum, mut n) = (0.0f64, 0usize);
        for c in contributions {
            if c.delta_l.len() < c.s_block || c.s_block == 0 {
                continue;
            }
            let start = c.delta_l.len() - c.s_block;
            for d in &c.delta_l[start..] {
                let g = d / (2.0 * cfg.eps as f64);
                sum += g * g;
                n += 1;
            }
        }
        if n > 0 {
            sum / n as f64
        } else {
            0.0
        }
    };
    let floor = GUARD_VAR_FLOOR_REL * mean_sq + 1e-30;
    let scaled: Vec<f64> = base
        .iter()
        .zip(&vars)
        .map(|(w, v)| w / (v.unwrap_or(fallback) + floor))
        .collect();
    let z: f64 = scaled.iter().sum();
    if z.is_finite() && z > 0.0 {
        scaled.iter().map(|w| w / z).collect()
    } else {
        base
    }
}

/// Variance proxy of this round's aggregated SPSA step:
/// `Σ_j w_j² · Var_j / S_j` over the final-block ghat estimates (the
/// standard variance of a weighted mean of per-client S_j-probe
/// averages), computed with the *guarded* weights actually used by the
/// fold. Always finite (0.0 when undefined) — logged per round as the
/// `eff_var` CSV column so the adaptive-S / variance-guard ablations have
/// a measurable target.
pub fn effective_variance(contributions: &[ZoContribution], cfg: &ZoConfig) -> f64 {
    let weights = contribution_weights(contributions, cfg);
    // the metric measures the step the fold ACTUALLY takes: under the
    // Clip guard the variance is that of the clamped estimates
    let clip = if cfg.guard == VarianceGuard::Clip {
        clip_threshold(contributions)
    } else {
        f64::INFINITY
    };
    let mut v = 0.0f64;
    for (c, w) in contributions.iter().zip(&weights) {
        if c.s_block == 0 || c.delta_l.len() < c.s_block {
            continue;
        }
        if let Some(var) = final_block_ghat_var(c, cfg.eps, clip) {
            v += w * w * var / c.s_block as f64;
        }
    }
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// The order-canonical fused (seed, coeff) item list of one ZOUPDATE —
/// the single source of truth shared by the live server pass
/// ([`apply_zo_update_sharded`]), the round-end broadcast accounting, and
/// the checkpoint/catch-up seed log ([`crate::ckpt::CheckpointStore`]):
/// replaying these items through `perturb_axpy_many_sharded` from the
/// same starting weights reproduces the server's update bit for bit.
/// Empty when no contribution carries samples (an all-drop round is the
/// identity update).
///
/// Heterogeneous probe counts are first-class: each contribution's block
/// structure comes from its **explicit** `s_block` (per-step S_j), its
/// ghat normalizes by its own S_j, and the configured [`VarianceGuard`]
/// rescales weights ([`contribution_weights`]) or clamps outlier ΔLs
/// before the coefficients are formed — so the guard rides inside the
/// single fused artifact and every consumer (live pass, broadcast
/// replayers, checkpoint log, catch-up reconstruction) stays bit-aligned.
///
/// # Panics
///
/// On a malformed contribution — `s_block == 0`, `delta_l.len() !=
/// seeds.len()`, or a seed list that is not a whole number of `s_block`
/// blocks. These are hard guards (not `debug_assert`): in release builds
/// a partial block would silently mis-assign the intermediate-vs-final
/// lr split and corrupt the update.
pub fn zo_update_items(
    contributions: &[ZoContribution],
    cfg: &ZoConfig,
    lr_client: f32,
    lr_server: f32,
) -> Vec<(u64, f32)> {
    zo_update_items_weighted(contributions, None, cfg, lr_client, lr_server)
}

/// Per-contribution staleness multipliers of the buffered-async engine's
/// polynomial decay: `m_j = (1 + staleness_j)^(-decay)`, where
/// `staleness_j` counts model versions between the snapshot the client
/// computed against and the version the fold lands on (FedBuff-style).
/// `decay = 0` yields exactly 1.0 for every entry — no discount.
pub fn staleness_multipliers(staleness: &[usize], decay: f64) -> Vec<f64> {
    staleness
        .iter()
        .map(|&s| (1.0 + s as f64).powf(-decay))
        .collect()
}

/// [`zo_update_items`] with optional per-contribution multipliers layered
/// over the guarded [`contribution_weights`] — the buffered-async
/// engine's staleness discount ([`staleness_multipliers`]). Multiplied
/// weights are renormalized to sum 1 so the discount redistributes trust
/// across the fold without shrinking the overall step; if every multiplied
/// weight is zero or non-finite the raw products are kept (an all-zero
/// list then yields the identity update, like an all-drop round).
///
/// `multipliers: None` takes the exact code path of the historical
/// unweighted fold — bit-identical, which is what keeps the sync engine's
/// golden trace untouched.
pub fn zo_update_items_weighted(
    contributions: &[ZoContribution],
    multipliers: Option<&[f64]>,
    cfg: &ZoConfig,
    lr_client: f32,
    lr_server: f32,
) -> Vec<(u64, f32)> {
    validate_contributions(contributions);
    let weights = resolved_weights(contributions, multipliers, cfg);
    if weights.iter().all(|&w| w == 0.0) {
        return Vec::new();
    }
    // The f32 product preserves bit-compatibility with the historical
    // single-lr API for grad_steps = 1 runs.
    let lr_final = lr_client * lr_server;
    let clip = fold_clip(contributions, cfg);
    // Gather every (seed, coeff) pair for ONE fused pass over the weights
    // (perturb_axpy_many) — the updates are linear in w, so order is
    // immaterial up to f32 rounding (§Perf L3).
    let mut items: Vec<(u64, f32)> = Vec::new();
    for (c, &weight) in contributions.iter().zip(&weights) {
        contribution_items(c, weight, clip, cfg, lr_client, lr_final, &mut items);
    }
    items
}

/// Hard-guard the contribution invariants `zo_update_items` documents
/// (see its `# Panics` section) — shared by the flat and two-tier folds.
fn validate_contributions(contributions: &[ZoContribution]) {
    for c in contributions {
        assert!(
            c.s_block > 0,
            "client {}: contribution carries s_block = 0",
            c.client
        );
        assert_eq!(
            c.delta_l.len(),
            c.seeds.len(),
            "client {}: ΔL count != seed count",
            c.client
        );
        assert_eq!(
            c.seeds.len() % c.s_block,
            0,
            "client {}: {} seeds is not a whole number of S = {} blocks",
            c.client,
            c.seeds.len(),
            c.s_block
        );
    }
}

/// The fold's final per-contribution weights: guarded
/// [`contribution_weights`], optionally rescaled by staleness
/// multipliers and renormalized — computed once over the **whole**
/// cohort, which is what a partial (per-edge) fold must broadcast from
/// the root for the two-tier merge to stay bit-identical.
fn resolved_weights(
    contributions: &[ZoContribution],
    multipliers: Option<&[f64]>,
    cfg: &ZoConfig,
) -> Vec<f64> {
    match multipliers {
        None => contribution_weights(contributions, cfg),
        Some(m) => {
            assert_eq!(
                m.len(),
                contributions.len(),
                "{} multipliers for {} contributions",
                m.len(),
                contributions.len()
            );
            let scaled: Vec<f64> = contribution_weights(contributions, cfg)
                .iter()
                .zip(m)
                .map(|(w, m)| w * m)
                .collect();
            let z: f64 = scaled.iter().sum();
            if z.is_finite() && z > 0.0 {
                scaled.iter().map(|w| w / z).collect()
            } else {
                scaled
            }
        }
    }
}

/// The Clip guard clamps |ΔL| to the fleet quantile before ghat is
/// formed; stats::percentile filters NaN, so a poisoned probe cannot
/// panic the fold. Like the weights, the threshold spans the whole
/// cohort — edge partials receive it from the root.
fn fold_clip(contributions: &[ZoContribution], cfg: &ZoConfig) -> f64 {
    if cfg.guard == VarianceGuard::Clip {
        clip_threshold(contributions)
    } else {
        f64::INFINITY
    }
}

/// Form one contribution's fused (seed, coeff) items given its resolved
/// cohort weight and the cohort clip threshold. Self-contained per
/// contribution — the property that makes the per-edge partial fold
/// bit-identical to the flat fold: every coefficient depends only on
/// `(contribution, weight, clip, cfg, lrs)`, never on which aggregator
/// formed it.
fn contribution_items(
    c: &ZoContribution,
    weight: f64,
    clip: f64,
    cfg: &ZoConfig,
    lr_client: f32,
    lr_final: f32,
    items: &mut Vec<(u64, f32)>,
) {
    let blocks = c.seeds.len() / c.s_block;
    for (i, &seed) in c.seeds.iter().enumerate() {
        let block = i / c.s_block;
        let lr = if block + 1 == blocks { lr_final } else { lr_client };
        let dl = if cfg.guard == VarianceGuard::Clip {
            c.delta_l[i].clamp(-clip, clip)
        } else {
            c.delta_l[i]
        };
        let ghat = dl / (2.0 * cfg.eps as f64);
        let coeff = -(lr as f64) * weight * ghat / c.s_block as f64;
        items.push((seed, coeff as f32));
    }
}

/// One edge aggregator's partial fused (seed, coeff) artifact: its own
/// cohort's items (contribution-contiguous, in cohort fold order) plus
/// the fold-order positions and per-contribution item counts the root
/// needs to splice the partials back together.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgePartial {
    pub edge: usize,
    /// positions of this edge's contributions in the round's fold order
    pub positions: Vec<usize>,
    /// item count per contribution (block boundaries for the root merge)
    pub counts: Vec<usize>,
    /// fused (seed, coeff) items, one contiguous block per contribution
    pub items: Vec<(u64, f32)>,
}

/// The hierarchical two-tier ZOUPDATE fold: partition `contributions`
/// across `e_count` edge aggregators (`edge_assign[i]` = the edge of
/// contribution `i`, e.g. [`crate::sim::edge_of`] of its client id),
/// let each edge form its cohort's partial artifact, and merge the
/// partials at the root — returning both the per-edge partials and the
/// merged item list.
///
/// **Bit-identity contract** (the equivalence-harness centerpiece): the
/// merged list equals [`zo_update_items_weighted`] over the same inputs
/// bit for bit, for every `e_count` and every assignment. Two root
/// broadcasts make that possible — the resolved cohort weights and the
/// cohort clip threshold are computed over the *full* round (they
/// normalize over every contribution, so no edge could compute them
/// locally) — and the root folds the partials in edge-index order, each
/// contribution's item block landing at its fold-order position
/// ([`merge_edge_partials`]). Since each coefficient depends only on its
/// own contribution plus the broadcast context ([`contribution_items`]),
/// the partition is invisible to the merged artifact, the applied
/// parameter update, the checkpoint seed log, and the broadcast
/// accounting.
pub fn zo_update_items_two_tier(
    contributions: &[ZoContribution],
    multipliers: Option<&[f64]>,
    edge_assign: &[usize],
    e_count: usize,
    cfg: &ZoConfig,
    lr_client: f32,
    lr_server: f32,
) -> (Vec<EdgePartial>, Vec<(u64, f32)>) {
    assert_eq!(
        edge_assign.len(),
        contributions.len(),
        "{} edge assignments for {} contributions",
        edge_assign.len(),
        contributions.len()
    );
    validate_contributions(contributions);
    let e_count = e_count.max(1);
    let mut partials: Vec<EdgePartial> = (0..e_count)
        .map(|edge| EdgePartial {
            edge,
            positions: Vec::new(),
            counts: Vec::new(),
            items: Vec::new(),
        })
        .collect();
    let weights = resolved_weights(contributions, multipliers, cfg);
    if weights.iter().all(|&w| w == 0.0) {
        // the identity update: every partial (and the merge) is empty,
        // matching the flat fold's early return
        return (partials, Vec::new());
    }
    let lr_final = lr_client * lr_server;
    let clip = fold_clip(contributions, cfg);
    for (pos, ((c, &weight), &edge)) in contributions
        .iter()
        .zip(&weights)
        .zip(edge_assign)
        .enumerate()
    {
        assert!(edge < e_count, "contribution {pos} assigned to edge {edge} of {e_count}");
        let p = &mut partials[edge];
        let before = p.items.len();
        contribution_items(c, weight, clip, cfg, lr_client, lr_final, &mut p.items);
        p.positions.push(pos);
        p.counts.push(p.items.len() - before);
    }
    let merged = merge_edge_partials(&partials, contributions.len());
    (partials, merged)
}

/// The root's merge of the two-tier fold: walk the partials in
/// edge-index order and copy each contribution's item block to its
/// fold-order offset. The output is the flat fold's item list bit for
/// bit (see [`zo_update_items_two_tier`]).
pub fn merge_edge_partials(partials: &[EdgePartial], n_contributions: usize) -> Vec<(u64, f32)> {
    let mut counts = vec![0usize; n_contributions];
    for p in partials {
        // hard fused-block invariants (PR-4 precedent): a drifted
        // partial would scatter items to wrong fold offsets in release
        assert_eq!(p.positions.len(), p.counts.len());
        assert_eq!(p.counts.iter().sum::<usize>(), p.items.len());
        for (&pos, &c) in p.positions.iter().zip(&p.counts) {
            counts[pos] = c;
        }
    }
    let mut offsets = vec![0usize; n_contributions + 1];
    for i in 0..n_contributions {
        offsets[i + 1] = offsets[i] + counts[i];
    }
    let mut merged = vec![(0u64, 0.0f32); offsets[n_contributions]];
    for p in partials {
        let mut cursor = 0usize;
        for (&pos, &c) in p.positions.iter().zip(&p.counts) {
            merged[offsets[pos]..offsets[pos] + c].copy_from_slice(&p.items[cursor..cursor + c]);
            cursor += c;
        }
    }
    merged
}

/// Bytes on the wire for one ZO round, per participating client (measured
/// counterpart of Table 1's analytic model).
pub fn zo_round_bytes(s_seeds: usize, participants: usize) -> (u64, u64) {
    let up = (s_seeds * 4) as u64; // S f32 ΔL values
    // down: S issued seeds (8B) + the broadcast of all (seed, ΔL) pairs
    let down = (s_seeds * 8 + participants * s_seeds * (8 + 4)) as u64;
    (up, down)
}

/// Round-total bytes for a (possibly mixed §A.4) ZO round: `zo_n` clients
/// run the seed protocol with `total_seeds` seeds issued across them
/// (heterogeneous per-client counts are fine — a client with fewer
/// samples than `grad_steps` runs fewer blocks and is charged only for
/// the seeds it was actually issued), and `fo_n` high-resource clients
/// exchange full weight vectors (`dim_bytes` = 4·d).
///
/// Seed traffic is charged **only** to the ZO participants — FO
/// participants never receive the seed broadcast, they download/upload
/// full weights instead. This makes the accounting additive:
/// `ledger(z, f) = ledger(z, 0) + ledger(0, f)` componentwise, which the
/// pre-fix `down_per · q` formula violated by charging the seed downlink
/// to FO participants too.
pub fn zo_round_ledger(
    total_seeds: usize,
    zo_n: usize,
    fo_n: usize,
    dim_bytes: u64,
) -> (u64, u64) {
    // up: one f32 ΔL per issued seed; down: each issued seed (8B) plus
    // the (seed, ΔL) broadcast of everything to every ZO participant.
    let up = (total_seeds * 4) as u64 + dim_bytes * fo_n as u64;
    let down = (total_seeds * 8 + zo_n * total_seeds * (8 + 4)) as u64
        + dim_bytes * fo_n as u64;
    (up, down)
}

/// One ZO participant's measured wire charges for a round under the `sim`
/// capability engine: what its seed-issue downlink and ΔL uplink actually
/// transmitted (full for survivors, the pre-cut prefix for dropouts), and
/// whether it survived to the fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ZoClientCharge {
    /// seeds the server derived for this client (S · its step count)
    pub issued_seeds: usize,
    /// ΔL payload bytes actually uploaded (≤ issued_seeds · 4)
    pub up_bytes: u64,
    /// bytes actually downloaded on the client's pre-round leg: the seed
    /// issue (≤ issued_seeds · 8) plus, for stale clients under the
    /// `ckpt` subsystem, the catch-up payload (snapshot and/or replay
    /// tail) that rides the same download
    pub seed_down_bytes: u64,
    pub survives: bool,
}

/// Byte-accurate round totals under capability profiles and drop
/// patterns, generalizing [`zo_round_ledger`]:
///
/// * per-client pre-round downlink (seed issue, plus any `ckpt`
///   catch-up payload riding the same leg) and ΔL uplink are charged as
///   *measured* (partial transmissions included);
/// * the end-of-round broadcast carries only the **surviving** (seed, ΔL)
///   pairs (12 B each — the pairs actually folded into the update) and
///   reaches only the surviving ZO participants;
/// * FO traffic (`fo_up`/`fo_down`, mixed §A.4 rounds) is added as-is.
///
/// With every client surviving at full uniform charges this reduces
/// bit-exactly to `zo_round_ledger`, and the FO/ZO decomposition stays
/// additive: `ledger(zo, fo) = ledger(zo, 0) + ledger(0, fo)`
/// componentwise — both properties are enforced by
/// `prop_ledger_outcomes_additive_under_drops`.
pub fn zo_round_ledger_outcomes(
    zo: &[ZoClientCharge],
    fo_up: u64,
    fo_down: u64,
) -> (u64, u64) {
    let surviving_seeds: usize = zo
        .iter()
        .filter(|c| c.survives)
        .map(|c| c.issued_seeds)
        .sum();
    let survivors = zo.iter().filter(|c| c.survives).count();
    let up = zo.iter().map(|c| c.up_bytes).sum::<u64>() + fo_up;
    let down = zo.iter().map(|c| c.seed_down_bytes).sum::<u64>()
        + (survivors * surviving_seeds * (8 + 4)) as u64
        + fo_down;
    (up, down)
}

/// Per-edge attribution of [`zo_round_ledger_outcomes`] under the
/// two-tier topology: each charge books on its client's edge
/// (`edge_assign[i]`, e.g. [`crate::sim::edge_of`]), the end-of-round
/// broadcast — which carries **all** surviving (seed, ΔL) pairs to every
/// surviving participant regardless of edge — books `surviving_seeds ·
/// 12` bytes on each survivor's edge, and optional per-edge FO traffic
/// (mixed §A.4 rounds) is added as-is (`fo_up`/`fo_down` indexed by
/// edge; short or empty slices read as zero).
///
/// **Reduction contract**: summing the returned per-edge `(up, down)`
/// pairs componentwise reproduces the flat
/// [`zo_round_ledger_outcomes`] totals bit-exactly (all-integer
/// arithmetic — the broadcast term partitions as `Σ_e survivors_e ·
/// surviving_seeds · 12 = survivors · surviving_seeds · 12`), for every
/// edge count and assignment — pinned by the extended
/// `prop_ledger_outcomes_additive_under_drops`.
pub fn zo_round_ledger_outcomes_per_edge(
    zo: &[ZoClientCharge],
    edge_assign: &[usize],
    e_count: usize,
    fo_up: &[u64],
    fo_down: &[u64],
) -> Vec<(u64, u64)> {
    assert_eq!(
        edge_assign.len(),
        zo.len(),
        "{} edge assignments for {} charges",
        edge_assign.len(),
        zo.len()
    );
    let e_count = e_count.max(1).max(fo_up.len()).max(fo_down.len());
    let surviving_seeds: usize = zo
        .iter()
        .filter(|c| c.survives)
        .map(|c| c.issued_seeds)
        .sum();
    let mut out = vec![(0u64, 0u64); e_count];
    for (c, &edge) in zo.iter().zip(edge_assign) {
        assert!(edge < e_count, "charge assigned to edge {edge} of {e_count}");
        out[edge].0 += c.up_bytes;
        out[edge].1 += c.seed_down_bytes;
        if c.survives {
            out[edge].1 += (surviving_seeds * (8 + 4)) as u64;
        }
    }
    for (edge, slot) in out.iter_mut().enumerate() {
        slot.0 += fo_up.get(edge).copied().unwrap_or(0);
        slot.1 += fo_down.get(edge).copied().unwrap_or(0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::backend::{BatchX, LinearBackend};
    use crate::util::rng::{Distribution, Xoshiro256};

    fn sep_batch(b: usize, f: usize, seed: u64) -> Batch {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..b {
            let cls = (i % 2) as i32;
            y.push(cls);
            for j in 0..f {
                let c = if cls == 0 { -1.0 } else { 1.0 };
                x.push(if j % 2 == 0 { c } else { 0.0 } + (rng.next_f32() - 0.5) * 0.1);
            }
        }
        Batch {
            x: BatchX::F32(x),
            y,
            mask: vec![1.0; b],
        }
    }

    #[test]
    fn seed_issuer_unique_and_deterministic() {
        let iss = SeedIssuer::new(7);
        let mut all = std::collections::BTreeSet::new();
        for round in 0..20 {
            for client in 0..10 {
                for s in 0..5 {
                    assert!(all.insert(iss.seed(round, client, s)));
                }
            }
        }
        assert_eq!(iss.seed(3, 2, 1), SeedIssuer::new(7).seed(3, 2, 1));
        assert_ne!(iss.seed(3, 2, 1), SeedIssuer::new(8).seed(3, 2, 1));
    }

    #[test]
    fn zoopt_then_update_reduces_loss() {
        let be = LinearBackend::new(8, 2, 16);
        let mut global = ParamVec::zeros(be.dim());
        let batch = sep_batch(16, 8, 0);
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 4,
            dist: Distribution::Rademacher,
            grad_steps: 1,
            ..ZoConfig::default()
        };
        let iss = SeedIssuer::new(0);
        let l0 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        for round in 0..30 {
            let seeds = iss.seeds_for(round, 0, cfg.s_seeds);
            let deltas = zoopt(
                &be,
                &global,
                &[vec![batch.clone()]],
                &seeds,
                &cfg,
                1.0,
            )
            .unwrap();
            let contrib = ZoContribution {
                client: 0,
                seeds,
                delta_l: deltas,
                n_samples: 16,
                s_block: cfg.s_seeds,
            };
            apply_zo_update(&mut global, &[contrib], &cfg, 1.0, 0.3);
        }
        let l1 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        assert!(l1 < 0.8 * l0, "ZO rounds must learn: {l0} -> {l1}");
    }

    #[test]
    fn lanes_kernel_learns_and_replay_matches() {
        // --kernel lanes end to end at the zo layer: the client measures
        // ΔL against the lane-split z, the server folds the same stream,
        // and the protocol still optimizes. Also pins the ckpt contract
        // under lanes: item replay through the dispatcher is bit-identical
        // to apply_zo_update itself.
        use crate::config::KernelKind;
        let be = LinearBackend::new(8, 2, 16);
        let mut global = ParamVec::zeros(be.dim());
        let batch = sep_batch(16, 8, 0);
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 4,
            dist: Distribution::Rademacher,
            grad_steps: 1,
            kernel: KernelKind::Lanes,
            ..ZoConfig::default()
        };
        let iss = SeedIssuer::new(0);
        let l0 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        for round in 0..30 {
            let seeds = iss.seeds_for(round, 0, cfg.s_seeds);
            let deltas =
                zoopt(&be, &global, &[vec![batch.clone()]], &seeds, &cfg, 1.0).unwrap();
            let contrib = ZoContribution {
                client: 0,
                seeds,
                delta_l: deltas,
                n_samples: 16,
                s_block: cfg.s_seeds,
            };
            apply_zo_update(&mut global, &[contrib], &cfg, 1.0, 0.3);
        }
        let l1 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        assert!(l1 < 0.8 * l0, "lanes-kernel ZO rounds must learn: {l0} -> {l1}");

        // replay-matches-apply under lanes (the ckpt/catch-up contract)
        let contribs = vec![ZoContribution {
            client: 0,
            seeds: vec![5, 6, 7],
            delta_l: vec![0.4, -0.2, 0.1],
            n_samples: 10,
            s_block: 3,
        }];
        let mut a = ParamVec(vec![0.1f32; 2048]);
        let mut b = a.clone();
        apply_zo_update(&mut a, &contribs, &cfg, 0.7, 0.3);
        let items = zo_update_items(&contribs, &cfg, 0.7, 0.3);
        crate::model::params::perturb_axpy_many_sharded_kernel(
            &mut b.0, &items, cfg.tau, cfg.dist, 1, cfg.kernel,
        );
        assert_eq!(a.0, b.0);
        // and the lanes fold is a genuinely different stream than scalar
        let mut c = ParamVec(vec![0.1f32; 2048]);
        let scalar_cfg = ZoConfig { kernel: KernelKind::Scalar, ..cfg };
        apply_zo_update(&mut c, &contribs, &scalar_cfg, 0.7, 0.3);
        assert_ne!(a.0, c.0);
    }

    #[test]
    fn staleness_decay_discounts_and_renormalizes() {
        // m_j = (1+s)^-α: decay 0 is exactly no-op, fresh beats stale
        assert_eq!(staleness_multipliers(&[0, 3, 7], 0.0), vec![1.0, 1.0, 1.0]);
        let m = staleness_multipliers(&[0, 1, 3], 1.0);
        assert!((m[0] - 1.0).abs() < 1e-12);
        assert!((m[1] - 0.5).abs() < 1e-12);
        assert!((m[2] - 0.25).abs() < 1e-12);
        assert!(m.windows(2).all(|w| w[0] > w[1]));

        let mk = |seed: u64, dl: f64, n: usize| ZoContribution {
            client: 0,
            seeds: vec![seed, seed + 1, seed + 2],
            delta_l: vec![dl; 3],
            n_samples: n,
            s_block: 3,
        };
        let cfg = ZoConfig::default();
        let contribs = vec![mk(10, 0.4, 8), mk(20, 0.4, 8)];
        // None is bit-identical to the unweighted API
        let plain = zo_update_items(&contribs, &cfg, 1.0, 0.05);
        let none = zo_update_items_weighted(&contribs, None, &cfg, 1.0, 0.05);
        assert_eq!(plain, none);
        // all-fresh multipliers renormalize back to the plain fold
        let fresh = staleness_multipliers(&[0, 0], 2.0);
        let items = zo_update_items_weighted(&contribs, Some(&fresh), &cfg, 1.0, 0.05);
        assert_eq!(plain, items);
        // a stale second client shifts coefficient mass to the fresh one
        let mixed = staleness_multipliers(&[0, 4], 1.0);
        let items = zo_update_items_weighted(&contribs, Some(&mixed), &cfg, 1.0, 0.05);
        assert!(items[0].1.abs() > plain[0].1.abs(), "fresh client gained weight");
        assert!(items[3].1.abs() < plain[3].1.abs(), "stale client lost weight");
        // renormalization preserves the total coefficient mass (up to the
        // f32 rounding of the stored coefficients)
        let sum = |v: &[(u64, f32)]| v.iter().map(|(_, c)| *c as f64).sum::<f64>();
        assert!((sum(&items) - sum(&plain)).abs() < 1e-3 * sum(&plain).abs().max(1.0));
        // all-zero multipliers degrade to the identity update
        assert!(zo_update_items_weighted(&contribs, Some(&[0.0, 0.0]), &cfg, 1.0, 0.05)
            .is_empty());
    }

    #[test]
    fn update_weighting_by_sample_count() {
        // a client with zero weight must not move the params; equal-ΔL
        // clients with equal n must move it twice as far as one alone.
        let cfg = ZoConfig::default();
        let mk = |seed, dl, n| ZoContribution {
            client: 0,
            seeds: vec![seed, seed + 1, seed + 2],
            delta_l: vec![dl; 3],
            n_samples: n,
            s_block: 3,
        };
        let mut a = ParamVec::zeros(1000);
        apply_zo_update(&mut a, &[mk(1, 0.5, 100), mk(9, 0.5, 0)], &cfg, 1.0, 0.1);
        let mut b = ParamVec::zeros(1000);
        apply_zo_update(&mut b, &[mk(1, 0.5, 77)], &cfg, 1.0, 0.1);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn multi_step_zoopt_consistency() {
        // grad_steps=2 with DISTINCT client/server lrs — the regression
        // the old single-lr replay missed. The client locally applied the
        // intermediate block at lr_client before measuring block 2's ΔLs,
        // so the server's replay must use lr_client for that block and
        // scale only the final gradient block by lr_server. The pre-fix
        // code replayed every block at lr_client·lr_server, diverging from
        // the client's trajectory whenever lr_server != 1.
        let be = LinearBackend::new(6, 2, 8);
        let global = ParamVec::zeros(be.dim());
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 2,
            dist: Distribution::Rademacher,
            grad_steps: 2,
            ..ZoConfig::default()
        };
        let b1 = sep_batch(8, 6, 1);
        let b2 = sep_batch(8, 6, 2);
        let seeds: Vec<u64> = (10..14).collect();
        let lr_client = 0.2f32;
        let lr_server = 0.25f32; // != 1: the case the old test never covered
        let deltas = zoopt(
            &be,
            &global,
            &[vec![b1.clone()], vec![b2.clone()]],
            &seeds,
            &cfg,
            lr_client,
        )
        .unwrap();
        assert_eq!(deltas.len(), 4);
        // the client's local trajectory, replayed by hand: intermediate
        // block at lr_client (exactly as zoopt applied it), final gradient
        // block scaled by the server lr.
        let mut w = global.clone();
        apply_seed_block(&mut w, &seeds[0..2], &deltas[0..2], &cfg, lr_client);
        let intermediate = w.clone(); // where block 2's ΔLs were measured
        apply_seed_block(&mut w, &seeds[2..4], &deltas[2..4], &cfg, lr_client * lr_server);
        // server replay via apply_zo_update with one client at weight 1
        let mut g = global.clone();
        apply_zo_update(
            &mut g,
            &[ZoContribution {
                client: 0,
                seeds: seeds.clone(),
                delta_l: deltas.clone(),
                n_samples: 8,
                s_block: cfg.s_seeds,
            }],
            &cfg,
            lr_client,
            lr_server,
        );
        for (x, y) in w.0.iter().zip(&g.0) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
        // and the server's replay passes through the client's measurement
        // point: subtracting the final block leaves the intermediate state.
        let mut back = g.clone();
        apply_seed_block(
            &mut back,
            &seeds[2..4],
            &deltas[2..4],
            &cfg,
            -(lr_client * lr_server),
        );
        for (x, y) in back.0.iter().zip(&intermediate.0) {
            assert!((x - y).abs() < 1e-6, "intermediate {x} vs {y}");
        }
        // regression guard: the old uniform-lr replay is NOT the fixed
        // trajectory when lr_server != 1.
        let mut old = global.clone();
        apply_seed_block(&mut old, &seeds[0..2], &deltas[0..2], &cfg, lr_client * lr_server);
        apply_seed_block(&mut old, &seeds[2..4], &deltas[2..4], &cfg, lr_client * lr_server);
        let diff: f64 = old
            .0
            .iter()
            .zip(&g.0)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum();
        assert!(diff > 1e-7, "fixed replay must differ from the old uniform-lr replay");
    }

    #[test]
    fn single_step_replay_matches_legacy_product_lr() {
        // grad_steps=1 (the paper's method): the two-lr API must reduce
        // bit-exactly to the historical lr_client·lr_server behavior.
        let cfg = ZoConfig::default(); // S = 3, one block
        let contrib = ZoContribution {
            client: 0,
            seeds: vec![5, 6, 7],
            delta_l: vec![0.4, -0.2, 0.1],
            n_samples: 10,
            s_block: 3,
        };
        let mut a = ParamVec::zeros(2048);
        apply_zo_update(&mut a, &[contrib.clone()], &cfg, 0.7, 0.3);
        let mut b = ParamVec::zeros(2048);
        // legacy behavior: every block at the f32 product
        apply_zo_update(&mut b, &[contrib], &cfg, 0.7 * 0.3, 1.0);
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn update_items_replay_matches_apply() {
        // the ckpt contract: replaying zo_update_items through the fused
        // pass is bit-identical to apply_zo_update itself
        let cfg = ZoConfig::default();
        let contribs = vec![
            ZoContribution {
                client: 0,
                seeds: vec![5, 6, 7],
                delta_l: vec![0.4, -0.2, 0.1],
                n_samples: 10,
                s_block: 3,
            },
            ZoContribution {
                client: 1,
                seeds: vec![11, 12, 13],
                delta_l: vec![-0.3, 0.0, 0.25],
                n_samples: 30,
                s_block: 3,
            },
        ];
        let mut a = ParamVec(vec![0.1f32; 2048]);
        let mut b = a.clone();
        apply_zo_update(&mut a, &contribs, &cfg, 0.7, 0.3);
        let items = zo_update_items(&contribs, &cfg, 0.7, 0.3);
        assert_eq!(items.len(), 6);
        crate::model::params::perturb_axpy_many_sharded(
            &mut b.0, &items, cfg.tau, cfg.dist, 1,
        );
        assert_eq!(a.0, b.0);
        // zero-sample rounds are the identity update
        assert!(zo_update_items(&[], &cfg, 1.0, 1.0).is_empty());
        let zero = ZoContribution {
            client: 0,
            seeds: vec![1, 2, 3],
            delta_l: vec![1.0; 3],
            n_samples: 0,
            s_block: 3,
        };
        assert!(zo_update_items(&[zero], &cfg, 1.0, 1.0).is_empty());
    }

    #[test]
    fn zoopt_rejects_bad_seed_count() {
        let be = LinearBackend::new(4, 2, 4);
        let g = ParamVec::zeros(be.dim());
        let cfg = ZoConfig::default(); // S = 3
        let b = sep_batch(4, 4, 3);
        assert!(zoopt(&be, &g, &[vec![b]], &[1, 2], &cfg, 1.0).is_err());
    }

    #[test]
    fn round_bytes_model() {
        let (up, down) = zo_round_bytes(3, 10);
        assert_eq!(up, 12); // 3 × f32
        assert_eq!(down, 3 * 8 + 10 * 3 * 12);
    }

    #[test]
    fn gaussian_variant_also_learns() {
        let be = LinearBackend::new(8, 2, 16);
        let mut global = ParamVec::zeros(be.dim());
        let batch = sep_batch(16, 8, 5);
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 4,
            dist: Distribution::Gaussian,
            grad_steps: 1,
            ..ZoConfig::default()
        };
        let iss = SeedIssuer::new(1);
        let l0 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        for round in 0..30 {
            let seeds = iss.seeds_for(round, 0, cfg.s_seeds);
            let deltas =
                zoopt(&be, &global, &[vec![batch.clone()]], &seeds, &cfg, 1.0).unwrap();
            apply_zo_update(
                &mut global,
                &[ZoContribution {
                    client: 0,
                    seeds,
                    delta_l: deltas,
                    n_samples: 16,
                    s_block: cfg.s_seeds,
                }],
                &cfg,
                1.0,
                0.2,
            );
        }
        let l1 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        assert!(l1 < l0, "{l0} -> {l1}");
    }

    #[test]
    fn seed_issuer_boundary_values_do_not_collide() {
        // every field at its documented limit must still derive distinct
        // seeds — the packed index stays unique at the field boundaries.
        let iss = SeedIssuer::new(3);
        let rounds = [0usize, 1, MAX_ROUNDS - 1];
        let clients = [0usize, 1, MAX_CLIENTS - 1];
        let ss = [0usize, 1, MAX_SEEDS_PER_ROUND - 1];
        let mut all = std::collections::BTreeSet::new();
        for &r in &rounds {
            for &c in &clients {
                for &s in &ss {
                    assert!(
                        all.insert(iss.seed(r, c, s)),
                        "collision at ({r}, {c}, {s})"
                    );
                }
            }
        }
        // the aliasing the guard exists to catch: s = 2^16 would pack
        // identically to (client + 1, s = 0)
        assert_eq!(
            (0u64) << 40 | 1 << 16 | 0,
            (0u64) << 40 | 0 << 16 | MAX_SEEDS_PER_ROUND as u64
        );
    }

    #[test]
    #[should_panic(expected = "overflows the 16-bit field")]
    fn seed_issuer_rejects_s_overflow() {
        SeedIssuer::new(0).seed(0, 0, MAX_SEEDS_PER_ROUND);
    }

    #[test]
    #[should_panic(expected = "overflows the 24-bit field")]
    fn seed_issuer_pack_rejects_client_overflow() {
        // the compact packing still hard-bounds its field; ids past it
        // take the wide derivation in seed() instead of packing
        SeedIssuer::pack(0, MAX_CLIENTS, 0);
    }

    #[test]
    fn seed_issuer_wide_clients_derive_distinct_deterministic_seeds() {
        // fleet-scale ids (>= 2^24) derive through the wide path: still
        // deterministic, still unique across (round, client, s), and the
        // compact domain is bit-for-bit what it always was
        let iss = SeedIssuer::new(7);
        let wide = MAX_CLIENTS + 123;
        assert_eq!(iss.seed(3, wide, 1), iss.seed(3, wide, 1));
        let mut all = std::collections::BTreeSet::new();
        for round in 0..4 {
            for client in [wide, wide + 1, 9_999_999 + MAX_CLIENTS] {
                for s in 0..3 {
                    assert!(all.insert(iss.seed(round, client, s)));
                }
            }
        }
        // a compact neighbor is untouched by the wide branch existing
        let legacy = {
            let packed = SeedIssuer::pack(3, MAX_CLIENTS - 1, 1);
            let mut sm = SplitMix64(7 ^ packed.wrapping_mul(0xA24B_AED4_963E_E407));
            sm.next_u64()
        };
        assert_eq!(iss.seed(3, MAX_CLIENTS - 1, 1), legacy);
        assert!(!all.contains(&legacy));
    }

    #[test]
    fn prop_seed_issuer_pack_unpack_round_trips() {
        // satellite: 24/24/16-bit pack/unpack round-trips for random
        // in-bounds triples (and the issuer derives from the same index)
        crate::util::prop::run_prop("seed_pack_unpack", 300, |g| {
            let mut rng = g.rng();
            let r = rng.below(MAX_ROUNDS);
            let c = rng.below(MAX_CLIENTS);
            let s = rng.below(MAX_SEEDS_PER_ROUND);
            let (r2, c2, s2) = SeedIssuer::unpack(SeedIssuer::pack(r, c, s));
            if (r, c, s) != (r2, c2, s2) {
                return Err(format!("({r},{c},{s}) -> ({r2},{c2},{s2})"));
            }
            // the packed index is what the issuer hashes: same triple,
            // same seed; a different in-bounds triple, a different index
            let iss = SeedIssuer::new(rng.next_u64());
            if iss.seed(r, c, s) != iss.seed(r, c, s) {
                return Err("issuer not deterministic".into());
            }
            let s_alt = (s + 1) % MAX_SEEDS_PER_ROUND;
            if SeedIssuer::pack(r, c, s) == SeedIssuer::pack(r, c, s_alt) {
                return Err(format!("pack collision at ({r},{c},{s})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ledger_outcomes_additive_under_drops() {
        // satellite: zo_round_ledger additivity holds under randomly
        // generated capability profiles and drop patterns — including
        // heterogeneous per-client probe budgets produced by the REAL
        // adaptive planner (extended for the adaptive-S tentpole).
        // Charges are produced by the real simulator, not hand-rolled
        // numbers.
        use crate::sim::{max_affordable_s, simulate_round, CapabilityProfile, RoundPlan};
        crate::util::prop::run_prop("zo_ledger_additivity", 120, |g| {
            let mut rng = g.rng();
            let n_clients = 1 + rng.below(g.size.max(1).min(24));
            let deadline = if rng.below(2) == 0 {
                0.0
            } else {
                0.1 + rng.next_f64() * 5.0
            };
            let mut charges = Vec::with_capacity(n_clients);
            for _ in 0..n_clients {
                let profile = CapabilityProfile {
                    tier: "rand".into(),
                    mem_bytes: u64::MAX,
                    up_mbps: 0.01 + rng.next_f64() * 20.0,
                    down_mbps: 0.01 + rng.next_f64() * 20.0,
                    compute: 0.05 + rng.next_f64() * 4.0,
                    drop_rate: rng.next_f64(),
                    join_round: 0,
                    absent_rate: 0.0,
                };
                // half the clients sit behind a random edge aggregator
                // (two-tier topology): their transmissions rate-limit at
                // the edge backhaul — additivity must survive
                // edge-adjusted charging too
                let profile = if rng.below(2) == 0 {
                    let ep = crate::sim::EdgeProfile {
                        name: "rand-edge".into(),
                        up_mbps: 0.01 + rng.next_f64() * 10.0,
                        down_mbps: 0.01 + rng.next_f64() * 10.0,
                        deadline_ms: 0.0,
                        failure_rate: 0.0,
                    };
                    crate::sim::edge_adjusted_profile(&profile, &ep)
                } else {
                    profile
                };
                // catch-up downlink (the ckpt subsystem's min(snapshot,
                // tail) charge) rides the same download leg as the seed
                // issue — additivity must hold with it in the plan too
                let catch_up = rng.below(1 << 16) as u64;
                // half the cases draw the probe count from the adaptive
                // planner against a random budget (the tentpole's issuing
                // path); the rest stay arbitrary
                let issued_seeds = if rng.below(2) == 0 {
                    let steps = 1 + rng.below(3);
                    let s_min = 1 + rng.below(3);
                    let s_max = s_min + rng.below(24);
                    let budget = rng.next_f64() * 10.0;
                    let s = max_affordable_s(&profile, 100_000, budget, s_min, s_max, |s| {
                        RoundPlan {
                            down_bytes: catch_up + (s * steps * 8) as u64,
                            passes: (2 * s * 50) as f64,
                            up_bytes: (s * steps * 4) as u64,
                        }
                    });
                    if !(s_min..=s_max).contains(&s) {
                        return Err(format!("planner out of bounds: {s}"));
                    }
                    s * steps
                } else {
                    1 + rng.below(48)
                };
                let plan = RoundPlan {
                    down_bytes: catch_up + (issued_seeds * 8) as u64,
                    passes: rng.below(2000) as f64 * 2.0,
                    up_bytes: (issued_seeds * 4) as u64,
                };
                let mut trace = rng.clone();
                rng.next_u64(); // decorrelate successive traces
                let o = simulate_round(&profile, &plan, 100_000, deadline, &mut trace);
                if o.up_bytes > plan.up_bytes || o.down_bytes > plan.down_bytes {
                    return Err("charged more than planned".into());
                }
                if o.survives && (o.up_bytes, o.down_bytes) != (plan.up_bytes, plan.down_bytes)
                {
                    return Err("survivor must be charged in full".into());
                }
                charges.push(ZoClientCharge {
                    issued_seeds,
                    up_bytes: o.up_bytes,
                    seed_down_bytes: o.down_bytes,
                    survives: o.survives,
                });
            }
            let fo_up = rng.below(1 << 20) as u64;
            let fo_down = rng.below(1 << 20) as u64;
            // FO/ZO decomposition is additive
            let mixed = zo_round_ledger_outcomes(&charges, fo_up, fo_down);
            let zo_only = zo_round_ledger_outcomes(&charges, 0, 0);
            let fo_only = zo_round_ledger_outcomes(&[], fo_up, fo_down);
            if mixed != (zo_only.0 + fo_only.0, zo_only.1 + fo_only.1) {
                return Err(format!("not additive: {mixed:?} vs {zo_only:?}+{fo_only:?}"));
            }
            // per-edge attribution (two-tier topology): under a random
            // edge count and a random assignment, per-edge ledgers must
            // sum bit-exactly to the flat totals — catch-up bytes ride
            // seed_down_bytes, so they are covered by construction
            let e_count = 1 + rng.below(8);
            let assign: Vec<usize> =
                charges.iter().map(|_| rng.below(e_count)).collect();
            let fo_up_e: Vec<u64> =
                (0..e_count).map(|_| rng.below(1 << 18) as u64).collect();
            let fo_down_e: Vec<u64> =
                (0..e_count).map(|_| rng.below(1 << 18) as u64).collect();
            let per_edge = zo_round_ledger_outcomes_per_edge(
                &charges, &assign, e_count, &fo_up_e, &fo_down_e,
            );
            if per_edge.len() != e_count {
                return Err(format!(
                    "expected {e_count} edge ledgers, got {}",
                    per_edge.len()
                ));
            }
            let summed = per_edge
                .iter()
                .fold((0u64, 0u64), |acc, e| (acc.0 + e.0, acc.1 + e.1));
            let flat = zo_round_ledger_outcomes(
                &charges,
                fo_up_e.iter().sum(),
                fo_down_e.iter().sum(),
            );
            if summed != flat {
                return Err(format!(
                    "per-edge ledgers don't reduce to flat: {summed:?} vs {flat:?} (E={e_count})"
                ));
            }
            // with every client surviving at full uniform charges, the
            // per-client model reduces bit-exactly to the aggregate one
            let all: Vec<ZoClientCharge> = charges
                .iter()
                .map(|c| ZoClientCharge {
                    issued_seeds: c.issued_seeds,
                    up_bytes: (c.issued_seeds * 4) as u64,
                    seed_down_bytes: (c.issued_seeds * 8) as u64,
                    survives: true,
                })
                .collect();
            let total: usize = all.iter().map(|c| c.issued_seeds).sum();
            if zo_round_ledger_outcomes(&all, 0, 0) != zo_round_ledger(total, all.len(), 0, 0)
            {
                return Err("no-drop case must reduce to zo_round_ledger".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_two_tier_fold_bit_identical_to_flat() {
        // the tentpole's centerpiece property: for random contributions,
        // random variance guards, optional staleness multipliers, and
        // every E in {1, 4, 16} with a random edge assignment, the root's
        // merge of per-edge partials equals the flat fold BIT FOR BIT —
        // same seeds, same coefficient bits, same order.
        use crate::config::VarianceGuard;
        crate::util::prop::run_prop("zo_two_tier_fold_bit_identity", 120, |g| {
            let mut rng = g.rng();
            let n = 1 + rng.below(g.size.max(1).min(12));
            let mut contributions = Vec::with_capacity(n);
            for cid in 0..n {
                let s_block = 1 + rng.below(4);
                let blocks = 1 + rng.below(3);
                let len = s_block * blocks;
                contributions.push(ZoContribution {
                    client: cid,
                    seeds: (0..len).map(|_| rng.next_u64()).collect(),
                    delta_l: (0..len).map(|_| (rng.next_f64() - 0.5) * 4.0).collect(),
                    // n_samples = 0 is legal (an empty local shard) and
                    // exercises the all-zero-weight identity early-out
                    n_samples: rng.below(20),
                    s_block,
                });
            }
            let cfg = ZoConfig {
                eps: 1e-3,
                guard: match rng.below(3) {
                    0 => VarianceGuard::Off,
                    1 => VarianceGuard::InvVar,
                    _ => VarianceGuard::Clip,
                },
                ..ZoConfig::default()
            };
            let mults: Option<Vec<f64>> = if rng.below(2) == 0 {
                Some((0..n).map(|_| rng.next_f64()).collect())
            } else {
                None
            };
            let lr_client = 0.05 + rng.next_f32();
            let lr_server = 0.05 + rng.next_f32();
            let flat =
                zo_update_items_weighted(&contributions, mults.as_deref(), &cfg, lr_client, lr_server);
            for &e_count in &[1usize, 4, 16] {
                let assign: Vec<usize> = (0..n).map(|_| rng.below(e_count)).collect();
                let (partials, merged) = zo_update_items_two_tier(
                    &contributions,
                    mults.as_deref(),
                    &assign,
                    e_count,
                    &cfg,
                    lr_client,
                    lr_server,
                );
                if partials.len() != e_count {
                    return Err(format!("E={e_count}: {} partials", partials.len()));
                }
                if merged.len() != flat.len() {
                    return Err(format!(
                        "E={e_count}: merged {} items, flat {}",
                        merged.len(),
                        flat.len()
                    ));
                }
                for (i, (m, f)) in merged.iter().zip(&flat).enumerate() {
                    if m.0 != f.0 || m.1.to_bits() != f.1.to_bits() {
                        return Err(format!(
                            "E={e_count} item {i}: two-tier {m:?} != flat {f:?}"
                        ));
                    }
                }
                // partials partition the artifact: no item counted twice
                let part_total: usize = partials.iter().map(|p| p.items.len()).sum();
                if part_total != flat.len() {
                    return Err(format!(
                        "E={e_count}: partials carry {part_total} items, flat {}",
                        flat.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn two_tier_partials_record_fold_positions() {
        // deterministic splice check: 3 contributions over 2 edges with an
        // interleaved assignment — each partial holds its cohort's blocks
        // contiguously, and the merge restores fold order.
        let mk = |seed: u64, dl: f64| ZoContribution {
            client: seed as usize,
            seeds: vec![seed, seed + 1],
            delta_l: vec![dl, -dl],
            n_samples: 8,
            s_block: 2,
        };
        let contribs = vec![mk(10, 0.4), mk(20, 0.2), mk(30, 0.6)];
        let cfg = ZoConfig::default();
        let assign = vec![1usize, 0, 1];
        let (partials, merged) =
            zo_update_items_two_tier(&contribs, None, &assign, 2, &cfg, 1.0, 0.05);
        assert_eq!(partials.len(), 2);
        assert_eq!(partials[0].positions, vec![1]);
        assert_eq!(partials[1].positions, vec![0, 2]);
        assert_eq!(partials[0].counts, vec![2]);
        assert_eq!(partials[1].counts, vec![2, 2]);
        // edge 1's partial holds contribution 0's block then 2's
        assert_eq!(partials[1].items[0].0, 10);
        assert_eq!(partials[1].items[2].0, 30);
        let flat = zo_update_items(&contribs, &cfg, 1.0, 0.05);
        assert_eq!(merged, flat);
        assert_eq!(
            merged.iter().map(|i| i.0).collect::<Vec<_>>(),
            vec![10, 11, 20, 21, 30, 31]
        );
        // degenerate e_count is clamped to one edge holding everything
        let (p1, m1) = zo_update_items_two_tier(&contribs, None, &[0, 0, 0], 0, &cfg, 1.0, 0.05);
        assert_eq!(p1.len(), 1);
        assert_eq!(m1, flat);
    }

    #[test]
    fn per_edge_ledger_reduces_to_flat_on_known_charges() {
        // hand-checked: broadcast down (survivors · surviving_seeds · 12)
        // lands on each survivor's OWN edge, so the per-edge split of the
        // flat broadcast term is exact by integer arithmetic.
        let charges = [
            ZoClientCharge { issued_seeds: 3, up_bytes: 12, seed_down_bytes: 24, survives: true },
            ZoClientCharge { issued_seeds: 6, up_bytes: 4, seed_down_bytes: 48, survives: false },
            ZoClientCharge { issued_seeds: 2, up_bytes: 8, seed_down_bytes: 16, survives: true },
        ];
        let assign = [0usize, 1, 1];
        // surviving_seeds = 3 + 2 = 5; broadcast per survivor = 5*12 = 60
        let per_edge =
            zo_round_ledger_outcomes_per_edge(&charges, &assign, 2, &[100, 0], &[0, 200]);
        assert_eq!(per_edge[0], (12 + 100, 24 + 60));
        assert_eq!(per_edge[1], (4 + 8, 48 + 16 + 60 + 200));
        let flat = zo_round_ledger_outcomes(&charges, 100, 200);
        let sum = per_edge.iter().fold((0, 0), |a, e| (a.0 + e.0, a.1 + e.1));
        assert_eq!(sum, flat);
        // empty edge stays zeroed; e_count grows to cover fo slices
        let one = zo_round_ledger_outcomes_per_edge(&charges, &[0, 0, 0], 1, &[7], &[9]);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], flat_minus(flat, 100 - 7, 200 - 9));
    }

    fn flat_minus(t: (u64, u64), du: u64, dd: u64) -> (u64, u64) {
        (t.0 - du, t.1 - dd)
    }

    #[test]
    fn ledger_outcomes_drop_edge_cases() {
        // all-drop round: zero broadcast, only the partial seed downlink
        let charges = [
            ZoClientCharge {
                issued_seeds: 3,
                up_bytes: 0,
                seed_down_bytes: 7,
                survives: false,
            },
            ZoClientCharge {
                issued_seeds: 6,
                up_bytes: 0,
                seed_down_bytes: 0,
                survives: false,
            },
        ];
        assert_eq!(zo_round_ledger_outcomes(&charges, 0, 0), (0, 7));
        // one survivor: broadcast carries only the surviving seeds, and
        // only the survivor receives it
        let charges = [
            ZoClientCharge {
                issued_seeds: 3,
                up_bytes: 12,
                seed_down_bytes: 24,
                survives: true,
            },
            ZoClientCharge {
                issued_seeds: 6,
                up_bytes: 4,
                seed_down_bytes: 48,
                survives: false,
            },
        ];
        let (up, down) = zo_round_ledger_outcomes(&charges, 0, 0);
        assert_eq!(up, 16);
        assert_eq!(down, 24 + 48 + 3 * 12);
        // empty round
        assert_eq!(zo_round_ledger_outcomes(&[], 0, 0), (0, 0));
    }

    #[test]
    fn mixed_round_ledger_is_additive() {
        // mixed-step2 bytes must equal the sum of the two pure models —
        // the pre-fix formula charged the seed downlink to FO
        // participants (down_per · q) and broke this.
        let d4 = 175_258u64 * 4;
        for s in [1usize, 3, 12] {
            for zo_n in [0usize, 1, 4, 9] {
                for fo_n in [0usize, 1, 3] {
                    let total = s * zo_n; // uniform per-client seed count
                    let mixed = zo_round_ledger(total, zo_n, fo_n, d4);
                    let pure_zo = zo_round_ledger(total, zo_n, 0, d4);
                    let pure_fo = zo_round_ledger(0, 0, fo_n, d4);
                    assert_eq!(
                        mixed,
                        (pure_zo.0 + pure_fo.0, pure_zo.1 + pure_fo.1),
                        "s={s} zo={zo_n} fo={fo_n}"
                    );
                }
            }
        }
        // FO participants exchange exactly full weights, both directions
        assert_eq!(zo_round_ledger(0, 0, 2, d4), (2 * d4, 2 * d4));
        // uniform pure ZO matches the per-participant Table 1 model
        let (up_per, down_per) = zo_round_bytes(3, 5);
        assert_eq!(zo_round_ledger(3 * 5, 5, 0, d4), (up_per * 5, down_per * 5));
        // heterogeneous seed counts (grad_steps > n for a small client):
        // only issued seeds are charged — 2 clients with 6 and 3 seeds
        let (up, down) = zo_round_ledger(9, 2, 0, d4);
        assert_eq!(up, 9 * 4);
        assert_eq!(down, (9 * 8 + 2 * 9 * 12) as u64);
    }

    #[test]
    #[should_panic(expected = "whole number of S = 3 blocks")]
    fn update_items_hard_rejects_partial_block() {
        // satellite: the whole-block invariant is a hard guard in release
        // builds — a malformed contribution must never silently mis-assign
        // the intermediate-vs-final lr split
        let cfg = ZoConfig::default();
        let bad = ZoContribution {
            client: 7,
            seeds: vec![1, 2, 3, 4], // 4 seeds, s_block 3: partial block
            delta_l: vec![0.1; 4],
            n_samples: 5,
            s_block: 3,
        };
        zo_update_items(&[bad], &cfg, 1.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "ΔL count != seed count")]
    fn update_items_hard_rejects_mismatched_deltas() {
        let cfg = ZoConfig::default();
        let bad = ZoContribution {
            client: 2,
            seeds: vec![1, 2, 3],
            delta_l: vec![0.1; 2],
            n_samples: 5,
            s_block: 3,
        };
        zo_update_items(&[bad], &cfg, 1.0, 1.0);
    }

    #[test]
    fn heterogeneous_s_blocks_normalize_per_client() {
        // adaptive-S: each contribution's ghat averages over ITS OWN probe
        // count. Two equal-n clients with identical per-probe ΔL but
        // different S_j must contribute the same total update mass
        // (coeff · S_j is S-invariant at fixed ΔL).
        let cfg = ZoConfig::default();
        let mk = |client: usize, s: usize| ZoContribution {
            client,
            seeds: (client as u64 * 100..client as u64 * 100 + s as u64).collect(),
            delta_l: vec![0.4; s],
            n_samples: 10,
            s_block: s,
        };
        let items = zo_update_items(&[mk(0, 2), mk(1, 8)], &cfg, 1.0, 1.0);
        assert_eq!(items.len(), 10);
        let mass_a: f64 = items[..2].iter().map(|(_, c)| *c as f64).sum();
        let mass_b: f64 = items[2..].iter().map(|(_, c)| *c as f64).sum();
        assert!((mass_a - mass_b).abs() < 1e-9, "{mass_a} vs {mass_b}");
        // and the per-item coeff really divides by the client's own S_j
        assert!((items[0].1 as f64 * 2.0 - items[2].1 as f64 * 8.0).abs() < 1e-9);
        // replaying the heterogeneous item list through the fused pass
        // still matches apply_zo_update (the ckpt contract)
        let contribs = [mk(0, 2), mk(1, 8)];
        let mut a = ParamVec(vec![0.2f32; 2048]);
        let mut b = a.clone();
        apply_zo_update(&mut a, &contribs, &cfg, 0.7, 0.3);
        let items = zo_update_items(&contribs, &cfg, 0.7, 0.3);
        crate::model::params::perturb_axpy_many_sharded(
            &mut b.0, &items, cfg.tau, cfg.dist, 1,
        );
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn invvar_guard_shifts_weight_to_tight_contributions() {
        let mut cfg = ZoConfig::default();
        let mk = |client: usize, deltas: Vec<f64>| ZoContribution {
            client,
            seeds: (client as u64 * 10..client as u64 * 10 + deltas.len() as u64).collect(),
            delta_l: deltas,
            n_samples: 10,
            s_block: 3,
        };
        let tight = mk(0, vec![0.10, 0.11, 0.09]);
        let noisy = mk(1, vec![2.0, -1.8, 0.4]);
        let contribs = [tight, noisy];
        let off = contribution_weights(&contribs, &cfg);
        assert_eq!(off, vec![0.5, 0.5], "equal n ⇒ equal base weights");
        cfg.guard = crate::config::VarianceGuard::InvVar;
        let on = contribution_weights(&contribs, &cfg);
        assert!((on.iter().sum::<f64>() - 1.0).abs() < 1e-12, "weights renormalize");
        assert!(
            on[0] > 0.9 && on[1] < 0.1,
            "inverse-variance must favor the tight client: {on:?}"
        );
        // guard folds into the fused artifact: the noisy client's items
        // shrink relative to the unguarded fold
        cfg.guard = crate::config::VarianceGuard::Off;
        let items_off = zo_update_items(&contribs, &cfg, 1.0, 1.0);
        cfg.guard = crate::config::VarianceGuard::InvVar;
        let items_on = zo_update_items(&contribs, &cfg, 1.0, 1.0);
        let max_noisy = |items: &[(u64, f32)]| {
            items[3..].iter().map(|(_, c)| c.abs()).fold(0.0f32, f32::max)
        };
        assert!(max_noisy(&items_on) < max_noisy(&items_off));
        // degenerate single-probe fleet: variance undefined everywhere,
        // guard falls back to the base weighting
        let single = [
            ZoContribution {
                client: 0,
                seeds: vec![1],
                delta_l: vec![0.5],
                n_samples: 4,
                s_block: 1,
            },
            ZoContribution {
                client: 1,
                seeds: vec![2],
                delta_l: vec![-0.5],
                n_samples: 12,
                s_block: 1,
            },
        ];
        let w = contribution_weights(&single, &cfg);
        assert_eq!(w, vec![0.25, 0.75]);
    }

    #[test]
    fn clip_guard_bounds_outlier_probes() {
        let mut cfg = ZoConfig::default();
        let mut deltas = vec![0.1f64; 29];
        deltas.push(50.0); // one exploding probe
        let c = ZoContribution {
            client: 0,
            seeds: (0..30).collect(),
            delta_l: deltas,
            n_samples: 10,
            s_block: 30,
        };
        let off = zo_update_items(std::slice::from_ref(&c), &cfg, 1.0, 1.0);
        cfg.guard = crate::config::VarianceGuard::Clip;
        let on = zo_update_items(std::slice::from_ref(&c), &cfg, 1.0, 1.0);
        let max_off = off.iter().map(|(_, v)| v.abs()).fold(0.0f32, f32::max);
        let max_on = on.iter().map(|(_, v)| v.abs()).fold(0.0f32, f32::max);
        assert!(
            max_on < max_off / 10.0,
            "clip must bound the outlier: {max_on} vs {max_off}"
        );
        // the non-outlier probes are untouched (0.1 is far below the
        // 95th-percentile magnitude)
        assert_eq!(on[0], off[0]);
        // the eff_var metric reflects the clamped fold, not the raw
        // probes — clip must visibly cut the measured variance
        let ev_on = effective_variance(std::slice::from_ref(&c), &cfg);
        cfg.guard = crate::config::VarianceGuard::Off;
        let ev_off = effective_variance(std::slice::from_ref(&c), &cfg);
        assert!(
            ev_on < ev_off / 10.0,
            "clip must cut the measured effective variance: {ev_on} vs {ev_off}"
        );
        cfg.guard = crate::config::VarianceGuard::Clip;
        // a NaN-poisoned probe must not panic the quantile (satellite:
        // stats::percentile is NaN-safe now)
        let mut poisoned = c.clone();
        poisoned.delta_l[3] = f64::NAN;
        let _ = zo_update_items(&[poisoned], &cfg, 1.0, 1.0);
    }

    #[test]
    fn guard_off_is_bit_identical_to_legacy_weighting() {
        // acceptance: the default guard reproduces the plain n_j/n_Q fold
        // exactly — same items, same bits
        let cfg = ZoConfig::default();
        assert_eq!(cfg.guard, crate::config::VarianceGuard::Off);
        let contribs = [
            ZoContribution {
                client: 0,
                seeds: vec![5, 6, 7],
                delta_l: vec![0.4, -0.2, 0.1],
                n_samples: 10,
                s_block: 3,
            },
            ZoContribution {
                client: 1,
                seeds: vec![11, 12, 13],
                delta_l: vec![-0.3, 0.0, 0.25],
                n_samples: 30,
                s_block: 3,
            },
        ];
        let items = zo_update_items(&contribs, &cfg, 0.7, 0.3);
        // hand-computed legacy coefficients
        let lr = 0.7f32 * 0.3f32;
        for (k, c) in contribs.iter().enumerate() {
            let weight = c.n_samples as f64 / 40.0;
            for i in 0..3 {
                let ghat = c.delta_l[i] / (2.0 * cfg.eps as f64);
                let coeff = -(lr as f64) * weight * ghat / 3.0;
                assert_eq!(items[k * 3 + i].1.to_bits(), (coeff as f32).to_bits());
            }
        }
    }

    #[test]
    fn effective_variance_is_finite_and_shrinks_with_probes() {
        let cfg = ZoConfig::default();
        assert_eq!(effective_variance(&[], &cfg), 0.0);
        let mk = |s: usize, scale: f64| ZoContribution {
            client: 0,
            seeds: (0..s as u64).collect(),
            // alternating ±scale: variance ≈ scale² regardless of S
            delta_l: (0..s).map(|i| if i % 2 == 0 { scale } else { -scale }).collect(),
            n_samples: 10,
            s_block: s,
        };
        let few = effective_variance(&[mk(4, 0.2)], &cfg);
        let many = effective_variance(&[mk(16, 0.2)], &cfg);
        assert!(few.is_finite() && many.is_finite());
        assert!(few > 0.0);
        assert!(
            many < few,
            "more probes must cut the estimator variance: {many} vs {few}"
        );
        // single-probe contributions have no defined variance → 0.0, finite
        assert_eq!(effective_variance(&[mk(1, 0.2)], &cfg), 0.0);
    }
}
