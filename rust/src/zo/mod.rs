//! Zeroth-order core: SPSA seed protocol (§3.1) and update reconstruction.
//!
//! Round protocol (Algorithm 1, step 2):
//! 1. the server derives `S` seeds per sampled client from its root seed
//!    ([`SeedIssuer`]) and sends them down (8 bytes each);
//! 2. each client evaluates ΔL_s = L(w+εz_s) − L(w−εz_s) on its *entire*
//!    local dataset (one gradient step per round) and uploads `S` f32
//!    scalars ([`ZoContribution`]);
//! 3. the server broadcasts the collected `(seed, ΔL, n)` list; every
//!    participant — and the server — reconstructs the identical update via
//!    [`apply_zo_update`], regenerating each z from its seed. No gradient
//!    or weight vector ever crosses the network.

pub mod fused;

use crate::config::ZoConfig;
use crate::model::backend::{Batch, ModelBackend};
use crate::model::params::ParamVec;
use crate::util::rng::SplitMix64;

/// Deterministic per-(round, client, s) seed derivation. Collision-free in
/// practice: SplitMix64 over a unique packed index.
#[derive(Debug, Clone)]
pub struct SeedIssuer {
    pub root: u64,
}

impl SeedIssuer {
    pub fn new(root: u64) -> Self {
        Self { root }
    }

    pub fn seed(&self, round: usize, client: usize, s: usize) -> u64 {
        let packed = (round as u64) << 40 | (client as u64) << 16 | s as u64;
        let mut sm = SplitMix64(self.root ^ packed.wrapping_mul(0xA24B_AED4_963E_E407));
        sm.next_u64()
    }

    pub fn seeds_for(&self, round: usize, client: usize, s_count: usize) -> Vec<u64> {
        (0..s_count).map(|s| self.seed(round, client, s)).collect()
    }
}

/// One client's round-t contribution: the seeds it was issued, its ΔL per
/// seed, and its sample count (for n_j/n_Q weighting).
#[derive(Debug, Clone)]
pub struct ZoContribution {
    pub client: usize,
    pub seeds: Vec<u64>,
    pub delta_l: Vec<f64>,
    pub n_samples: usize,
}

/// Client-side ZOOPT: evaluate ΔL for each issued seed over the client's
/// full dataset (chunked exactly via loss-sum accumulation). ΔL is
/// normalized to the *mean* loss difference so client size does not scale
/// the estimate (weighting happens server-side).
///
/// With `cfg.grad_steps > 1` (Table 3 ablation) the dataset is split into
/// `grad_steps` groups; each group gets its own seed block and the client
/// applies its own update locally between steps — the server replays the
/// identical sequence, so global state stays consistent.
pub fn zoopt<B: ModelBackend>(
    backend: &B,
    global: &ParamVec,
    chunks_per_step: &[Vec<Batch>],
    seeds: &[u64],
    cfg: &ZoConfig,
    lr_client: f32,
) -> anyhow::Result<Vec<f64>> {
    let s_per_step = cfg.s_seeds;
    anyhow::ensure!(
        seeds.len() == s_per_step * chunks_per_step.len(),
        "seed count {} != S({}) * steps({})",
        seeds.len(),
        s_per_step,
        chunks_per_step.len()
    );
    let mut w = global.clone();
    let mut out = Vec::with_capacity(seeds.len());
    for (step, chunks) in chunks_per_step.iter().enumerate() {
        let step_seeds = &seeds[step * s_per_step..(step + 1) * s_per_step];
        let mut step_deltas = Vec::with_capacity(s_per_step);
        for &seed in step_seeds {
            let mut count = 0.0f64;
            let mut delta = 0.0f64;
            // w + εz
            let mut wp = w.clone();
            wp.perturb_axpy(seed, cfg.tau, cfg.dist, cfg.eps);
            for b in chunks {
                let s = backend.fwd_loss(&wp, b)?;
                delta += s.loss_sum;
                count += s.count;
            }
            // flip to w − εz in place
            wp.perturb_axpy(seed, cfg.tau, cfg.dist, -2.0 * cfg.eps);
            for b in chunks {
                let s = backend.fwd_loss(&wp, b)?;
                delta -= s.loss_sum;
            }
            step_deltas.push(if count > 0.0 { delta / count } else { 0.0 });
        }
        // local replay of this step's update (no-op for the final step's
        // visible effect on the *returned* ΔLs, but required so later
        // steps evaluate at the locally-updated weights — Table 3).
        if step + 1 < chunks_per_step.len() {
            apply_seed_block(&mut w, step_seeds, &step_deltas, cfg, lr_client);
        }
        out.extend(step_deltas);
    }
    Ok(out)
}

/// Apply one S-seed block: w ← w − (η/S)·Σ_s (ΔL_s / 2ε) · z_s.
fn apply_seed_block(w: &mut ParamVec, seeds: &[u64], deltas: &[f64], cfg: &ZoConfig, lr: f32) {
    for (&seed, &dl) in seeds.iter().zip(deltas) {
        let ghat = dl / (2.0 * cfg.eps as f64);
        let coeff = -(lr as f64) * ghat / seeds.len() as f64;
        w.perturb_axpy(seed, cfg.tau, cfg.dist, coeff as f32);
    }
}

/// Server/participant-side ZOUPDATE: fold every contribution into the
/// global parameters, weighting client j by n_j / n_Q (eq. 1's weighting
/// carried into the ZO phase; Algorithm 1 line 31-32 with the evident
/// descent sign). `lr` is the effective ZO learning rate
/// (η_zo^c · η_zo^s).
pub fn apply_zo_update(
    global: &mut ParamVec,
    contributions: &[ZoContribution],
    cfg: &ZoConfig,
    lr: f32,
) {
    let n_total: f64 = contributions.iter().map(|c| c.n_samples as f64).sum();
    if n_total == 0.0 {
        return;
    }
    // Gather every (seed, coeff) pair, then apply in ONE fused pass over
    // the weights (perturb_axpy_many) — the updates are linear in w, so
    // order is immaterial up to f32 rounding (§Perf L3).
    let mut items: Vec<(u64, f32)> = Vec::new();
    for c in contributions {
        let weight = c.n_samples as f64 / n_total;
        for (i, &seed) in c.seeds.iter().enumerate() {
            let ghat = c.delta_l[i] / (2.0 * cfg.eps as f64);
            let coeff = -(lr as f64) * weight * ghat / cfg.s_seeds as f64;
            items.push((seed, coeff as f32));
        }
    }
    crate::model::params::perturb_axpy_many(&mut global.0, &items, cfg.tau, cfg.dist);
}

/// Bytes on the wire for one ZO round, per participating client (measured
/// counterpart of Table 1's analytic model).
pub fn zo_round_bytes(s_seeds: usize, participants: usize) -> (u64, u64) {
    let up = (s_seeds * 4) as u64; // S f32 ΔL values
    // down: S issued seeds (8B) + the broadcast of all (seed, ΔL) pairs
    let down = (s_seeds * 8 + participants * s_seeds * (8 + 4)) as u64;
    (up, down)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::backend::{BatchX, LinearBackend};
    use crate::util::rng::{Distribution, Xoshiro256};

    fn sep_batch(b: usize, f: usize, seed: u64) -> Batch {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..b {
            let cls = (i % 2) as i32;
            y.push(cls);
            for j in 0..f {
                let c = if cls == 0 { -1.0 } else { 1.0 };
                x.push(if j % 2 == 0 { c } else { 0.0 } + (rng.next_f32() - 0.5) * 0.1);
            }
        }
        Batch {
            x: BatchX::F32(x),
            y,
            mask: vec![1.0; b],
        }
    }

    #[test]
    fn seed_issuer_unique_and_deterministic() {
        let iss = SeedIssuer::new(7);
        let mut all = std::collections::BTreeSet::new();
        for round in 0..20 {
            for client in 0..10 {
                for s in 0..5 {
                    assert!(all.insert(iss.seed(round, client, s)));
                }
            }
        }
        assert_eq!(iss.seed(3, 2, 1), SeedIssuer::new(7).seed(3, 2, 1));
        assert_ne!(iss.seed(3, 2, 1), SeedIssuer::new(8).seed(3, 2, 1));
    }

    #[test]
    fn zoopt_then_update_reduces_loss() {
        let be = LinearBackend::new(8, 2, 16);
        let mut global = ParamVec::zeros(be.dim());
        let batch = sep_batch(16, 8, 0);
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 4,
            dist: Distribution::Rademacher,
            grad_steps: 1,
        };
        let iss = SeedIssuer::new(0);
        let l0 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        for round in 0..30 {
            let seeds = iss.seeds_for(round, 0, cfg.s_seeds);
            let deltas = zoopt(
                &be,
                &global,
                &[vec![batch.clone()]],
                &seeds,
                &cfg,
                1.0,
            )
            .unwrap();
            let contrib = ZoContribution {
                client: 0,
                seeds,
                delta_l: deltas,
                n_samples: 16,
            };
            apply_zo_update(&mut global, &[contrib], &cfg, 0.3);
        }
        let l1 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        assert!(l1 < 0.8 * l0, "ZO rounds must learn: {l0} -> {l1}");
    }

    #[test]
    fn update_weighting_by_sample_count() {
        // a client with zero weight must not move the params; equal-ΔL
        // clients with equal n must move it twice as far as one alone.
        let cfg = ZoConfig::default();
        let mk = |seed, dl, n| ZoContribution {
            client: 0,
            seeds: vec![seed, seed + 1, seed + 2],
            delta_l: vec![dl; 3],
            n_samples: n,
        };
        let mut a = ParamVec::zeros(1000);
        apply_zo_update(&mut a, &[mk(1, 0.5, 100), mk(9, 0.5, 0)], &cfg, 0.1);
        let mut b = ParamVec::zeros(1000);
        apply_zo_update(&mut b, &[mk(1, 0.5, 77)], &cfg, 0.1);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn multi_step_zoopt_consistency() {
        // grad_steps=2: server replay (apply_zo_update) must land on the
        // same weights the client reached locally.
        let be = LinearBackend::new(6, 2, 8);
        let global = ParamVec::zeros(be.dim());
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 2,
            dist: Distribution::Rademacher,
            grad_steps: 2,
        };
        let b1 = sep_batch(8, 6, 1);
        let b2 = sep_batch(8, 6, 2);
        let seeds: Vec<u64> = (10..14).collect();
        let lr = 0.2f32;
        let deltas = zoopt(
            &be,
            &global,
            &[vec![b1.clone()], vec![b2.clone()]],
            &seeds,
            &cfg,
            lr,
        )
        .unwrap();
        assert_eq!(deltas.len(), 4);
        // local trajectory replayed by hand
        let mut w = global.clone();
        apply_seed_block(&mut w, &seeds[0..2], &deltas[0..2], &cfg, lr);
        apply_seed_block(&mut w, &seeds[2..4], &deltas[2..4], &cfg, lr);
        // server replay via apply_zo_update with one client at weight 1
        let mut g = global.clone();
        apply_zo_update(
            &mut g,
            &[ZoContribution {
                client: 0,
                seeds: seeds.clone(),
                delta_l: deltas.clone(),
                n_samples: 8,
            }],
            &cfg,
            lr,
        );
        for (x, y) in w.0.iter().zip(&g.0) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn zoopt_rejects_bad_seed_count() {
        let be = LinearBackend::new(4, 2, 4);
        let g = ParamVec::zeros(be.dim());
        let cfg = ZoConfig::default(); // S = 3
        let b = sep_batch(4, 4, 3);
        assert!(zoopt(&be, &g, &[vec![b]], &[1, 2], &cfg, 1.0).is_err());
    }

    #[test]
    fn round_bytes_model() {
        let (up, down) = zo_round_bytes(3, 10);
        assert_eq!(up, 12); // 3 × f32
        assert_eq!(down, 3 * 8 + 10 * 3 * 12);
    }

    #[test]
    fn gaussian_variant_also_learns() {
        let be = LinearBackend::new(8, 2, 16);
        let mut global = ParamVec::zeros(be.dim());
        let batch = sep_batch(16, 8, 5);
        let cfg = ZoConfig {
            eps: 1e-3,
            tau: 0.75,
            s_seeds: 4,
            dist: Distribution::Gaussian,
            grad_steps: 1,
        };
        let iss = SeedIssuer::new(1);
        let l0 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        for round in 0..30 {
            let seeds = iss.seeds_for(round, 0, cfg.s_seeds);
            let deltas =
                zoopt(&be, &global, &[vec![batch.clone()]], &seeds, &cfg, 1.0).unwrap();
            apply_zo_update(
                &mut global,
                &[ZoContribution {
                    client: 0,
                    seeds,
                    delta_l: deltas,
                    n_samples: 16,
                }],
                &cfg,
                0.2,
            );
        }
        let l1 = be.fwd_loss(&global, &batch).unwrap().mean_loss();
        assert!(l1 < l0, "{l0} -> {l1}");
    }
}
