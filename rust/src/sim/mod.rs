//! Device-capability scenario engine: per-client capability profiles,
//! deterministic availability/straggler traces, and round deadline
//! simulation.
//!
//! The paper's premise is that edge devices fall on a *spectrum* of memory
//! and communication capability, with eq. 4/5 ([`CostModel`]) deciding who
//! can afford first-order updates. The seed repo collapsed that spectrum
//! into a binary `Resource::{High,Low}` flag; this module replaces the
//! flag with [`CapabilityProfile`]s — a memory budget, up/down bandwidth,
//! a relative compute speed, and a per-round failure rate — sampled
//! reproducibly from the federation seed via a [`Scenario`].
//!
//! ## Eligibility
//!
//! A client is **FO-capable** when its memory budget covers the eq. 4
//! backprop footprint ([`CostModel::fo_threshold_bytes`]) and
//! **ZO-capable** when it covers the eq. 5 inference footprint
//! ([`CostModel::zo_mem_bytes`]). The federated engines derive the legacy
//! `Resource` class from these thresholds instead of a hardcoded flag; the
//! default [`Scenario::Binary`] uses symbolic budgets
//! ([`MemBudget::FitsBackprop`] / [`MemBudget::FitsZoOnly`]) so the class
//! split reproduces the seed's `assign_resources` exactly, bit for bit,
//! for any model.
//!
//! ## Deadlines and stragglers
//!
//! Every round, each sampled client runs a simulated timeline
//! ([`simulate_round`]): download its round payload, compute, upload.
//! Clients whose timeline exceeds the scenario deadline — or who hit a
//! failure drawn from their deterministic per-(round, client) trace —
//! drop out mid-round. The server folds in only surviving contributions,
//! and the `CommLedger` charges only the bytes actually on the wire
//! before the cut. All of this is derived *before* the parallel fan-out
//! from pure functions of `(master seed, round, client id)`, so results
//! stay bit-identical for every worker count (the `fed::server`
//! threading-model contract).
//!
//! ## Timing model
//!
//! Simulated milliseconds, with fixed documented constants:
//! * link time = bytes / (mbps · 125) — megabits/s to bytes/ms;
//! * compute time = sample-passes · (params / 10⁶) · [`MS_PER_MPARAM_PASS`]
//!   / `compute`, where a backprop pass counts [`FO_PASS_FACTOR`] forward
//!   passes and a ZO round costs `2 · S` forward passes per sample;
//! * a failing client aborts at a uniform point of its own timeline.
//!
//! The absolute scale is synthetic (the probe is not a real phone); what
//! matters is the *relative* ordering it induces between tiers, which is
//! what the paper's ablations sweep.

use crate::comm::CostModel;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

// The sim-domain RNG salts are *defined* in the central registry
// (`util::rng::salts`, DESIGN.md §14 — `detlint` rejects definitions
// anywhere else) and re-exported here at their historical paths, so no
// call site or stream changed when they moved.
pub use crate::util::rng::salts::{
    ARRIVAL_SALT, ASSIGN_SALT, ASYNC_SIM_SALT, CHURN_SALT, EDGE_FAIL_SALT, EDGE_SALT, SIM_SALT,
};

/// ms per sample-pass per million parameters at `compute = 1.0`.
pub const MS_PER_MPARAM_PASS: f64 = 0.1;

/// Relative cost of one backprop sample-pass vs one forward pass
/// (forward + backward + update).
pub const FO_PASS_FACTOR: f64 = 3.0;

/// Megabits/s → bytes per simulated millisecond.
pub fn bytes_per_ms(mbps: f64) -> f64 {
    mbps * 125.0
}

/// Sample-passes of one warm-phase local training job.
pub fn fo_passes(n: usize, local_epochs: usize) -> f64 {
    (n * local_epochs) as f64 * FO_PASS_FACTOR
}

/// Sample-passes of one ZO round: every sample is forwarded twice per
/// seed (w ± εz), regardless of how `grad_steps` groups the data.
pub fn zo_passes(n: usize, s_seeds: usize) -> f64 {
    (2 * s_seeds * n) as f64
}

/// Sample-passes of one FedKSeed local job: two sides per step over a
/// `step_batch`-sized minibatch.
pub fn kseed_passes(local_steps: usize, step_batch: usize) -> f64 {
    (2 * local_steps * step_batch) as f64
}

/// Pass-equivalents of one fused (seed, coeff) catch-up replay item: a
/// single O(P) traversal of the weight vector, modeled as one forward
/// sample-pass (both are parameter-proportional; the axpy is
/// memory-bound, the forward compute-bound — close enough at this
/// model's granularity).
pub const REPLAY_PASS_FACTOR: f64 = 1.0;

/// Sample-passes a rejoining client spends replaying `items` catch-up
/// items locally ([`crate::ckpt::CatchUpPlan::replay_items`]) — charged
/// on its round timeline so deadlines bite on the replay, not just the
/// download.
pub fn replay_passes(items: usize) -> f64 {
    items as f64 * REPLAY_PASS_FACTOR
}

// ---------------------------------------------------------------------------
// capability profiles
// ---------------------------------------------------------------------------

/// A tier's memory budget: absolute bytes, or symbolic — resolved against
/// the run's [`CostModel`] so the same scenario works for any model size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemBudget {
    Bytes(u64),
    /// Exactly the eq. 4 backprop footprint: FO-capable by definition.
    FitsBackprop,
    /// Exactly the eq. 5 ZO footprint: ZO-capable but never FO-capable
    /// (the threshold is strictly above it — see
    /// [`CostModel::fo_threshold_bytes`]).
    FitsZoOnly,
}

impl MemBudget {
    pub fn resolve(self, cost: &CostModel) -> u64 {
        match self {
            MemBudget::Bytes(b) => b,
            MemBudget::FitsBackprop => cost.fo_threshold_bytes(),
            MemBudget::FitsZoOnly => cost.zo_mem_bytes(),
        }
    }
}

/// One device class in a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTier {
    pub name: String,
    /// fraction of the fleet in this tier (fractions sum to 1)
    pub frac: f64,
    pub mem: MemBudget,
    pub up_mbps: f64,
    pub down_mbps: f64,
    /// relative compute speed (1.0 = reference device)
    pub compute: f64,
    /// per-round probability of failing mid-round
    pub drop_rate: f64,
    /// first round this tier's clients are part of the federation
    /// (late joiners; 0 = from the start)
    pub join_round: usize,
    /// per-round probability of sitting the whole round out (absent
    /// before any byte moves, unlike `drop_rate`'s mid-round cut)
    pub absent_rate: f64,
}

impl DeviceTier {
    fn new(name: &str, frac: f64, mem: MemBudget) -> Self {
        Self {
            name: name.to_string(),
            frac,
            mem,
            up_mbps: 10.0,
            down_mbps: 10.0,
            compute: 1.0,
            drop_rate: 0.0,
            join_round: 0,
            absent_rate: 0.0,
        }
    }

    fn net(mut self, up_mbps: f64, down_mbps: f64) -> Self {
        self.up_mbps = up_mbps;
        self.down_mbps = down_mbps;
        self
    }

    fn speed(mut self, compute: f64) -> Self {
        self.compute = compute;
        self
    }

    fn drops(mut self, rate: f64) -> Self {
        self.drop_rate = rate;
        self
    }

    fn joins(mut self, round: usize) -> Self {
        self.join_round = round;
        self
    }

    fn absent(mut self, rate: f64) -> Self {
        self.absent_rate = rate;
        self
    }

    fn from_json(i: usize, j: &Json) -> anyhow::Result<Self> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("tier{i}"));
        let frac = j
            .req("frac")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("tier {name}: frac must be a number"))?;
        let mem = match (j.get("mem"), j.get("mem_bytes")) {
            (Some(m), None) => match m.as_str() {
                Some("backprop") => MemBudget::FitsBackprop,
                Some("zo") => MemBudget::FitsZoOnly,
                _ => anyhow::bail!("tier {name}: mem must be \"backprop\" or \"zo\""),
            },
            (None, Some(b)) => MemBudget::Bytes(
                b.as_f64()
                    .filter(|v| *v >= 0.0)
                    .ok_or_else(|| anyhow::anyhow!("tier {name}: bad mem_bytes"))?
                    as u64,
            ),
            _ => anyhow::bail!("tier {name}: exactly one of mem / mem_bytes required"),
        };
        let num = |key: &str, default: f64| -> anyhow::Result<f64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("tier {name}: {key} must be a number")),
            }
        };
        let join_round = num("join_round", 0.0)?;
        anyhow::ensure!(
            join_round >= 0.0 && join_round.fract() == 0.0,
            "tier {name}: join_round must be a non-negative integer"
        );
        Ok(Self {
            frac,
            mem,
            up_mbps: num("up_mbps", 10.0)?,
            down_mbps: num("down_mbps", 10.0)?,
            compute: num("compute", 1.0)?,
            drop_rate: num("drop_rate", 0.0)?,
            join_round: join_round as usize,
            absent_rate: num("absent_rate", 0.0)?,
            name,
        })
    }
}

/// One client's sampled capabilities, as used by the round engines.
#[derive(Debug, Clone, PartialEq)]
pub struct CapabilityProfile {
    pub tier: String,
    pub mem_bytes: u64,
    pub up_mbps: f64,
    pub down_mbps: f64,
    pub compute: f64,
    pub drop_rate: f64,
    /// first round this client is part of the federation (late joiner)
    pub join_round: usize,
    /// per-round whole-round absence probability (churn)
    pub absent_rate: f64,
}

impl CapabilityProfile {
    /// Can run backprop-based local training (eq. 4).
    pub fn fo_capable(&self, cost: &CostModel) -> bool {
        self.mem_bytes >= cost.fo_threshold_bytes()
    }

    /// Can run forward-only SPSA evaluation (eq. 5).
    pub fn zo_capable(&self, cost: &CostModel) -> bool {
        self.mem_bytes >= cost.zo_mem_bytes()
    }

    fn from_tier(t: &DeviceTier, cost: &CostModel) -> Self {
        Self {
            tier: t.name.clone(),
            mem_bytes: t.mem.resolve(cost),
            up_mbps: t.up_mbps,
            down_mbps: t.down_mbps,
            compute: t.compute,
            drop_rate: t.drop_rate,
            join_round: t.join_round,
            absent_rate: t.absent_rate,
        }
    }
}

/// Churn trace: is this client part of round `round` at all? `false`
/// before the tier's `join_round` (late joiner) or on a whole-round
/// absence drawn from the deterministic per-(round, client) churn stream
/// ([`CHURN_SALT`] — separate from the mid-round drop trace, so default
/// scenarios stay bit-identical). Absent clients transmit nothing and go
/// stale; their next participation pays the catch-up downlink
/// ([`crate::ckpt::CheckpointStore`]) when checkpointing is enabled.
pub fn is_available(
    profile: &CapabilityProfile,
    master_seed: u64,
    round: usize,
    cid: usize,
) -> bool {
    if round < profile.join_round {
        return false;
    }
    if profile.absent_rate <= 0.0 {
        return true;
    }
    let mut rng = crate::fed::client::round_client_rng(master_seed, CHURN_SALT, round, cid);
    rng.next_f64() >= profile.absent_rate
}

// ---------------------------------------------------------------------------
// edge aggregators (two-tier topology)
// ---------------------------------------------------------------------------

/// Deterministic keyed assignment of a client to one of `e_count` edge
/// aggregators — a pure function of `(cid, e_count, seed)`, the same
/// SplitMix64-hash idiom as [`Scenario::profile_of`] under its own
/// [`EDGE_SALT`] domain. O(1) per call, so a 10^7-client fleet never
/// materializes the partition; the round engines evaluate it for the
/// O(sampled) clients they touch. `e_count <= 1` short-circuits to edge 0
/// without consuming the stream (the flat topology).
pub fn edge_of(cid: usize, e_count: usize, seed: u64) -> usize {
    if e_count <= 1 {
        return 0;
    }
    let mut h = crate::util::rng::SplitMix64(cid as u64);
    let mut rng = Xoshiro256::seed_from(seed ^ EDGE_SALT ^ h.next_u64());
    rng.below(e_count)
}

/// Whole-aggregator failure trace: does edge `edge` sit out round
/// `round` entirely, dropping its whole sampled cohort? A deterministic
/// per-(round, edge) draw under [`EDGE_FAIL_SALT`], so edge outages are
/// reproducible for every worker count and never perturb any per-client
/// stream. `rate <= 0` (the default — scenarios without edge profiles)
/// consumes no randomness.
pub fn edge_failed(master_seed: u64, round: usize, edge: usize, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    let mut rng = crate::fed::client::round_client_rng(master_seed, EDGE_FAIL_SALT, round, edge);
    rng.next_f64() < rate
}

/// One regional edge aggregator's link and reliability profile. Scenarios
/// that declare edge profiles diverge from the flat topology: client
/// timelines run against the bottleneck of their own link and their
/// edge's backhaul ([`edge_adjusted_profile`]), the edge's
/// `deadline_ms` (when set) overrides the scenario deadline for its
/// cohort, and `failure_rate` drives whole-cohort outages
/// ([`edge_failed`]). Scenarios without edge profiles keep every
/// historical trace byte-identical regardless of `--edges`.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeProfile {
    pub name: String,
    /// backhaul uplink of this aggregator (mbps)
    pub up_mbps: f64,
    /// backhaul downlink of this aggregator (mbps) — also the rate the
    /// edge-local checkpoint cache serves catch-up payloads at
    pub down_mbps: f64,
    /// per-cohort round deadline override in simulated ms; 0 = inherit
    /// the scenario deadline
    pub deadline_ms: f64,
    /// per-round probability the whole aggregator is unreachable
    pub failure_rate: f64,
}

impl EdgeProfile {
    fn new(name: &str, up_mbps: f64, down_mbps: f64) -> Self {
        Self {
            name: name.to_string(),
            up_mbps,
            down_mbps,
            deadline_ms: 0.0,
            failure_rate: 0.0,
        }
    }

    fn deadline(mut self, ms: f64) -> Self {
        self.deadline_ms = ms;
        self
    }

    fn fails(mut self, rate: f64) -> Self {
        self.failure_rate = rate;
        self
    }

    fn from_json(i: usize, j: &Json) -> anyhow::Result<Self> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("edge{i}"));
        let num = |key: &str, default: f64| -> anyhow::Result<f64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("edge {name}: {key} must be a number")),
            }
        };
        Ok(Self {
            up_mbps: num("up_mbps", 100.0)?,
            down_mbps: num("down_mbps", 100.0)?,
            deadline_ms: num("deadline_ms", 0.0)?,
            failure_rate: num("failure_rate", 0.0)?,
            name,
        })
    }
}

/// A client's effective capability behind its edge aggregator: the
/// download/upload rates bottleneck at `min(client link, edge backhaul)`
/// — the catch-up payload in particular is served from the edge-local
/// checkpoint cache at the edge's rate, never faster than the client can
/// receive it. Memory, compute and failure draws are the client's own.
pub fn edge_adjusted_profile(p: &CapabilityProfile, ep: &EdgeProfile) -> CapabilityProfile {
    CapabilityProfile {
        up_mbps: p.up_mbps.min(ep.up_mbps),
        down_mbps: p.down_mbps.min(ep.down_mbps),
        ..p.clone()
    }
}

// ---------------------------------------------------------------------------
// scenarios
// ---------------------------------------------------------------------------

/// A named fleet composition + deadline. JSON schema (see
/// `rust/src/exp/README.md`):
///
/// ```json
/// {
///   "name": "my-fleet",
///   "deadline_ms": 8.0,
///   "tiers": [
///     {"name": "server", "frac": 0.1, "mem": "backprop",
///      "up_mbps": 100, "down_mbps": 100, "compute": 8.0, "drop_rate": 0.0},
///     {"name": "phone", "frac": 0.9, "mem_bytes": 200000000,
///      "up_mbps": 2, "down_mbps": 8, "compute": 0.5, "drop_rate": 0.1}
///   ]
/// }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub tiers: Vec<DeviceTier>,
    /// round deadline in simulated ms; 0.0 = no deadline
    pub deadline_ms: f64,
    /// regional edge-aggregator profiles (two-tier topology). Empty =
    /// no edge modeling: `--edges E` then only partitions attribution
    /// and stays byte-identical to the flat topology. When non-empty,
    /// edge index `e` resolves to `edges[e % edges.len()]`.
    pub edges: Vec<EdgeProfile>,
}

/// How the fleet's capabilities are drawn.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum Scenario {
    /// The legacy binary High/Low fleet driven by `FedConfig::hi_frac`.
    /// Profile sampling consumes the exact RNG stream of the seed repo's
    /// `assign_resources`, so seed-equivalent configs stay bit-identical.
    #[default]
    Binary,
    Custom(ScenarioSpec),
}

/// Preset names accepted by `--scenario` (besides a JSON file path or an
/// inline `{...}` spec).
pub const PRESETS: [&str; 9] = [
    "binary",
    "uniform-high",
    "edge-spectrum",
    "stragglers",
    "flaky",
    "churn",
    "fleet",
    "geo-iot",
    "geo-phones",
];

/// Stream salt of the lazy per-client tier draw ([`Scenario::profile_of`])
/// — re-exported from the central registry (`util::rng::salts`); its own
/// domain, decorrelated from the materialized shuffle stream
/// ([`ASSIGN_SALT`]), the drop trace ([`SIM_SALT`]) and the churn trace
/// ([`CHURN_SALT`]).
pub use crate::util::rng::salts::PROFILE_SALT;

fn binary_tiers() -> Vec<DeviceTier> {
    vec![
        DeviceTier::new("high", 0.5, MemBudget::FitsBackprop)
            .net(100.0, 100.0)
            .speed(4.0),
        DeviceTier::new("low", 0.5, MemBudget::FitsZoOnly).net(8.0, 8.0),
    ]
}

impl Scenario {
    pub fn preset(name: &str) -> Option<Scenario> {
        let spec = match name {
            "binary" => return Some(Scenario::Binary),
            "uniform-high" => ScenarioSpec {
                name: name.into(),
                tiers: vec![DeviceTier::new("server", 1.0, MemBudget::FitsBackprop)
                    .net(100.0, 100.0)
                    .speed(4.0)],
                edges: Vec::new(),
                deadline_ms: 0.0,
            },
            "edge-spectrum" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("server", 0.05, MemBudget::FitsBackprop)
                        .net(50.0, 100.0)
                        .speed(8.0)
                        .drops(0.01),
                    DeviceTier::new("desktop", 0.15, MemBudget::FitsBackprop)
                        .net(20.0, 80.0)
                        .speed(4.0)
                        .drops(0.02),
                    DeviceTier::new("mobile", 0.5, MemBudget::FitsZoOnly)
                        .net(5.0, 20.0)
                        .drops(0.05),
                    DeviceTier::new("iot", 0.3, MemBudget::FitsZoOnly)
                        .net(1.0, 4.0)
                        .speed(0.25)
                        .drops(0.1),
                ],
                edges: Vec::new(),
                deadline_ms: 0.0,
            },
            // tuned for the linear-probe scale (d ≈ 10⁴): stragglers with
            // medium/large shards blow the 8 ms deadline mid-compute,
            // tiny-shard stragglers squeak through — the mixed
            // survive/drop fleet the related systems papers study
            "stragglers" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("high", 0.3, MemBudget::FitsBackprop)
                        .net(100.0, 100.0)
                        .speed(8.0),
                    DeviceTier::new("straggler", 0.7, MemBudget::FitsZoOnly)
                        .net(0.5, 0.5)
                        .speed(0.01)
                        .drops(0.05),
                ],
                edges: Vec::new(),
                deadline_ms: 8.0,
            },
            "flaky" => ScenarioSpec {
                name: name.into(),
                tiers: binary_tiers()
                    .into_iter()
                    .map(|t| t.drops(0.25))
                    .collect(),
                edges: Vec::new(),
                deadline_ms: 0.0,
            },
            // the cross-device million-client workload of the related
            // systems papers: a thin FO-capable backbone (so warm-up
            // still has someone to sample) over a vast ZO-only edge.
            // Designed for the lazy population layer — per-client
            // profiles derive on demand from (scenario, seed, id), so a
            // 10^7-client federation costs O(sampled) per round.
            "fleet" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("backbone", 0.02, MemBudget::FitsBackprop)
                        .net(100.0, 100.0)
                        .speed(8.0),
                    DeviceTier::new("phone", 0.68, MemBudget::FitsZoOnly).net(5.0, 20.0),
                    DeviceTier::new("iot", 0.30, MemBudget::FitsZoOnly)
                        .net(1.0, 4.0)
                        .speed(0.25)
                        .drops(0.02),
                ],
                edges: Vec::new(),
                deadline_ms: 0.0,
            },
            // the late-join / rejoin workload the ckpt subsystem exists
            // for: an anchor tier that is always there, a flaky tier that
            // sits out a third of its rounds (rejoining stale), and a
            // late tier that only joins at round 8 — inside the ZO phase
            // at smoke scale (pivot 6), during warm-up at larger scales.
            "churn" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("anchor", 0.25, MemBudget::FitsBackprop)
                        .net(100.0, 100.0)
                        .speed(4.0),
                    DeviceTier::new("flaky", 0.35, MemBudget::FitsZoOnly)
                        .net(8.0, 8.0)
                        .absent(0.35),
                    DeviceTier::new("late", 0.4, MemBudget::FitsZoOnly)
                        .net(8.0, 8.0)
                        .drops(0.1)
                        .joins(8),
                ],
                edges: Vec::new(),
                deadline_ms: 0.0,
            },
            // geo-distributed IoT fleet behind regional aggregators: the
            // device side is the `fleet` composition's low end, but the
            // per-region backhaul — not the device link — is the
            // bottleneck, some regions run tighter deadlines, and a
            // region occasionally goes dark for a whole round
            // (edge-failure cohort drops). Pair with `--edges 4`.
            "geo-iot" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("gateway", 0.05, MemBudget::FitsBackprop)
                        .net(50.0, 100.0)
                        .speed(4.0),
                    DeviceTier::new("sensor", 0.65, MemBudget::FitsZoOnly)
                        .net(1.0, 4.0)
                        .speed(0.25)
                        .drops(0.05),
                    DeviceTier::new("meter", 0.3, MemBudget::FitsZoOnly)
                        .net(0.5, 2.0)
                        .speed(0.1)
                        .drops(0.1),
                ],
                edges: vec![
                    EdgeProfile::new("metro", 40.0, 40.0),
                    EdgeProfile::new("rural", 2.0, 2.0).fails(0.1),
                    EdgeProfile::new("industrial", 10.0, 10.0).deadline(50.0),
                    EdgeProfile::new("remote", 1.0, 1.0).deadline(80.0).fails(0.2),
                ],
                deadline_ms: 0.0,
            },
            // geo-distributed phone fleet: well-provisioned regional
            // aggregators over the `fleet` phone/backbone mix — edges
            // barely bottleneck, outages are rare, so this preset is the
            // "mild" end of the topology spectrum.
            "geo-phones" => ScenarioSpec {
                name: name.into(),
                tiers: vec![
                    DeviceTier::new("backbone", 0.04, MemBudget::FitsBackprop)
                        .net(100.0, 100.0)
                        .speed(8.0),
                    DeviceTier::new("phone", 0.8, MemBudget::FitsZoOnly).net(5.0, 20.0),
                    DeviceTier::new("tablet", 0.16, MemBudget::FitsZoOnly)
                        .net(8.0, 30.0)
                        .speed(1.5)
                        .drops(0.02),
                ],
                edges: vec![
                    EdgeProfile::new("region-a", 200.0, 200.0),
                    EdgeProfile::new("region-b", 100.0, 100.0),
                    EdgeProfile::new("region-c", 50.0, 50.0).fails(0.02),
                ],
                deadline_ms: 0.0,
            },
            _ => return None,
        };
        Some(Scenario::Custom(spec))
    }

    /// Resolve `--scenario <value>`: an inline `{...}` JSON spec, a preset
    /// name, or a path to a JSON file.
    pub fn load(spec: &str) -> anyhow::Result<Scenario> {
        let t = spec.trim();
        if t.starts_with('{') {
            let j = Json::parse(t).map_err(|e| anyhow::anyhow!("inline scenario: {e}"))?;
            return Scenario::from_json(&j);
        }
        if let Some(s) = Scenario::preset(t) {
            return Ok(s);
        }
        let text = std::fs::read_to_string(t).map_err(|e| {
            anyhow::anyhow!("--scenario {t:?}: not a preset (one of {PRESETS:?}) and not a readable file: {e}")
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{t}: {e}"))?;
        Scenario::from_json(&j)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Scenario> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("custom")
            .to_string();
        let deadline_ms = match j.get("deadline_ms") {
            None => 0.0,
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("deadline_ms must be a number"))?,
        };
        let tiers_json = j
            .req("tiers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tiers must be an array"))?;
        let tiers = tiers_json
            .iter()
            .enumerate()
            .map(|(i, t)| DeviceTier::from_json(i, t))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let edges = match j.get("edges") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("edges must be an array"))?
                .iter()
                .enumerate()
                .map(|(i, e)| EdgeProfile::from_json(i, e))
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        let sc = Scenario::Custom(ScenarioSpec {
            name,
            tiers,
            deadline_ms,
            edges,
        });
        sc.validate()?;
        Ok(sc)
    }

    pub fn name(&self) -> &str {
        match self {
            Scenario::Binary => "binary",
            Scenario::Custom(s) => &s.name,
        }
    }

    pub fn deadline_ms(&self) -> f64 {
        match self {
            Scenario::Binary => 0.0,
            Scenario::Custom(s) => s.deadline_ms,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let spec = match self {
            Scenario::Binary => return Ok(()),
            Scenario::Custom(s) => s,
        };
        anyhow::ensure!(!spec.tiers.is_empty(), "scenario has no tiers");
        anyhow::ensure!(spec.deadline_ms >= 0.0, "deadline_ms must be >= 0");
        let mut sum = 0.0;
        for t in &spec.tiers {
            anyhow::ensure!(t.frac >= 0.0, "tier {}: frac must be >= 0", t.name);
            anyhow::ensure!(
                t.up_mbps > 0.0 && t.down_mbps > 0.0,
                "tier {}: bandwidth must be > 0",
                t.name
            );
            anyhow::ensure!(t.compute > 0.0, "tier {}: compute must be > 0", t.name);
            anyhow::ensure!(
                (0.0..=1.0).contains(&t.drop_rate),
                "tier {}: drop_rate must be in [0,1]",
                t.name
            );
            anyhow::ensure!(
                (0.0..=1.0).contains(&t.absent_rate),
                "tier {}: absent_rate must be in [0,1]",
                t.name
            );
            sum += t.frac;
        }
        anyhow::ensure!(
            (sum - 1.0).abs() < 1e-6,
            "tier fractions sum to {sum}, expected 1"
        );
        for e in &spec.edges {
            anyhow::ensure!(
                e.up_mbps > 0.0 && e.down_mbps > 0.0,
                "edge {}: bandwidth must be > 0",
                e.name
            );
            anyhow::ensure!(
                e.deadline_ms >= 0.0,
                "edge {}: deadline_ms must be >= 0",
                e.name
            );
            anyhow::ensure!(
                (0.0..=1.0).contains(&e.failure_rate),
                "edge {}: failure_rate must be in [0,1]",
                e.name
            );
        }
        Ok(())
    }

    /// The aggregator profile of edge index `edge` — `None` when the
    /// scenario declares no edge modeling (the flat-equivalent default).
    /// With fewer declared profiles than `--edges E`, indices wrap
    /// (`edge % profiles.len()`), so a 3-profile preset still covers
    /// E = 16.
    pub fn edge_profile(&self, edge: usize) -> Option<&EdgeProfile> {
        match self {
            Scenario::Binary => None,
            Scenario::Custom(s) => {
                if s.edges.is_empty() {
                    None
                } else {
                    Some(&s.edges[edge % s.edges.len()])
                }
            }
        }
    }

    /// Whether this scenario models edge aggregators at all. `false`
    /// means `--edges E` is pure attribution: every trace stays
    /// byte-identical to the flat topology.
    pub fn has_edge_profiles(&self) -> bool {
        matches!(self, Scenario::Custom(s) if !s.edges.is_empty())
    }

    /// The round deadline edge `edge`'s cohort runs under: the edge's
    /// override when it declares one, the scenario deadline otherwise.
    pub fn edge_deadline_ms(&self, edge: usize) -> f64 {
        match self.edge_profile(edge) {
            Some(ep) if ep.deadline_ms > 0.0 => ep.deadline_ms,
            _ => self.deadline_ms(),
        }
    }

    /// Per-tier client counts for a fleet of `k`. `hi_count` drives the
    /// Binary split (so the legacy `hi_frac` rounding is reproduced
    /// exactly); custom tiers use largest-remainder allocation of their
    /// fractions.
    pub fn tier_counts(&self, k: usize, hi_count: usize) -> Vec<usize> {
        match self {
            Scenario::Binary => {
                let hi = hi_count.min(k);
                vec![hi, k - hi]
            }
            Scenario::Custom(spec) => {
                let mut counts: Vec<usize> = spec
                    .tiers
                    .iter()
                    .map(|t| (t.frac * k as f64).floor() as usize)
                    .collect();
                let mut rem: Vec<(usize, f64)> = spec
                    .tiers
                    .iter()
                    .enumerate()
                    .map(|(i, t)| (i, t.frac * k as f64 - counts[i] as f64))
                    .collect();
                // largest fractional remainder first; ties → earlier
                // tier. total_cmp: a NaN fraction (degenerate spec) must
                // order deterministically, not panic the partial_cmp
                // unwrap mid-round (DESIGN.md §14 float-ordering rule)
                rem.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
                let assigned: usize = counts.iter().sum();
                for (i, _) in rem.iter().cycle().take(k - assigned) {
                    counts[*i] += 1;
                }
                counts
            }
        }
    }

    fn resolved_tiers(&self) -> Vec<DeviceTier> {
        match self {
            Scenario::Binary => binary_tiers(),
            Scenario::Custom(s) => s.tiers.clone(),
        }
    }

    /// Sample the fleet's capability profiles. Membership is drawn from a
    /// seed-shuffled client order (the exact RNG stream of the legacy
    /// `assign_resources`: one shuffle of `0..k` from
    /// `seed ^ `[`ASSIGN_SALT`]),
    /// then tiers claim consecutive runs of that order — so the Binary
    /// scenario reproduces the seed's High/Low assignment bit for bit.
    pub fn sample_profiles(
        &self,
        k: usize,
        hi_count: usize,
        seed: u64,
        cost: &CostModel,
    ) -> Vec<CapabilityProfile> {
        let tiers = self.resolved_tiers();
        let counts = self.tier_counts(k, hi_count);
        assert_eq!(tiers.len(), counts.len());
        assert_eq!(counts.iter().sum::<usize>(), k);
        let mut rng = Xoshiro256::seed_from(seed ^ ASSIGN_SALT);
        let mut order: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut order);
        let mut out: Vec<Option<CapabilityProfile>> = vec![None; k];
        let mut next = order.iter();
        for (tier, count) in tiers.iter().zip(&counts) {
            for _ in 0..*count {
                let cid = *next.next().expect("counts sum to k");
                out[cid] = Some(CapabilityProfile::from_tier(tier, cost));
            }
        }
        out.into_iter().map(|p| p.expect("all clients assigned")).collect()
    }

    /// Per-tier draw probabilities of the lazy population layer: custom
    /// tiers use their declared fractions; the Binary fleet reproduces
    /// its `hi_count / k` split as a probability.
    fn tier_probs(&self, k: usize, hi_count: usize) -> Vec<f64> {
        match self {
            Scenario::Binary => {
                let p = if k == 0 {
                    0.0
                } else {
                    hi_count.min(k) as f64 / k as f64
                };
                vec![p, 1.0 - p]
            }
            Scenario::Custom(s) => s.tiers.iter().map(|t| t.frac).collect(),
        }
    }

    /// Derive ONE client's capability profile on demand — a pure function
    /// of `(scenario, seed, cid)` (plus the Binary split parameters), the
    /// core of the **lazy population layer**: a federation over 10^7
    /// clients never materializes a profile vector, it evaluates this for
    /// the O(K) clients a round actually samples.
    ///
    /// The tier is a keyed pseudo-random draw over the id space: the
    /// client id is hashed ([`crate::util::rng::SplitMix64`]) into a
    /// [`PROFILE_SALT`]-salted stream and one uniform picks the tier by
    /// cumulative fraction. Unlike the materialized
    /// [`Self::sample_profiles`] shuffle (kept, bit-compatible, for
    /// seed-era configs), tier occupancy here is binomial rather than
    /// exact-count — the correct model for effectively unbounded
    /// cross-device populations. Equivalence with the materialized *lazy*
    /// vector ([`Self::sample_profiles_lazy`]) is element-wise exact and
    /// pinned by `prop_profile_of_matches_lazy_materialization`.
    pub fn profile_of(
        &self,
        k: usize,
        hi_count: usize,
        seed: u64,
        cid: usize,
        cost: &CostModel,
    ) -> CapabilityProfile {
        let mut h = crate::util::rng::SplitMix64(cid as u64);
        let mut rng = Xoshiro256::seed_from(seed ^ PROFILE_SALT ^ h.next_u64());
        let u = rng.next_f64();
        let tiers = self.resolved_tiers();
        let probs = self.tier_probs(k, hi_count);
        assert_eq!(tiers.len(), probs.len());
        let mut acc = 0.0f64;
        let mut pick = tiers.len() - 1; // guard fp round-off: last tier
        for (i, p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                pick = i;
                break;
            }
        }
        CapabilityProfile::from_tier(&tiers[pick], cost)
    }

    /// Materialize the lazy population's profiles for all `k` clients —
    /// exactly `(0..k).map(profile_of)`. Only sensible at test/reference
    /// scale; the round engines never call it.
    pub fn sample_profiles_lazy(
        &self,
        k: usize,
        hi_count: usize,
        seed: u64,
        cost: &CostModel,
    ) -> Vec<CapabilityProfile> {
        (0..k)
            .map(|cid| self.profile_of(k, hi_count, seed, cid, cost))
            .collect()
    }

    /// Population fraction that is FO-capable under `cost` — the draw
    /// probability mass of tiers whose memory budget covers the eq. 4
    /// threshold. The lazy warm-phase sampler uses this to prove its
    /// rejection loop terminates, and HeteroFL's budget model uses it as
    /// the expected full-width share.
    pub fn fo_tier_frac(&self, k: usize, hi_count: usize, cost: &CostModel) -> f64 {
        let tiers = self.resolved_tiers();
        let probs = self.tier_probs(k, hi_count);
        tiers
            .iter()
            .zip(&probs)
            .filter(|(t, _)| t.mem.resolve(cost) >= cost.fo_threshold_bytes())
            .map(|(_, p)| *p)
            .sum()
    }
}

// ---------------------------------------------------------------------------
// round simulation
// ---------------------------------------------------------------------------

/// One client's planned round, in wire order: download, compute, upload.
#[derive(Debug, Clone, Copy)]
pub struct RoundPlan {
    /// payload the client must download before computing
    pub down_bytes: u64,
    /// sample-passes of compute
    pub passes: f64,
    /// payload uploaded after computing
    pub up_bytes: u64,
}

/// What the wire actually saw.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundOutcome {
    pub survives: bool,
    /// bytes actually uploaded (full on survival, partial on a drop)
    pub up_bytes: u64,
    /// bytes actually downloaded
    pub down_bytes: u64,
    /// simulated ms until completion (or the cut)
    pub sim_ms: f64,
}

/// The three timeline legs of a planned round (download, compute,
/// upload) in simulated ms — the deterministic core shared by
/// [`simulate_round`] (which adds failure draws and deadline cuts) and
/// the adaptive seed-budget planner ([`max_affordable_s`], which inverts
/// it). Consumes no randomness.
pub fn leg_times_ms(
    profile: &CapabilityProfile,
    plan: &RoundPlan,
    params: u64,
) -> (f64, f64, f64) {
    let t_down = plan.down_bytes as f64 / bytes_per_ms(profile.down_mbps);
    let t_comp = plan.passes * (params as f64 / 1e6) * MS_PER_MPARAM_PASS / profile.compute;
    let t_up = plan.up_bytes as f64 / bytes_per_ms(profile.up_mbps);
    (t_down, t_comp, t_up)
}

/// Full planned timeline length (no failure draw): what the client's
/// round costs if nothing cuts it. Deterministic — the planner's view of
/// [`simulate_round`]'s `sim_ms` for a survivor.
pub fn plan_time_ms(profile: &CapabilityProfile, plan: &RoundPlan, params: u64) -> f64 {
    let (t_down, t_comp, t_up) = leg_times_ms(profile, plan, params);
    t_down + t_comp + t_up
}

/// Invert the round-timeline model for the adaptive seed budget: the
/// largest `S ∈ [s_min, s_max]` whose planned timeline (`mk_plan(S)`,
/// catch-up charge and all) fits `budget_ms` — or `s_min` when even the
/// floor does not fit (the client is then expected to drop at simulation
/// time, exactly as it would have under the uniform protocol). A
/// non-positive budget means "unconstrained" and yields `s_max`.
///
/// The timeline is monotone non-decreasing in S (more probes ⇒ more
/// seed-issue bytes, more forward passes, more ΔL uplink), so a binary
/// search against [`plan_time_ms`] finds the frontier in O(log(s_max −
/// s_min)) deterministic evaluations — no RNG is consumed, keeping the
/// planner invisible to the per-(round, client) trace streams.
pub fn max_affordable_s(
    profile: &CapabilityProfile,
    params: u64,
    budget_ms: f64,
    s_min: usize,
    s_max: usize,
    mk_plan: impl Fn(usize) -> RoundPlan,
) -> usize {
    assert!(s_min >= 1 && s_min <= s_max);
    if budget_ms <= 0.0 {
        return s_max;
    }
    let fits = |s: usize| plan_time_ms(profile, &mk_plan(s), params) <= budget_ms;
    if fits(s_max) {
        return s_max;
    }
    if !fits(s_min) {
        return s_min;
    }
    // invariant: lo fits, hi does not
    let (mut lo, mut hi) = (s_min, s_max);
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Poisson arrival model of the async engine: the simulated delay (ms)
/// between a dispatch being issued and the client actually starting its
/// download→compute→upload timeline — an Exp(`rate_per_ms`) draw via
/// inverse CDF from a dedicated per-(dispatch, client) stream
/// ([`ARRIVAL_SALT`]). `rate_per_ms <= 0` models staggered-immediate
/// arrivals (delay 0) and consumes no randomness, so the default
/// `--arrival-rate 0` leaves every other stream untouched.
pub fn arrival_delay_ms(
    master_seed: u64,
    dispatch_seq: usize,
    cid: usize,
    rate_per_ms: f64,
) -> f64 {
    if rate_per_ms <= 0.0 {
        return 0.0;
    }
    let mut rng =
        crate::fed::client::round_client_rng(master_seed, ARRIVAL_SALT, dispatch_seq, cid);
    let u = rng.next_f64(); // in [0, 1) — so 1-u is in (0, 1] and ln is finite
    -(1.0 - u).ln() / rate_per_ms
}

/// Simulate one client's round against its profile, the scenario deadline
/// (`0.0` = none) and its availability trace. `trace` must be the
/// per-(round, client) RNG salted with [`SIM_SALT`]; exactly two draws are
/// consumed per call, so the stream is stable across code paths. Pure —
/// callers evaluate it before any parallel fan-out.
pub fn simulate_round(
    profile: &CapabilityProfile,
    plan: &RoundPlan,
    params: u64,
    deadline_ms: f64,
    trace: &mut Xoshiro256,
) -> RoundOutcome {
    let down_rate = bytes_per_ms(profile.down_mbps);
    let up_rate = bytes_per_ms(profile.up_mbps);
    let (t_down, t_comp, t_up) = leg_times_ms(profile, plan, params);
    let t_total = t_down + t_comp + t_up;

    // availability trace: always two draws, whether or not they matter
    let u_fail = trace.next_f64();
    let u_when = trace.next_f64();
    let mut cut = f64::INFINITY;
    if u_fail < profile.drop_rate {
        cut = u_when * t_total;
    }
    if deadline_ms > 0.0 {
        cut = cut.min(deadline_ms);
    }

    if t_total <= cut {
        return RoundOutcome {
            survives: true,
            up_bytes: plan.up_bytes,
            down_bytes: plan.down_bytes,
            sim_ms: t_total,
        };
    }
    // dropped mid-round: charge only what was on the wire before the cut
    let down_bytes = plan.down_bytes.min((cut * down_rate) as u64);
    let up_bytes = if cut > t_down + t_comp {
        plan.up_bytes.min(((cut - t_down - t_comp) * up_rate) as u64)
    } else {
        0
    };
    RoundOutcome {
        survives: false,
        up_bytes,
        down_bytes,
        sim_ms: cut,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe_cost() -> CostModel {
        CostModel::generic(7690, 32)
    }

    #[test]
    fn tier_counts_survive_nan_fraction_deterministically() {
        // regression (DESIGN.md §14 float-ordering rule): a NaN tier
        // fraction — a degenerate spec, e.g. 0.0/0.0 from generated
        // JSON — used to panic the largest-remainder sort's
        // partial_cmp().unwrap(); under total_cmp it must instead order
        // deterministically and still allocate exactly k clients
        let spec = ScenarioSpec {
            name: "nan-frac".into(),
            tiers: vec![
                DeviceTier::new("ok", 0.5, MemBudget::FitsBackprop),
                DeviceTier::new("nan", f64::NAN, MemBudget::FitsZoOnly),
            ],
            edges: Vec::new(),
            deadline_ms: 0.0,
        };
        let s = Scenario::Custom(spec);
        let a = s.tier_counts(7, 0);
        let b = s.tier_counts(7, 0);
        assert_eq!(a, b, "NaN ordering must be deterministic");
        assert_eq!(a.iter().sum::<usize>(), 7, "every client gets a tier");
    }

    fn profile(up: f64, down: f64, compute: f64, drop_rate: f64) -> CapabilityProfile {
        CapabilityProfile {
            tier: "t".into(),
            mem_bytes: u64::MAX,
            up_mbps: up,
            down_mbps: down,
            compute,
            drop_rate,
            join_round: 0,
            absent_rate: 0.0,
        }
    }

    #[test]
    fn presets_validate() {
        for name in PRESETS {
            let s = Scenario::preset(name).unwrap();
            s.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(Scenario::load(name).unwrap(), s);
        }
        assert!(Scenario::preset("nope").is_none());
        assert!(Scenario::load("no-such-preset-or-file").is_err());
    }

    #[test]
    fn binary_profiles_match_legacy_resource_split() {
        let cost = probe_cost();
        for (k, hi, seed) in [(20, 6, 0u64), (20, 6, 1), (50, 5, 7), (8, 1, 3)] {
            let profiles = Scenario::Binary.sample_profiles(k, hi, seed, &cost);
            let classes: Vec<bool> = profiles.iter().map(|p| p.fo_capable(&cost)).collect();
            let legacy = crate::fed::server::assign_resources(k, hi, seed);
            for (c, l) in classes.iter().zip(&legacy) {
                assert_eq!(*c, *l == crate::fed::client::Resource::High, "k={k} hi={hi} seed={seed}");
            }
            assert_eq!(classes.iter().filter(|&&c| c).count(), hi);
            // low tier is ZO-capable but never FO-capable
            for p in &profiles {
                assert!(p.zo_capable(&cost));
            }
        }
    }

    #[test]
    fn tier_counts_conserve_clients() {
        let spec = Scenario::preset("edge-spectrum").unwrap();
        for k in [1usize, 7, 8, 20, 50, 101] {
            let counts = spec.tier_counts(k, 0);
            assert_eq!(counts.iter().sum::<usize>(), k, "k={k}");
        }
        // binary honors the exact hi_count
        assert_eq!(Scenario::Binary.tier_counts(10, 3), vec![3, 7]);
        assert_eq!(Scenario::Binary.tier_counts(10, 12), vec![10, 0]);
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let cost = probe_cost();
        let s = Scenario::preset("edge-spectrum").unwrap();
        let a = s.sample_profiles(30, 0, 5, &cost);
        let b = s.sample_profiles(30, 0, 5, &cost);
        let c = s.sample_profiles(30, 0, 6, &cost);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn edge_of_is_deterministic_in_range_and_flat_at_one() {
        for seed in [0u64, 7, 42] {
            for cid in 0..500usize {
                // E = 1 is the flat topology: everyone on edge 0
                assert_eq!(edge_of(cid, 1, seed), 0);
                for e_count in [2usize, 4, 16] {
                    let e = edge_of(cid, e_count, seed);
                    assert!(e < e_count, "edge {e} out of range for E={e_count}");
                    assert_eq!(e, edge_of(cid, e_count, seed), "must be deterministic");
                }
            }
        }
        // the partition actually spreads: at E=4 over 500 clients every
        // edge gets someone (binomial with p=1/4 — a miss would signal a
        // broken keyed stream, not bad luck)
        let mut counts = [0usize; 4];
        for cid in 0..500 {
            counts[edge_of(cid, 4, 7)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        // different seeds shuffle the assignment
        let a: Vec<usize> = (0..64).map(|c| edge_of(c, 4, 1)).collect();
        let b: Vec<usize> = (0..64).map(|c| edge_of(c, 4, 2)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn edge_failure_trace_is_keyed_and_rate_bounded() {
        // rate 0 never fails and consumes no stream; rate 1 always fails
        for round in 0..20 {
            for edge in 0..4 {
                assert!(!edge_failed(7, round, edge, 0.0));
                assert!(edge_failed(7, round, edge, 1.0));
            }
        }
        // deterministic per (seed, round, edge); different rounds draw
        // independently (some flip at rate 0.5 across 64 rounds)
        let draws: Vec<bool> = (0..64).map(|r| edge_failed(7, r, 1, 0.5)).collect();
        assert_eq!(draws, (0..64).map(|r| edge_failed(7, r, 1, 0.5)).collect::<Vec<_>>());
        assert!(draws.iter().any(|&d| d) && draws.iter().any(|&d| !d));
    }

    #[test]
    fn edge_adjusted_profile_bottlenecks_bandwidth_only() {
        let p = profile(10.0, 20.0, 2.0, 0.1);
        let ep = EdgeProfile::new("m", 5.0, 40.0);
        let adj = edge_adjusted_profile(&p, &ep);
        assert_eq!(adj.up_mbps, 5.0, "uplink bottlenecks at the edge");
        assert_eq!(adj.down_mbps, 20.0, "downlink bottlenecks at the client");
        assert_eq!(adj.compute, p.compute);
        assert_eq!(adj.drop_rate, p.drop_rate);
        assert_eq!(adj.mem_bytes, p.mem_bytes);
    }

    #[test]
    fn geo_presets_declare_edges_and_accessors_resolve() {
        let geo = Scenario::preset("geo-iot").unwrap();
        assert!(geo.has_edge_profiles());
        // indices wrap: E = 16 over a 4-profile preset stays covered
        for e in 0..16 {
            let ep = geo.edge_profile(e).unwrap();
            assert_eq!(ep.name, geo.edge_profile(e % 4).unwrap().name);
        }
        // deadline override only where the edge declares one
        assert_eq!(geo.edge_deadline_ms(0), geo.deadline_ms());
        assert_eq!(geo.edge_deadline_ms(2), 50.0);
        // flat-compatible scenarios: no edge modeling anywhere
        for name in ["binary", "fleet", "stragglers"] {
            let s = Scenario::preset(name).unwrap();
            assert!(!s.has_edge_profiles(), "{name}");
            assert!(s.edge_profile(0).is_none(), "{name}");
            assert_eq!(s.edge_deadline_ms(3), s.deadline_ms(), "{name}");
        }
    }

    #[test]
    fn edge_profiles_parse_from_json_and_validate() {
        let sc = Scenario::load(
            r#"{"name": "t", "tiers": [
                 {"name": "a", "frac": 1.0, "mem": "zo"}],
               "edges": [
                 {"name": "e0", "up_mbps": 10, "down_mbps": 10},
                 {"down_mbps": 5, "deadline_ms": 9, "failure_rate": 0.5}]}"#,
        )
        .unwrap();
        assert!(sc.has_edge_profiles());
        let e1 = sc.edge_profile(1).unwrap();
        assert_eq!(e1.name, "edge1");
        assert_eq!(e1.up_mbps, 100.0);
        assert_eq!(e1.down_mbps, 5.0);
        assert_eq!(e1.deadline_ms, 9.0);
        assert_eq!(e1.failure_rate, 0.5);
        // invalid edge declarations are rejected
        for bad in [
            r#"{"tiers": [{"frac": 1.0, "mem": "zo"}], "edges": [{"up_mbps": 0}]}"#,
            r#"{"tiers": [{"frac": 1.0, "mem": "zo"}], "edges": [{"failure_rate": 2}]}"#,
            r#"{"tiers": [{"frac": 1.0, "mem": "zo"}], "edges": [{"deadline_ms": -1}]}"#,
        ] {
            assert!(Scenario::load(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn survivor_is_charged_in_full() {
        let p = profile(10.0, 10.0, 1.0, 0.0);
        let plan = RoundPlan {
            down_bytes: 1000,
            passes: 10.0,
            up_bytes: 500,
        };
        let mut trace = Xoshiro256::seed_from(0);
        let o = simulate_round(&p, &plan, 1_000_000, 0.0, &mut trace);
        assert!(o.survives);
        assert_eq!(o.up_bytes, plan.up_bytes);
        assert_eq!(o.down_bytes, plan.down_bytes);
        // t = 1000/1250 + 10*0.1 + 500/1250 = 0.8 + 1.0 + 0.4
        assert!((o.sim_ms - 2.2).abs() < 1e-9, "{}", o.sim_ms);
    }

    #[test]
    fn deadline_cuts_during_download_charges_no_uplink() {
        let p = profile(10.0, 1.0, 1.0, 0.0);
        let plan = RoundPlan {
            down_bytes: 10_000, // 80 ms at 1 mbps
            passes: 100.0,
            up_bytes: 400,
        };
        let mut trace = Xoshiro256::seed_from(0);
        let o = simulate_round(&p, &plan, 1_000_000, 2.0, &mut trace);
        assert!(!o.survives);
        assert_eq!(o.up_bytes, 0);
        assert_eq!(o.down_bytes, (2.0 * bytes_per_ms(1.0)) as u64);
        assert!(o.down_bytes < plan.down_bytes);
        assert_eq!(o.sim_ms, 2.0);
    }

    #[test]
    fn deadline_cut_during_upload_charges_partial_uplink() {
        let p = profile(1.0, 100.0, 100.0, 0.0);
        let plan = RoundPlan {
            down_bytes: 125, // 0.01 ms
            passes: 0.0,
            up_bytes: 12_500, // 100 ms at 1 mbps
        };
        let mut trace = Xoshiro256::seed_from(0);
        let o = simulate_round(&p, &plan, 1_000_000, 50.0, &mut trace);
        assert!(!o.survives);
        assert_eq!(o.down_bytes, plan.down_bytes);
        assert!(o.up_bytes > 0 && o.up_bytes < plan.up_bytes, "{}", o.up_bytes);
    }

    #[test]
    fn drop_rate_one_always_fails_and_is_deterministic() {
        let p = profile(10.0, 10.0, 1.0, 1.0);
        let plan = RoundPlan {
            down_bytes: 1000,
            passes: 10.0,
            up_bytes: 1000,
        };
        let mut t1 = Xoshiro256::seed_from(42);
        let mut t2 = Xoshiro256::seed_from(42);
        let a = simulate_round(&p, &plan, 1_000_000, 0.0, &mut t1);
        let b = simulate_round(&p, &plan, 1_000_000, 0.0, &mut t2);
        assert!(!a.survives);
        assert_eq!(a, b);
        assert!(a.sim_ms >= 0.0);
    }

    #[test]
    fn empty_plan_survives_instantly() {
        let p = profile(1.0, 1.0, 1.0, 0.0);
        let plan = RoundPlan {
            down_bytes: 0,
            passes: 0.0,
            up_bytes: 0,
        };
        let mut trace = Xoshiro256::seed_from(0);
        let o = simulate_round(&p, &plan, 1_000_000, 0.001, &mut trace);
        assert!(o.survives);
        assert_eq!((o.up_bytes, o.down_bytes), (0, 0));
    }

    #[test]
    fn churn_preset_has_late_joiners_and_absences() {
        let s = Scenario::preset("churn").unwrap();
        s.validate().unwrap();
        let Scenario::Custom(spec) = &s else { panic!() };
        assert!(spec.tiers.iter().any(|t| t.join_round > 0));
        assert!(spec.tiers.iter().any(|t| t.absent_rate > 0.0));
        // anchor tier is always available
        let cost = probe_cost();
        let profiles = s.sample_profiles(8, 0, 0, &cost);
        let anchor = profiles.iter().find(|p| p.tier == "anchor").unwrap();
        for round in 0..20 {
            assert!(is_available(anchor, 0, round, 0));
        }
    }

    #[test]
    fn availability_respects_join_round_and_is_deterministic() {
        let mut late = profile(10.0, 10.0, 1.0, 0.0);
        late.join_round = 5;
        for round in 0..5 {
            assert!(!is_available(&late, 7, round, 3));
        }
        assert!(is_available(&late, 7, 5, 3));
        // absences: deterministic per (seed, round, cid), rate-0 never
        // absent, rate-1 always absent
        let mut flaky = profile(10.0, 10.0, 1.0, 0.0);
        flaky.absent_rate = 0.5;
        let mut away = 0;
        for round in 0..200 {
            let a = is_available(&flaky, 7, round, 3);
            assert_eq!(a, is_available(&flaky, 7, round, 3));
            if !a {
                away += 1;
            }
        }
        assert!((50..150).contains(&away), "absences {away}/200 at rate 0.5");
        flaky.absent_rate = 1.0;
        assert!(!is_available(&flaky, 7, 0, 0));
        flaky.absent_rate = 0.0;
        assert!(is_available(&flaky, 7, 0, 0));
    }

    #[test]
    fn json_join_round_and_absent_rate_parse_and_validate() {
        let sc = Scenario::load(
            r#"{"tiers": [
                 {"frac": 0.5, "mem": "backprop"},
                 {"frac": 0.5, "mem": "zo", "join_round": 12, "absent_rate": 0.2}
               ]}"#,
        )
        .unwrap();
        let Scenario::Custom(spec) = &sc else { panic!() };
        assert_eq!(spec.tiers[1].join_round, 12);
        assert_eq!(spec.tiers[1].absent_rate, 0.2);
        assert_eq!(spec.tiers[0].join_round, 0);
        // out-of-range absent_rate rejected
        assert!(Scenario::load(
            r#"{"tiers": [{"frac": 1.0, "mem": "zo", "absent_rate": 1.5}]}"#
        )
        .is_err());
        // join_round must be a non-negative integer — no silent flooring
        assert!(Scenario::load(
            r#"{"tiers": [{"frac": 1.0, "mem": "zo", "join_round": 8.9}]}"#
        )
        .is_err());
        assert!(Scenario::load(
            r#"{"tiers": [{"frac": 1.0, "mem": "zo", "join_round": -3}]}"#
        )
        .is_err());
    }

    #[test]
    fn json_spec_round_trips() {
        let text = r#"{
          "name": "two-tier",
          "deadline_ms": 5.5,
          "tiers": [
            {"name": "fast", "frac": 0.25, "mem": "backprop",
             "up_mbps": 40, "down_mbps": 80, "compute": 4.0},
            {"name": "slow", "frac": 0.75, "mem_bytes": 123456,
             "up_mbps": 1, "down_mbps": 2, "compute": 0.5, "drop_rate": 0.2}
          ]
        }"#;
        let sc = Scenario::load(text).unwrap();
        assert_eq!(sc.name(), "two-tier");
        assert_eq!(sc.deadline_ms(), 5.5);
        let Scenario::Custom(spec) = &sc else { panic!() };
        assert_eq!(spec.tiers.len(), 2);
        assert_eq!(spec.tiers[0].mem, MemBudget::FitsBackprop);
        assert_eq!(spec.tiers[1].mem, MemBudget::Bytes(123456));
        assert_eq!(spec.tiers[1].drop_rate, 0.2);
        // re-serialize through the Json tree (the apply_json path) and reload
        let j = Json::parse(text).unwrap();
        let sc2 = Scenario::load(&j.to_string()).unwrap();
        assert_eq!(sc, sc2);
    }

    #[test]
    fn bad_specs_rejected() {
        // fracs must sum to 1
        assert!(Scenario::load(
            r#"{"tiers": [{"frac": 0.5, "mem": "zo"}]}"#
        )
        .is_err());
        // bandwidth must be positive
        assert!(Scenario::load(
            r#"{"tiers": [{"frac": 1.0, "mem": "zo", "up_mbps": 0}]}"#
        )
        .is_err());
        // mem is required
        assert!(Scenario::load(r#"{"tiers": [{"frac": 1.0}]}"#).is_err());
        // tiers are required
        assert!(Scenario::load(r#"{"name": "x"}"#).is_err());
    }

    fn probe_zo_plan(n: usize, steps: usize, catch: u64) -> impl Fn(usize) -> RoundPlan {
        move |s| RoundPlan {
            down_bytes: catch + (s * steps * 8) as u64,
            passes: zo_passes(n, s),
            up_bytes: (s * steps * 4) as u64,
        }
    }

    #[test]
    fn plan_time_matches_simulated_survivor() {
        // the planner's deterministic timeline is exactly what
        // simulate_round reports for a survivor
        let p = profile(10.0, 10.0, 1.0, 0.0);
        let plan = RoundPlan {
            down_bytes: 1000,
            passes: 10.0,
            up_bytes: 500,
        };
        let mut trace = Xoshiro256::seed_from(0);
        let o = simulate_round(&p, &plan, 1_000_000, 0.0, &mut trace);
        assert!(o.survives);
        assert_eq!(o.sim_ms.to_bits(), plan_time_ms(&p, &plan, 1_000_000).to_bits());
        let (d, c, u) = leg_times_ms(&p, &plan, 1_000_000);
        assert!((d - 0.8).abs() < 1e-12);
        assert!((c - 1.0).abs() < 1e-12);
        assert!((u - 0.4).abs() < 1e-12);
    }

    #[test]
    fn planner_fills_the_budget_and_respects_bounds() {
        let p = profile(10.0, 10.0, 1.0, 0.0);
        let mk = probe_zo_plan(40, 1, 0);
        // unconstrained budget → ceiling
        assert_eq!(max_affordable_s(&p, 100_000, 0.0, 1, 32, &mk), 32);
        // a budget below even S=1 → floor (the client will likely drop)
        assert_eq!(max_affordable_s(&p, 100_000, 1e-9, 1, 32, &mk), 1);
        // a mid budget: the result S fits, S+1 does not
        let budget = plan_time_ms(&p, &mk(9), 100_000) + 1e-9;
        let s = max_affordable_s(&p, 100_000, budget, 1, 32, &mk);
        assert_eq!(s, 9);
        assert!(plan_time_ms(&p, &mk(s), 100_000) <= budget);
        assert!(plan_time_ms(&p, &mk(s + 1), 100_000) > budget);
        // a catch-up charge fronting the download shrinks the probe budget
        let with_catch = probe_zo_plan(40, 1, 4_000_000);
        assert!(max_affordable_s(&p, 100_000, budget, 1, 32, &with_catch) < s);
    }

    #[test]
    fn planner_gives_stronger_clients_more_probes() {
        // the tentpole's premise: under a shared budget, compute/bandwidth
        // translate directly into affordable probes
        let budget = 50.0;
        let mk = probe_zo_plan(64, 1, 0);
        let iot = max_affordable_s(&profile(1.0, 4.0, 0.25, 0.0), 175_258, budget, 1, 64, &mk);
        let phone = max_affordable_s(&profile(5.0, 20.0, 1.0, 0.0), 175_258, budget, 1, 64, &mk);
        let server = max_affordable_s(&profile(50.0, 100.0, 8.0, 0.0), 175_258, budget, 1, 64, &mk);
        assert!(iot < phone && phone < server, "{iot} < {phone} < {server}");
    }

    #[test]
    fn prop_planner_is_monotone_and_exact() {
        // random profiles/budgets: the planner stays in bounds, is
        // monotone in the budget, and sits exactly on the frontier
        // (S fits; S+1 does not, unless capped)
        crate::util::prop::run_prop("adaptive_s_planner", 200, |g| {
            let mut rng = g.rng();
            let p = CapabilityProfile {
                tier: "rand".into(),
                mem_bytes: u64::MAX,
                up_mbps: 0.01 + rng.next_f64() * 50.0,
                down_mbps: 0.01 + rng.next_f64() * 50.0,
                compute: 0.05 + rng.next_f64() * 8.0,
                drop_rate: 0.0,
                join_round: 0,
                absent_rate: 0.0,
            };
            let n = 1 + rng.below(200);
            let steps = 1 + rng.below(3);
            let catch = (rng.below(1 << 18)) as u64;
            let s_min = 1 + rng.below(4);
            let s_max = s_min + rng.below(40);
            let params = 1_000 + rng.below(1_000_000) as u64;
            let mk = probe_zo_plan(n, steps, catch);
            let b1 = rng.next_f64() * 20.0;
            let b2 = b1 + rng.next_f64() * 20.0;
            let s1 = max_affordable_s(&p, params, b1, s_min, s_max, &mk);
            let s2 = max_affordable_s(&p, params, b2, s_min, s_max, &mk);
            if !(s_min..=s_max).contains(&s1) || !(s_min..=s_max).contains(&s2) {
                return Err(format!("out of bounds: {s1}/{s2} not in [{s_min},{s_max}]"));
            }
            if s2 < s1 {
                return Err(format!("not monotone in budget: {s1} -> {s2}"));
            }
            // frontier exactness whenever the floor fits and the cap is slack
            if plan_time_ms(&p, &mk(s_min), params) <= b1 && s1 < s_max {
                if plan_time_ms(&p, &mk(s1), params) > b1 {
                    return Err(format!("S={s1} does not fit its own budget"));
                }
                if plan_time_ms(&p, &mk(s1 + 1), params) <= b1 {
                    return Err(format!("S={s1} is not maximal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_timeline_is_monotone_and_survivor_exact() {
        // satellite: the timeline model the async event queue trusts —
        // plan_time_ms/leg_times_ms are monotone nondecreasing in probe
        // count S, payload bytes, and catch-up charge, and a survivor's
        // sim_ms is bit-exactly the leg sum
        crate::util::prop::run_prop("timeline_monotone", 300, |g| {
            let mut rng = g.rng();
            let p = CapabilityProfile {
                tier: "rand".into(),
                mem_bytes: u64::MAX,
                up_mbps: 0.01 + rng.next_f64() * 50.0,
                down_mbps: 0.01 + rng.next_f64() * 50.0,
                compute: 0.05 + rng.next_f64() * 8.0,
                drop_rate: rng.next_f64() * 0.5,
                join_round: 0,
                absent_rate: 0.0,
            };
            let params = 1_000 + rng.below(1_000_000) as u64;
            let n = 1 + rng.below(200);
            let steps = 1 + rng.below(3);
            let catch = rng.below(1 << 18) as u64;
            let mk = probe_zo_plan(n, steps, catch);
            let s = 1 + rng.below(48);
            let ds = 1 + rng.below(16);
            // monotone in S
            if plan_time_ms(&p, &mk(s + ds), params) < plan_time_ms(&p, &mk(s), params) {
                return Err(format!("not monotone in S at S={s}+{ds}"));
            }
            // monotone in payload bytes, leg by leg
            let base = mk(s);
            let extra = 1 + rng.below(1 << 20) as u64;
            let mut fat = base;
            fat.down_bytes += extra;
            fat.up_bytes += extra;
            let (d0, c0, u0) = leg_times_ms(&p, &base, params);
            let (d1, c1, u1) = leg_times_ms(&p, &fat, params);
            if d1 < d0 || u1 < u0 || c1 != c0 {
                return Err(format!("payload bytes shrank a leg: {d0}->{d1}, {u0}->{u1}"));
            }
            // monotone in the catch-up charge (it fronts the download)
            let heavier = probe_zo_plan(n, steps, catch + extra);
            if plan_time_ms(&p, &heavier(s), params) < plan_time_ms(&p, &base, params) {
                return Err("catch-up charge shortened the timeline".into());
            }
            // a survivor's sim_ms is exactly the deterministic leg sum
            let deadline = if rng.next_f64() < 0.5 { 0.0 } else { rng.next_f64() * 50.0 };
            let mut trace = Xoshiro256::seed_from(rng.next_u64());
            let o = simulate_round(&p, &base, params, deadline, &mut trace);
            if o.survives && o.sim_ms.to_bits() != plan_time_ms(&p, &base, params).to_bits() {
                return Err(format!(
                    "survivor sim_ms {} != planned {}",
                    o.sim_ms,
                    plan_time_ms(&p, &base, params)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn arrival_delays_are_deterministic_and_rate_scaled() {
        // rate 0 = staggered-immediate: exactly zero, no stream consumed
        assert_eq!(arrival_delay_ms(7, 3, 5, 0.0), 0.0);
        // pure function of (seed, seq, cid, rate)
        let a = arrival_delay_ms(7, 3, 5, 0.5);
        assert_eq!(a, arrival_delay_ms(7, 3, 5, 0.5));
        assert!(a >= 0.0 && a.is_finite());
        // distinct dispatches draw distinct delays (fresh streams)
        assert_ne!(a, arrival_delay_ms(7, 4, 5, 0.5));
        // the empirical mean tracks 1/rate (Exp inverse-CDF sanity)
        for rate in [0.1, 1.0, 4.0] {
            let n = 4000;
            let mean: f64 = (0..n)
                .map(|seq| arrival_delay_ms(42, seq, 1, rate))
                .sum::<f64>()
                / n as f64;
            assert!(
                (mean * rate - 1.0).abs() < 0.1,
                "mean {mean} at rate {rate} far from 1/rate"
            );
        }
    }

    #[test]
    fn fleet_preset_has_a_thin_fo_backbone_over_a_zo_edge() {
        let s = Scenario::preset("fleet").unwrap();
        s.validate().unwrap();
        let cost = probe_cost();
        let fo = s.fo_tier_frac(0, 0, &cost);
        assert!(fo > 0.0 && fo < 0.1, "thin FO backbone, got {fo}");
        // every tier can at least run ZO
        let Scenario::Custom(spec) = &s else { panic!() };
        for t in &spec.tiers {
            assert!(t.mem.resolve(&cost) >= cost.zo_mem_bytes(), "tier {}", t.name);
        }
        // binary's fo share reproduces the hi split as a probability
        assert_eq!(Scenario::Binary.fo_tier_frac(20, 6, &cost), 0.3);
    }

    #[test]
    fn profile_of_is_pure_and_scales_to_fleet_ids() {
        // the lazy layer's contract: profile_of is a pure function of
        // (scenario, seed, cid) — same inputs, same profile, evaluation
        // order irrelevant, and a 10^7-space id costs O(1)
        let cost = probe_cost();
        let s = Scenario::preset("fleet").unwrap();
        let a = s.profile_of(10_000_000, 0, 7, 9_876_543, &cost);
        let b = s.profile_of(10_000_000, 0, 7, 9_876_543, &cost);
        assert_eq!(a, b);
        let c = s.profile_of(10_000_000, 0, 8, 9_876_543, &cost);
        let d = s.profile_of(10_000_000, 0, 7, 9_876_544, &cost);
        // different seed or id *may* land in the same tier; over a spread
        // of ids the mix must be heterogeneous
        let _ = (c, d);
        let mut tiers = std::collections::BTreeSet::new();
        for cid in 0..500 {
            tiers.insert(s.profile_of(10_000_000, 0, 7, cid, &cost).tier);
        }
        assert!(tiers.len() >= 2, "one draw swallowed the fleet: {tiers:?}");
    }

    #[test]
    fn prop_profile_of_matches_lazy_materialization() {
        // satellite: lazy profile_of matches the materialized lazy vector
        // element-wise across random scenarios, seeds, and probe orders
        crate::util::prop::run_prop("lazy_profile_equivalence", 60, |g| {
            let mut rng = g.rng();
            let cost = CostModel::generic(1_000 + rng.below(1 << 20) as u64, 32);
            let scenario = if rng.below(4) == 0 {
                Scenario::Binary
            } else {
                // random custom scenario: 1..5 tiers, normalized fracs
                let n_tiers = 1 + rng.below(4);
                let raw: Vec<f64> = (0..n_tiers).map(|_| 0.05 + rng.next_f64()).collect();
                let z: f64 = raw.iter().sum();
                let tiers: Vec<DeviceTier> = raw
                    .iter()
                    .enumerate()
                    .map(|(i, f)| {
                        let mem = if rng.below(2) == 0 {
                            MemBudget::FitsBackprop
                        } else {
                            MemBudget::FitsZoOnly
                        };
                        let mut t = DeviceTier::new(&format!("t{i}"), f / z, mem)
                            .net(0.5 + rng.next_f64() * 50.0, 0.5 + rng.next_f64() * 50.0);
                        t.compute = 0.1 + rng.next_f64() * 8.0;
                        t.drop_rate = rng.next_f64() * 0.5;
                        t
                    })
                    .collect();
                let spec = ScenarioSpec {
                    name: "rand".into(),
                    tiers,
                    deadline_ms: 0.0,
                    edges: Vec::new(),
                };
                let sc = Scenario::Custom(spec);
                sc.validate().map_err(|e| e.to_string())?;
                sc
            };
            let k = 1 + rng.below(g.size.max(1) * 2);
            let hi = rng.below(k + 1);
            let seed = rng.next_u64();
            let materialized = scenario.sample_profiles_lazy(k, hi, seed, &cost);
            if materialized.len() != k {
                return Err(format!("{} profiles for k={k}", materialized.len()));
            }
            // independently-coded reference of the documented draw (NOT
            // a call back into profile_of): hash the id, seed the
            // PROFILE_SALT stream, walk the cumulative fractions
            let reference = |cid: usize| -> CapabilityProfile {
                let mut h = crate::util::rng::SplitMix64(cid as u64);
                let u = Xoshiro256::seed_from(seed ^ PROFILE_SALT ^ h.next_u64()).next_f64();
                let (tiers, probs): (Vec<DeviceTier>, Vec<f64>) = match &scenario {
                    Scenario::Binary => {
                        let p = hi.min(k) as f64 / k as f64;
                        (binary_tiers(), vec![p, 1.0 - p])
                    }
                    Scenario::Custom(s) => (
                        s.tiers.clone(),
                        s.tiers.iter().map(|t| t.frac).collect(),
                    ),
                };
                let mut acc = 0.0;
                let mut pick = tiers.len() - 1;
                for (i, p) in probs.iter().enumerate() {
                    acc += p;
                    if u < acc {
                        pick = i;
                        break;
                    }
                }
                CapabilityProfile::from_tier(&tiers[pick], &cost)
            };
            // probe a random subset in random order: element-wise equal
            // to both the materialized vector and the reference draw
            for _ in 0..8.min(k) {
                let cid = rng.below(k);
                let lazy = scenario.profile_of(k, hi, seed, cid, &cost);
                if lazy != materialized[cid] {
                    return Err(format!(
                        "profile_of({cid}) != materialized[{cid}]: {lazy:?} vs {:?}",
                        materialized[cid]
                    ));
                }
                let want = reference(cid);
                if lazy != want {
                    return Err(format!(
                        "profile_of({cid}) diverged from the documented draw: \
                         {lazy:?} vs {want:?}"
                    ));
                }
            }
            // tier identity is a real tier of the scenario
            let names: Vec<String> = match &scenario {
                Scenario::Binary => vec!["high".into(), "low".into()],
                Scenario::Custom(s) => s.tiers.iter().map(|t| t.name.clone()).collect(),
            };
            for p in &materialized {
                if !names.contains(&p.tier) {
                    return Err(format!("unknown tier {:?}", p.tier));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mem_budget_resolution_orders_thresholds() {
        let cost = probe_cost();
        let hi = MemBudget::FitsBackprop.resolve(&cost);
        let lo = MemBudget::FitsZoOnly.resolve(&cost);
        assert!(hi > lo, "{hi} vs {lo}");
        assert!(hi >= cost.fo_threshold_bytes());
        assert!(lo >= cost.zo_mem_bytes());
        assert!(lo < cost.fo_threshold_bytes());
        assert_eq!(MemBudget::Bytes(7).resolve(&cost), 7);
    }
}
