//! Server-side checkpointing and seed-log compaction — bounded catch-up
//! for late joiners and rejoining dropouts (DESIGN.md §7).
//!
//! The seed protocol's negligible downlink has a flip side inherited from
//! FedKSeed: a client that misses rounds (dropped mid-round, sampled out,
//! flaky availability, or joined late) can only reconstruct the current
//! global model by replaying the *entire* seed history since its last
//! sync. The [`CheckpointStore`] bounds that cost: the server
//! periodically materializes a parameter **snapshot** (every
//! `FedConfig::ckpt_every` seed-replayable rounds, CLI `--ckpt-every`)
//! and truncates the live seed log to the **tail** since the snapshot. A
//! stale client then reconstructs bit-identical state from whichever is
//! cheaper on the wire:
//!
//! * **tail replay** — download the (seed, ΔL) pairs of the rounds it
//!   missed ([`BYTES_PER_REPLAY_ITEM`] each) and replay them locally, or
//! * **snapshot + tail** — download the full snapshot (`4·d` bytes, the
//!   eq. 4/5 weight-transfer cost) plus the post-snapshot tail.
//!
//! [`CheckpointStore::catch_up_bytes`] charges `min` of the available
//! paths; [`CheckpointStore::reconstruct`] performs the replay through the
//! same sharded fused pass the live server uses
//! ([`crate::model::params::perturb_axpy_many_sharded`]), so the rebuilt
//! parameters are **bit-identical to never having left** — for every
//! worker count (enforced by
//! `tests/integration_scenarios.rs::rejoin_after_drop_reconstructs_bit_identical_to_continuous`).
//!
//! ## Round taxonomy
//!
//! A round is **seed-replayable** when its entire effect on the global
//! weights is the fused (seed, coeff) pass — every pure ZO round,
//! including empty (all-drop) rounds whose item list is empty. A round is
//! **opaque** when the update involves full weight vectors (warm-phase
//! FedAvg steps, mixed-§A.4 FO folds): no seed list can replay it, so the
//! store snapshots right after it and restarts the tail. During the warm
//! phase this is free in protocol terms — warm participants download full
//! weights every round anyway.
//!
//! With `ckpt_every == 0` (the default) the subsystem is disabled and
//! byte-inert: no snapshots, no log, `catch_up_bytes` is 0 — the seed
//! repo's implicit free-rejoin accounting, preserved so default configs
//! reproduce the existing golden trace unchanged.
//!
//! ## Edge-local caches (two-tier topology)
//!
//! Under the two-tier topology (DESIGN.md §13, `--edges E`) every edge
//! aggregator mirrors the root's snapshot + tail: the root broadcasts
//! each snapshot and each round's fused items to its E edges, so a stale
//! client's catch-up downlink is served from **its own edge's cache** and
//! charged at the edge link's rate. The store itself stays singular —
//! the mirrors are byte-identical replicas, so the simulation keeps one
//! `CheckpointStore` and the per-edge attribution lives entirely in
//! [`crate::comm::CommLedger::record_edge_catch_up`].
//! [`CheckpointStore::tail_log`] exposes the live tail so the
//! cross-mode equivalence harness (`tests/integration_matrix.rs`) can
//! assert the two-tier fold leaves the seed log bit-identical to flat.

use crate::config::KernelKind;
use crate::model::params::{perturb_axpy_many_sharded_kernel, ParamVec};
use crate::util::rng::Distribution;

/// Wire bytes per replayed (seed, ΔL) pair — 8-byte seed + 4-byte f32,
/// matching the round-end broadcast accounting in
/// [`crate::zo::zo_round_ledger_outcomes`].
pub const BYTES_PER_REPLAY_ITEM: u64 = 12;

/// One seed-replayable round's log entry: the order-canonical fused
/// (seed, coeff) items exactly as the server applied them
/// ([`crate::zo::zo_update_items`]).
#[derive(Debug, Clone)]
pub struct SeedRoundLog {
    /// the federated round this entry replays
    pub round: usize,
    /// the fused items, in server application order
    pub items: Vec<(u64, f32)>,
}

/// A materialized parameter snapshot: `params` is the global state
/// *entering* round `at` (i.e. after rounds `0..at`).
#[derive(Debug, Clone)]
struct Snapshot {
    at: usize,
    params: ParamVec,
}

/// How a stale client catches up, and what it costs on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CatchUpPlan {
    /// true: download the snapshot then replay the post-snapshot tail;
    /// false: replay the tail from the client's own synced state
    pub via_snapshot: bool,
    /// seed-replayable rounds the client replays locally
    pub replay_rounds: usize,
    /// fused (seed, coeff) items replayed locally — the client-side
    /// compute of the catch-up (one O(d) weight pass per item), charged
    /// as simulated passes by the round engine (`sim::replay_passes`)
    pub replay_items: usize,
    /// downlink bytes charged (the `min` over available paths)
    pub bytes: u64,
}

/// Server-side checkpoint + compacted seed log (see module docs).
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    /// snapshot cadence in seed-replayable rounds; 0 = disabled
    every: usize,
    /// `None` iff disabled; otherwise invariant `snapshot.at + tail.len()
    /// == rounds recorded so far` (the tail is contiguous)
    snapshot: Option<Snapshot>,
    tail: Vec<SeedRoundLog>,
    /// snapshots materialized over the run (the initial state counts)
    pub snapshots_taken: usize,
    /// log items discarded by compaction over the run
    pub compacted_items: u64,
    /// longest tail observed (worst-case catch-up replay length)
    pub max_tail_rounds: usize,
}

impl CheckpointStore {
    /// `every` = snapshot cadence (0 disables the subsystem entirely);
    /// `init` = the global parameters entering round 0.
    pub fn new(every: usize, init: &ParamVec) -> Self {
        let snapshot = (every > 0).then(|| Snapshot {
            at: 0,
            params: init.clone(),
        });
        Self {
            every,
            snapshots_taken: snapshot.is_some() as usize,
            snapshot,
            tail: Vec::new(),
            compacted_items: 0,
            max_tail_rounds: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.snapshot.is_some()
    }

    /// Earliest round reconstructable from the current snapshot.
    pub fn base_round(&self) -> usize {
        self.snapshot.as_ref().map_or(0, |s| s.at)
    }

    /// Seed-replayable rounds currently in the live log.
    pub fn tail_rounds(&self) -> usize {
        self.tail.len()
    }

    /// The live (post-snapshot) seed log, in round order — the exact
    /// fused items the server applied. The equivalence harness diffs
    /// this across topologies: a two-tier fold that is bit-identical to
    /// the flat fold must leave an identical tail.
    pub fn tail_log(&self) -> &[SeedRoundLog] {
        &self.tail
    }

    fn take_snapshot(&mut self, at: usize, global: &ParamVec) {
        self.compacted_items += self
            .tail
            .iter()
            .map(|e| e.items.len() as u64)
            .sum::<u64>();
        self.tail.clear();
        self.snapshot = Some(Snapshot {
            at,
            params: global.clone(),
        });
        self.snapshots_taken += 1;
    }

    /// Record a round whose update cannot be replayed from seeds (warm
    /// FedAvg step, mixed-§A.4 FO fold): snapshot right after it so
    /// catch-up never has to cross it. `global` is the state *after* the
    /// round.
    pub fn record_opaque(&mut self, round: usize, global: &ParamVec) {
        if !self.enabled() {
            return;
        }
        assert_eq!(self.base_round() + self.tail.len(), round, "rounds must be recorded in order");
        self.take_snapshot(round + 1, global);
    }

    /// Record a seed-replayable round: append its fused items to the tail
    /// and, at the `ckpt_every` cadence, materialize a snapshot and
    /// compact. `global` is the state *after* the round.
    pub fn record_seed_round(&mut self, round: usize, items: Vec<(u64, f32)>, global: &ParamVec) {
        if !self.enabled() {
            return;
        }
        // hard log invariant: an out-of-order record would replay a
        // permuted tail bit-differently in release (DESIGN.md §14)
        assert_eq!(self.base_round() + self.tail.len(), round, "rounds must be recorded in order");
        self.tail.push(SeedRoundLog { round, items });
        self.max_tail_rounds = self.max_tail_rounds.max(self.tail.len());
        if self.tail.len() >= self.every {
            self.take_snapshot(round + 1, global);
        }
    }

    /// Replay cost (wire bytes, item count) for the tail rounds
    /// `[from, to)` (indices are round numbers); `None` if the span is
    /// reversed or not fully inside the live tail.
    fn tail_span(&self, from: usize, to: usize) -> Option<(u64, usize)> {
        let base = self.base_round();
        if to < from || from < base || to > base + self.tail.len() {
            return None;
        }
        let items: usize = self.tail[from - base..to - base]
            .iter()
            .map(|e| e.items.len())
            .sum();
        Some((items as u64 * BYTES_PER_REPLAY_ITEM, items))
    }

    /// The cheapest way to take a client holding the state entering round
    /// `known` to the state entering round `target` (`dim_bytes` = 4·d,
    /// the snapshot transfer size). `None` when no catch-up is needed or
    /// the store is disabled.
    pub fn catch_up_plan(&self, known: usize, target: usize, dim_bytes: u64) -> Option<CatchUpPlan> {
        let snap = self.snapshot.as_ref()?;
        if known >= target {
            return None;
        }
        assert!(
            target <= snap.at + self.tail.len(),
            "target {target} beyond recorded history {}",
            snap.at + self.tail.len()
        );
        // a target sealed behind the snapshot (target < snap.at) is
        // served by the snapshot alone: the client lands at base_round,
        // at or past the state it asked for, with nothing to replay
        let (snap_tail_bytes, snap_tail_items) =
            self.tail_span(snap.at, target.max(snap.at)).unwrap_or((0, 0));
        let snapshot_plan = CatchUpPlan {
            via_snapshot: true,
            replay_rounds: target.saturating_sub(snap.at),
            replay_items: snap_tail_items,
            bytes: dim_bytes + snap_tail_bytes,
        };
        match self.tail_span(known, target) {
            Some((tail_bytes, tail_items)) if tail_bytes <= snapshot_plan.bytes => {
                Some(CatchUpPlan {
                    via_snapshot: false,
                    replay_rounds: target - known,
                    replay_items: tail_items,
                    bytes: tail_bytes,
                })
            }
            _ => Some(snapshot_plan),
        }
    }

    /// Catch-up downlink charge: `min(snapshot_bytes, tail_seed_bytes)`
    /// over the available paths; 0 when already synced or disabled.
    pub fn catch_up_bytes(&self, known: usize, target: usize, dim_bytes: u64) -> u64 {
        self.catch_up_plan(known, target, dim_bytes)
            .map_or(0, |p| p.bytes)
    }

    /// Rebuild the global parameters entering round `target` from the
    /// snapshot plus tail replay, through the identical sharded fused
    /// pass the live server applies — bit-identical to continuous
    /// participation for every `workers` count. `kernel` must be the
    /// run's `ZoConfig::kernel`: the seed log only replays to the live
    /// state through the same perturbation stream the live fold used.
    pub fn reconstruct(
        &self,
        target: usize,
        tau: f32,
        dist: Distribution,
        workers: usize,
        kernel: KernelKind,
    ) -> anyhow::Result<ParamVec> {
        let snap = self
            .snapshot
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("checkpointing disabled (ckpt_every = 0)"))?;
        anyhow::ensure!(
            target >= snap.at && target <= snap.at + self.tail.len(),
            "round {target} outside reconstructable span [{}, {}]",
            snap.at,
            snap.at + self.tail.len()
        );
        let mut p = snap.params.clone();
        for e in &self.tail[..target - snap.at] {
            perturb_axpy_many_sharded_kernel(&mut p.0, &e.items, tau, dist, workers, kernel);
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    const TAU: f32 = 0.75;
    const DIST: Distribution = Distribution::Rademacher;
    const KERNEL: KernelKind = KernelKind::Scalar;

    fn items(rng: &mut Xoshiro256, n: usize) -> Vec<(u64, f32)> {
        (0..n)
            .map(|_| (rng.next_u64(), (rng.next_f32() - 0.5) * 1e-2))
            .collect()
    }

    /// Reference: straight-line replay of every round from init — what a
    /// client that never left (and never compacted) would hold.
    fn replay_all(init: &ParamVec, rounds: &[Vec<(u64, f32)>], upto: usize) -> ParamVec {
        let mut p = init.clone();
        for r in &rounds[..upto] {
            perturb_axpy_many_sharded_kernel(&mut p.0, r, TAU, DIST, 1, KERNEL);
        }
        p
    }

    #[test]
    fn disabled_store_is_inert() {
        let init = ParamVec::zeros(64);
        let mut s = CheckpointStore::new(0, &init);
        assert!(!s.enabled());
        s.record_seed_round(0, vec![(1, 0.5)], &init);
        s.record_opaque(1, &init);
        assert_eq!(s.catch_up_bytes(0, 5, 1024), 0);
        assert_eq!(s.tail_rounds(), 0);
        assert!(s.reconstruct(0, TAU, DIST, 1, KERNEL).is_err());
    }

    #[test]
    fn reconstruct_matches_straight_replay_across_compaction() {
        let mut rng = Xoshiro256::seed_from(9);
        let init = ParamVec(vec![0.25f32; 300]);
        let mut store = CheckpointStore::new(3, &init);
        let mut live = init.clone();
        let mut all_rounds: Vec<Vec<(u64, f32)>> = Vec::new();
        for round in 0..8 {
            let it = items(&mut rng, 1 + round % 4);
            perturb_axpy_many_sharded_kernel(&mut live.0, &it, TAU, DIST, 1, KERNEL);
            all_rounds.push(it.clone());
            store.record_seed_round(round, it, &live);
            // every reconstructable prefix equals the never-left replay
            for target in store.base_round()..=store.base_round() + store.tail_rounds() {
                let rec = store.reconstruct(target, TAU, DIST, 1, KERNEL).unwrap();
                assert_eq!(rec, replay_all(&init, &all_rounds, target), "target {target}");
            }
        }
        // cadence 3 over 8 rounds: snapshots after rounds 2 and 5 (+ init)
        assert_eq!(store.snapshots_taken, 3);
        assert_eq!(store.base_round(), 6);
        assert_eq!(store.tail_rounds(), 2);
        assert!(store.compacted_items > 0);
    }

    #[test]
    fn opaque_rounds_snapshot_and_restart_the_tail() {
        let init = ParamVec(vec![0.0f32; 128]);
        let mut store = CheckpointStore::new(10, &init);
        let mut rng = Xoshiro256::seed_from(4);
        let mut live = init.clone();
        let it = items(&mut rng, 3);
        perturb_axpy_many_sharded_kernel(&mut live.0, &it, TAU, DIST, 1, KERNEL);
        store.record_seed_round(0, it, &live);
        // an opaque (warm/mixed) round: pretend a full-weight fold happened
        live.0[7] += 1.0;
        store.record_opaque(1, &live);
        assert_eq!(store.base_round(), 2);
        assert_eq!(store.tail_rounds(), 0);
        // catch-up from before the opaque round can only use the snapshot
        let plan = store.catch_up_plan(0, 2, 512).unwrap();
        assert!(plan.via_snapshot);
        assert_eq!(plan.bytes, 512);
        // a target sealed behind the snapshot (0 -> 1 < base 2) must not
        // panic: the snapshot alone serves it (client lands at base)
        let sealed = store.catch_up_plan(0, 1, 512).unwrap();
        assert!(sealed.via_snapshot);
        assert_eq!(sealed.bytes, 512);
        assert_eq!(sealed.replay_rounds, 0);
        assert_eq!(sealed.replay_items, 0);
        // and reconstruct at the new base is exactly the live state
        assert_eq!(store.reconstruct(2, TAU, DIST, 1, KERNEL).unwrap(), live);
        assert!(store.reconstruct(1, TAU, DIST, 1, KERNEL).is_err());
    }

    #[test]
    fn catch_up_picks_the_cheaper_path() {
        let init = ParamVec::zeros(64);
        // cadence 3: snapshot after round 2 (at = 3), tail = rounds 3..5
        let mut store = CheckpointStore::new(3, &init);
        let mut rng = Xoshiro256::seed_from(1);
        let mut live = init.clone();
        for round in 0..6 {
            let it = items(&mut rng, 5); // 5 items = 60 B per round
            perturb_axpy_many_sharded_kernel(&mut live.0, &it, TAU, DIST, 1, KERNEL);
            store.record_seed_round(round, it, &live);
        }
        assert_eq!(store.base_round(), 3);
        assert_eq!(store.tail_rounds(), 3);
        // a nearly-synced client replays the short tail span
        let near = store.catch_up_plan(5, 6, 10_000).unwrap();
        assert!(!near.via_snapshot);
        assert_eq!(near.bytes, 60);
        assert_eq!(near.replay_rounds, 1);
        // a client stale since before the snapshot cannot use the tail —
        // its missed rounds were compacted away — so it takes the
        // snapshot plus the post-snapshot tail
        let cold = store.catch_up_plan(0, 6, 100).unwrap();
        assert!(cold.via_snapshot);
        assert_eq!(cold.bytes, 100 + 3 * 60);
        assert_eq!(cold.replay_rounds, 3);
        // within tail coverage pure tail replay always wins — the
        // snapshot path would ship the same span *plus* the snapshot
        let tailful = store.catch_up_plan(3, 6, 10_000).unwrap();
        assert!(!tailful.via_snapshot);
        assert_eq!(tailful.bytes, 3 * 60);
        let snappy = store.catch_up_plan(3, 6, 10).unwrap();
        assert!(!snappy.via_snapshot);
        assert_eq!(snappy.bytes, 3 * 60);
        // synced clients pay nothing
        assert_eq!(store.catch_up_bytes(6, 6, 10_000), 0);
    }

    #[test]
    fn prop_catch_up_and_reconstruct_invariants() {
        // random interleavings of seed/opaque rounds and cadences:
        // (1) reconstruct == straight-line replay at every reconstructable
        //     target (with opaque rounds modeled as arbitrary mutations);
        // (2) catch_up_bytes is 0 iff synced, monotone non-increasing in
        //     `known`, and never exceeds the pure snapshot path;
        // (3) the tail stays bounded by the cadence.
        crate::util::prop::run_prop("ckpt_catch_up", 60, |g| {
            let mut rng = g.rng();
            let dim = 64 + rng.below(g.size.max(1) * 4);
            let every = 1 + rng.below(5);
            let rounds = 1 + rng.below(g.size.max(2).min(14));
            let dim_bytes = (dim * 4) as u64;
            let init = ParamVec(vec![0.1f32; dim]);
            let mut store = CheckpointStore::new(every, &init);
            let mut live = init.clone();
            // live history of *states entering* each round
            let mut entering: Vec<ParamVec> = vec![init.clone()];
            for round in 0..rounds {
                if rng.below(4) == 0 {
                    // opaque round: arbitrary full-weight mutation
                    let k = rng.below(dim);
                    live.0[k] += rng.next_f32() - 0.5;
                    store.record_opaque(round, &live);
                } else {
                    // 0-item rounds model the all-drop identity rounds
                    // the live server logs
                    let n_items = rng.below(6);
                    let it = items(&mut rng, n_items);
                    perturb_axpy_many_sharded_kernel(&mut live.0, &it, 0.75, DIST, 1, KERNEL);
                    store.record_seed_round(round, it, &live);
                }
                entering.push(live.clone());
            }
            if store.tail_rounds() >= every {
                return Err(format!("tail {} >= cadence {every}", store.tail_rounds()));
            }
            let base = store.base_round();
            let top = base + store.tail_rounds();
            for target in base..=top {
                let rec = store
                    .reconstruct(target, 0.75, DIST, 1, KERNEL)
                    .map_err(|e| e.to_string())?;
                if rec != entering[target] {
                    return Err(format!("reconstruct({target}) != live state"));
                }
                let snap_only = dim_bytes
                    + store.tail_span(base, target).map_or(0, |t| t.0);
                let mut prev = u64::MAX;
                for known in 0..=target {
                    let b = store.catch_up_bytes(known, target, dim_bytes);
                    // free catch-up is legitimate exactly when synced, or
                    // when the missed span is inside the tail and carries
                    // zero items (all-drop identity rounds)
                    let free_ok = known >= target
                        || (known >= base
                            && store.tail_span(known, target).map_or(false, |t| t.0 == 0));
                    if (b == 0) != free_ok {
                        return Err(format!(
                            "charge {b} inconsistent at known={known}->{target} \
                             (free_ok {free_ok})"
                        ));
                    }
                    if b > snap_only {
                        return Err(format!("{b} exceeds snapshot path {snap_only}"));
                    }
                    if b > prev {
                        return Err(format!(
                            "catch-up not monotone at known={known}: {b} > {prev}"
                        ));
                    }
                    prev = b;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn reconstruct_is_worker_invariant() {
        let mut rng = Xoshiro256::seed_from(11);
        // above the sharding threshold so workers actually shard
        let dim = 1 << 15;
        let init = ParamVec(vec![0.5f32; dim]);
        let mut store = CheckpointStore::new(8, &init);
        let mut live = init.clone();
        for round in 0..5 {
            let it = items(&mut rng, 4);
            perturb_axpy_many_sharded_kernel(&mut live.0, &it, TAU, DIST, 1, KERNEL);
            store.record_seed_round(round, it, &live);
        }
        let w1 = store.reconstruct(5, TAU, DIST, 1, KERNEL).unwrap();
        for workers in [2usize, 4, 8] {
            assert_eq!(
                store.reconstruct(5, TAU, DIST, workers, KERNEL).unwrap(),
                w1,
                "workers={workers}"
            );
        }
        assert_eq!(w1, live);
    }
}
