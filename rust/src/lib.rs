//! # ZOWarmUp — zeroth-order federated pre-training with low-resource clients
//!
//! Rust + JAX + Pallas reproduction of *"Warming Up for Zeroth-Order
//! Federated Pre-Training with Low Resource Clients"* (Legate, Rish,
//! Belilovsky, 2025). See DESIGN.md for the architecture and the
//! per-experiment index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — PJRT client executing AOT HLO-text artifacts (L2/L1
//!   compiled from `python/compile/`).
//! * [`fed`] — the coordinator: Algorithm 1's two-phase loop, FedAvg /
//!   FedAdam aggregation, the seed-based SPSA protocol, and the
//!   population layer (`fed::population`): materialized (seed-era) or
//!   lazy (fleet-scale, O(sampled) rounds over 10^7-client populations,
//!   sparse per-client ledgers).
//! * [`zo`] — SPSA estimation, seed bookkeeping, and the fused
//!   (seed, coeff) ZOUPDATE artifact with explicit per-client block maps
//!   and variance-guarded aggregation (DESIGN.md §9).
//! * [`baselines`] — HeteroFL, FedKSeed, High-Res-Only comparators.
//! * [`ckpt`] — server-side checkpointing + seed-log compaction: bounded
//!   catch-up replay for late joiners and rejoining dropouts
//!   (`--ckpt-every`; DESIGN.md §7).
//! * [`data`] — procedural datasets + Dirichlet partitioner.
//! * [`comm`] — measured byte accounting + the eq. 4/5 analytic cost model.
//! * [`sim`] — the device-capability scenario engine: per-client
//!   memory/bandwidth/compute profiles sampled from the federation seed,
//!   deterministic availability/straggler traces, round deadline
//!   simulation with byte-accurate partial-transmission accounting, and
//!   the adaptive probe-budget planner (`max_affordable_s`) that inverts
//!   the timeline model to size each client's per-round S_j.
//! * [`exp`] — runners that regenerate every paper table and figure.
//! * [`util`] — offline substrates (RNG, JSON, CLI, bench, property
//!   tests). [`util::rng::salts`] is the central stream-salt registry;
//!   `rust/detlint` statically enforces that no salt constant lives
//!   anywhere else (DESIGN.md §14).
//!
//! ## Capability scenarios
//!
//! Fleets are described by [`sim::Scenario`]s — named presets
//! (`binary`, `uniform-high`, `edge-spectrum`, `stragglers`, `flaky`,
//! `churn`, `fleet`) or JSON specs (`train --scenario <name|file>`; schema in
//! README.md and `rust/src/exp/README.md`). Each client draws a
//! [`sim::CapabilityProfile`] reproducibly from the master seed; the
//! eq. 4/5 cost model decides FO-vs-ZO eligibility (replacing the old
//! hardcoded binary flag — `fed::server::assign_resources` survives as a
//! bit-compatible shim), and rounds gain deadline semantics: clients
//! whose simulated wall-time exceeds the deadline drop out mid-round,
//! the server folds only surviving contributions, and the ledger charges
//! only bytes actually transmitted before the drop. The default
//! scenario reproduces the seed repo's behavior bit for bit.
//!
//! ## Checkpointing & late joiners
//!
//! Scenarios can also model **churn**: tiers may join the federation late
//! (`join_round`) or sit out whole rounds (`absent_rate`, drawn from a
//! deterministic per-(round, client) trace). A client that missed rounds
//! is *stale* — it never received the (seed, ΔL) broadcasts — and must
//! catch up before it can evaluate seeds against the current model. With
//! `FedConfig::ckpt_every > 0` the server materializes periodic parameter
//! snapshots, compacts the seed log to the tail, and charges each stale
//! client the cheaper of `snapshot + tail` vs pure tail replay
//! ([`ckpt::CheckpointStore`]); reconstruction replays the tail through
//! the same sharded fused pass as the live server, so a rejoiner's state
//! is bit-identical to continuous participation. With `ckpt_every == 0`
//! (default) the accounting is byte-inert, reproducing the seed repo's
//! traces unchanged.
//!
//! ## Threading model
//!
//! Federated rounds execute sampled clients in parallel on a scoped
//! thread pool (`util::pool::parallel_map_n`), and the fused ZOUPDATE
//! shards the weight vector across the same workers
//! (`model::params::perturb_axpy_many_sharded`). The worker count comes
//! from `FedConfig::threads`: `0` (the default) resolves to the
//! `ZOWARMUP_THREADS` env var, else the machine's available parallelism.
//!
//! **Determinism guarantee:** results are bit-identical for every worker
//! count. Per-client randomness is derived *before* each fan-out from
//! `(master seed, round, client id)`, jobs are pure functions of the
//! broadcast weights and the client shard, results fold back in sampled
//! order, and the sharded weight pass fast-forwards each perturbation
//! stream to its 64-aligned chunk offset (one u64 per 64-element block,
//! LSB-first) so every weight element sees the identical f32 operations
//! in the identical order. See `fed::server` for the full argument and
//! `fed::server::tests::thread_count_does_not_change_results` for the
//! enforcement.

// Lint posture (CI runs `cargo clippy --workspace --all-targets -D
// warnings`): correctness, suspicious and perf lints are enforced; the
// style lints below are allowed crate-wide where the explicit form
// documents protocol intent better than the idiom — index loops that
// mirror the paper's subscripted equations, field-by-field config setup
// in tests, and the deliberately argument-rich simulation entry points.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::field_reassign_with_default,
    clippy::manual_range_contains
)]

pub mod baselines;
pub mod ckpt;
pub mod comm;
pub mod config;
pub mod data;
pub mod exp;
pub mod fed;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod zo;
