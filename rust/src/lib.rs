//! # ZOWarmUp — zeroth-order federated pre-training with low-resource clients
//!
//! Rust + JAX + Pallas reproduction of *"Warming Up for Zeroth-Order
//! Federated Pre-Training with Low Resource Clients"* (Legate, Rish,
//! Belilovsky, 2025). See DESIGN.md for the architecture and the
//! per-experiment index, EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — PJRT client executing AOT HLO-text artifacts (L2/L1
//!   compiled from `python/compile/`).
//! * [`fed`] — the coordinator: Algorithm 1's two-phase loop, FedAvg /
//!   FedAdam aggregation, and the seed-based SPSA protocol.
//! * [`zo`] — SPSA estimation and seed bookkeeping.
//! * [`baselines`] — HeteroFL, FedKSeed, High-Res-Only comparators.
//! * [`data`] — procedural datasets + Dirichlet partitioner.
//! * [`comm`] — measured byte accounting + the eq. 4/5 analytic cost model.
//! * [`exp`] — runners that regenerate every paper table and figure.
//! * [`util`] — offline substrates (RNG, JSON, CLI, bench, property tests).

pub mod baselines;
pub mod comm;
pub mod config;
pub mod data;
pub mod exp;
pub mod fed;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod util;
pub mod zo;
