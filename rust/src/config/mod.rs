//! Experiment configuration: every hyperparameter of Algorithm 1 plus the
//! simulation scales. Configs are plain structs with JSON file / CLI
//! override support (`--config file.json --clients 50 ...`).

use crate::sim::Scenario;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Distribution;

/// Server-side optimizer for aggregated updates (§4.4 compares Adam).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServerOpt {
    Sgd,
    Adam { beta1: f64, beta2: f64, eps: f64 },
}

impl ServerOpt {
    pub fn adam() -> Self {
        ServerOpt::Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sgd" => Some(ServerOpt::Sgd),
            "adam" => Some(ServerOpt::adam()),
            _ => None,
        }
    }
}

/// How the server de-noises the aggregated SPSA estimate before folding
/// contributions into the fused (seed, coeff) item list
/// (`zo::zo_update_items`; DESIGN.md §9). `Off` reproduces the plain
/// n_j/n_Q weighting bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarianceGuard {
    /// plain n_j/n_Q weighting (the seed behavior)
    Off,
    /// scale each contribution's weight by the inverse of its final-block
    /// ghat sample variance (floored, renormalized) — noisy clients count
    /// less, tight estimates count more
    InvVar,
    /// clamp every |ΔL| to the fleet's `zo::GUARD_CLIP_QUANTILE` quantile
    /// before forming ghat — bounds the reach of outlier probes
    Clip,
}

impl VarianceGuard {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(VarianceGuard::Off),
            "invvar" => Some(VarianceGuard::InvVar),
            "clip" => Some(VarianceGuard::Clip),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            VarianceGuard::Off => "off",
            VarianceGuard::InvVar => "invvar",
            VarianceGuard::Clip => "clip",
        }
    }
}

/// Which round engine drives the run (`--engine sync|async`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// lock-step barrier rounds (`fed::server::{warm_round, zo_round}`) —
    /// the default, bit-identical to every seed-era trace
    Sync,
    /// discrete-event buffered-async ZO rounds (`fed::engine`): clients
    /// complete on their own simulated timelines and the server folds the
    /// first `buffer_k` arrivals with staleness-weighted coefficients.
    /// The warm (FedAvg) phase stays barrier-synchronous either way.
    Async,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sync" => Some(EngineKind::Sync),
            "async" => Some(EngineKind::Async),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Sync => "sync",
            EngineKind::Async => "async",
        }
    }
}

/// Which ZOUPDATE kernel regenerates perturbations from seeds
/// (`--kernel scalar|lanes`; DESIGN.md §12). The kernel is part of the
/// *protocol*, not just an implementation detail: it defines the
/// perturbation stream z(seed), so client probing, the live server fold,
/// catch-up replay and checkpoint reconstruction must all run the same
/// kind — which is why it lives in [`ZoConfig`] and flows through every
/// replay path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// one Xoshiro stream per seed — byte-identical to every historical
    /// trace including the golden fixture (the default)
    Scalar,
    /// four independent Xoshiro lanes per seed
    /// (`model::params::LANES_DEFAULT`), interleaved per 64-element
    /// block: a *different* perturbation stream with its own golden
    /// fixture, bit-identical across worker counts within the mode.
    /// Rademacher-only (lane fast-forward needs the one-u64-per-block
    /// consumption contract).
    Lanes,
}

impl KernelKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(KernelKind::Scalar),
            "lanes" => Some(KernelKind::Lanes),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Lanes => "lanes",
        }
    }
}

/// Knobs of the buffered-async engine (`fed::engine`; inert under the
/// default `EngineKind::Sync`).
#[derive(Debug, Clone, Copy)]
pub struct AsyncConfig {
    /// completions folded per aggregation step (CLI `--buffer-k`;
    /// 0 = use `sample_zo`)
    pub buffer_k: usize,
    /// polynomial staleness-decay exponent α: a contribution computed
    /// against a model `s` versions old is down-weighted by (1+s)^-α
    /// before the weight renormalization (CLI `--staleness-decay`;
    /// 0.0 = no staleness discount)
    pub staleness_decay: f64,
    /// in-flight dispatch slots the server keeps filled (CLI
    /// `--concurrency`; 0 = 2 × effective buffer_k)
    pub concurrency: usize,
    /// Poisson arrival rate in dispatches per simulated ms: every
    /// dispatch is delayed by an Exp(rate) draw before its
    /// download→compute→upload timeline starts (CLI `--arrival-rate`;
    /// 0.0 = staggered-immediate, no extra delay)
    pub arrival_rate: f64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            buffer_k: 0,
            staleness_decay: 0.5,
            concurrency: 0,
            arrival_rate: 0.0,
        }
    }
}

/// How the client population is backed (`fed::population::Population`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopulationMode {
    /// materialized below [`LAZY_AUTO_THRESHOLD`] clients (bit-compatible
    /// with every seed-era trace), lazy above it — the default
    Auto,
    /// always materialize per-client state (seed-era semantics at any N;
    /// memory scales O(N))
    Materialized,
    /// always derive per-client state on demand (O(sampled) rounds; tier
    /// occupancy is binomial, shards are fixed-size keyed draws)
    Lazy,
}

impl PopulationMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(PopulationMode::Auto),
            "materialized" => Some(PopulationMode::Materialized),
            "lazy" => Some(PopulationMode::Lazy),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            PopulationMode::Auto => "auto",
            PopulationMode::Materialized => "materialized",
            PopulationMode::Lazy => "lazy",
        }
    }
}

/// `PopulationMode::Auto` switches to the lazy population layer above
/// this many clients: small federations keep the byte-identical
/// materialized semantics, fleet-scale ones never pay O(N) setup.
pub const LAZY_AUTO_THRESHOLD: usize = 1 << 17;

/// Upper bound on `--edges`: the per-edge tables (ledger attribution,
/// partial-fold headers) are O(E), so a fat-fingered E can't allocate
/// unboundedly. 2^16 regional aggregators is far beyond any deployment.
pub const MAX_EDGES: usize = 1 << 16;

/// ZO-phase hyperparameters (§A.5 defaults: ε=1e-4, S=3, τ=0.75).
#[derive(Debug, Clone, Copy)]
pub struct ZoConfig {
    pub eps: f32,
    pub tau: f32,
    /// probes per client per local step. With `adaptive_s` off this is
    /// the uniform S every ZO participant runs; with it on it is the
    /// *reference* S — the per-client planner (`sim::max_affordable_s`)
    /// sizes the no-deadline round budget from the slowest sampled
    /// client's timeline at this S.
    pub s_seeds: usize,
    pub dist: Distribution,
    /// local ZO gradient steps per round (1 = the paper's method; >1 for
    /// the Table 3 ablation, splitting the client's data across steps)
    pub grad_steps: usize,
    /// capability-adaptive per-client probe budgets: each sampled ZO
    /// client is issued the largest S_j ∈ [s_min, s_max] whose simulated
    /// download → compute → upload timeline (catch-up charge included)
    /// fits the round budget — the scenario deadline when one is set,
    /// else the slowest sampled client's uniform-S timeline. Default off:
    /// every client gets exactly `s_seeds`, bit-identical to the seed
    /// behavior. CLI `--adaptive-s true`.
    pub adaptive_s: bool,
    /// adaptive-S floor (CLI `--s-min`; ≥ 1)
    pub s_min: usize,
    /// adaptive-S ceiling (CLI `--s-max`; `s_max · grad_steps` must fit
    /// the 2^16 per-round seed-index field)
    pub s_max: usize,
    /// variance-guard mode for the server aggregation (CLI
    /// `--guard off|invvar|clip`)
    pub guard: VarianceGuard,
    /// which ZOUPDATE kernel generates z(seed) on *both* protocol sides
    /// (CLI `--kernel scalar|lanes`; default scalar = seed-compatible)
    pub kernel: KernelKind,
}

impl Default for ZoConfig {
    fn default() -> Self {
        Self {
            eps: 1e-4,
            tau: 0.75,
            s_seeds: 3,
            dist: Distribution::Rademacher,
            grad_steps: 1,
            adaptive_s: false,
            s_min: 1,
            s_max: 16,
            guard: VarianceGuard::Off,
            kernel: KernelKind::Scalar,
        }
    }
}

/// Full federation config (Algorithm 1's knobs).
#[derive(Debug, Clone)]
pub struct FedConfig {
    /// total clients K
    pub clients: usize,
    /// fraction of clients that are high-resource (the "10/90" splits)
    pub hi_frac: f64,
    /// total federated rounds N + M
    pub rounds_total: usize,
    /// pivot point: rounds of high-resource warm-up (N); ZO thereafter
    pub pivot: usize,
    /// clients sampled per warm round (P ⊆ H; clamped to |H|)
    pub sample_warm: usize,
    /// clients sampled per ZO round (Q ⊆ K)
    pub sample_zo: usize,
    /// local epochs per warm round (paper: 3)
    pub local_epochs: usize,
    /// warm-phase minibatch size (paper: 64)
    pub batch: usize,
    /// learning rates (client/server × warm/zo, per §A.5)
    pub lr_client_warm: f32,
    pub lr_server_warm: f32,
    pub lr_client_zo: f32,
    pub lr_server_zo: f32,
    pub server_opt: ServerOpt,
    pub zo: ZoConfig,
    /// evaluate on the test set every this many rounds (always at pivot/end)
    pub eval_every: usize,
    /// master seed: drives init, partition, sampling, perturbations
    pub seed: u64,
    /// let high-resource clients keep making first-order updates in step 2
    /// (§A.4 ablation; default false = all-ZO, which the paper finds better)
    pub mixed_step2: bool,
    /// worker threads for the parallel round engine (0 = auto: the
    /// `ZOWARMUP_THREADS` env override, else available parallelism).
    /// Results are bit-identical for every value — see the threading
    /// model docs in `fed::server`.
    pub threads: usize,
    /// device-capability scenario: per-client memory/bandwidth/compute
    /// profiles, availability traces, and the round deadline (`sim`
    /// module). The default `Binary` reproduces the seed's High/Low
    /// `assign_resources` split bit for bit from `hi_frac`; custom
    /// scenarios ignore `hi_frac` and draw tiers from their own
    /// fractions. CLI: `--scenario <preset|file.json|{inline json}>`.
    pub scenario: Scenario,
    /// checkpoint cadence: materialize a server parameter snapshot every
    /// this many seed-replayable ZO rounds and compact the live seed log
    /// to the tail since it (`ckpt` module; CLI `--ckpt-every`). Stale
    /// clients (late joiners, rejoining dropouts, churn absences) are
    /// then charged the cheaper of snapshot-vs-tail catch-up downlink
    /// before they can participate. 0 (default) disables the subsystem —
    /// the seed repo's free-rejoin accounting, byte-identical to before.
    pub ckpt_every: usize,
    /// population backing mode (CLI `--population auto|materialized|lazy`;
    /// see `fed::population`). `Auto` (default) materializes up to
    /// [`LAZY_AUTO_THRESHOLD`] clients — byte-identical to the seed-era
    /// path — and derives lazily above it, so `--clients 10000000` costs
    /// O(sampled) per round.
    pub population: PopulationMode,
    /// round engine (CLI `--engine sync|async`). `Sync` (default) keeps
    /// the barrier rounds bit-identical to the seed; `Async` drives the
    /// ZO phase through the discrete-event buffered engine
    /// (`fed::engine`), deterministic per worker count in its own right.
    pub engine: EngineKind,
    /// buffered-async engine knobs (inert under `EngineKind::Sync`)
    pub async_zo: AsyncConfig,
    /// edge aggregators E in the two-tier topology (CLI `--edges`):
    /// clients partition across E regional aggregators via
    /// `sim::edge_of`, each edge partially folds its cohort, and the root
    /// merges the partials in edge-index order — bit-identical to the
    /// flat fold for every E (see `zo::zo_update_items_two_tier`).
    /// 1 (default) short-circuits the partition entirely, byte-identical
    /// to every historical trace. Edge *rate/failure* modeling only
    /// engages when the scenario declares `"edges": [...]` profiles
    /// (`geo-iot` / `geo-phones` presets).
    pub edges: usize,
}

impl Default for FedConfig {
    fn default() -> Self {
        Self {
            clients: 20,
            hi_frac: 0.5,
            rounds_total: 100,
            pivot: 40,
            sample_warm: 5,
            sample_zo: 5,
            local_epochs: 3,
            batch: 64,
            lr_client_warm: 0.05,
            lr_server_warm: 1.0,
            lr_client_zo: 1.0,
            lr_server_zo: 0.05,
            server_opt: ServerOpt::Sgd,
            zo: ZoConfig::default(),
            eval_every: 5,
            seed: 0,
            mixed_step2: false,
            threads: 0,
            scenario: Scenario::Binary,
            ckpt_every: 0,
            population: PopulationMode::Auto,
            engine: EngineKind::Sync,
            async_zo: AsyncConfig::default(),
            edges: 1,
        }
    }
}

impl FedConfig {
    /// Number of high-resource clients |H| (at least 1).
    pub fn hi_count(&self) -> usize {
        ((self.clients as f64 * self.hi_frac).round() as usize)
            .clamp(1, self.clients)
    }

    /// Whether this config runs on the lazy population layer
    /// (`fed::population::Population::Lazy`): forced by
    /// `--population lazy|materialized`, or size-resolved under `Auto`.
    pub fn lazy_population(&self) -> bool {
        match self.population {
            PopulationMode::Lazy => true,
            PopulationMode::Materialized => false,
            PopulationMode::Auto => self.clients > LAZY_AUTO_THRESHOLD,
        }
    }

    /// Effective async fold size: `--buffer-k`, defaulting to the sync
    /// engine's per-round ZO sample (clamped like `zo_round`'s Q).
    pub fn buffer_k(&self) -> usize {
        if self.async_zo.buffer_k > 0 {
            self.async_zo.buffer_k
        } else {
            self.sample_zo.clamp(1, self.clients)
        }
    }

    /// Effective async in-flight dispatch slots: `--concurrency`,
    /// defaulting to twice the fold size so slow clients keep computing
    /// across folds (the source of nonzero staleness).
    pub fn async_concurrency(&self) -> usize {
        if self.async_zo.concurrency > 0 {
            self.async_zo.concurrency
        } else {
            2 * self.buffer_k()
        }
    }

    /// The paper's full protocol: 50 clients, 200 + 300 rounds.
    pub fn paper_scale(mut self) -> Self {
        self.clients = 50;
        self.rounds_total = 500;
        self.pivot = 200;
        self.sample_warm = 10;
        self.sample_zo = 10;
        self
    }

    /// Seconds-scale smoke preset (CI / quick checks).
    pub fn smoke_scale(mut self) -> Self {
        self.clients = 8;
        self.rounds_total = 12;
        self.pivot = 6;
        self.sample_warm = 3;
        self.sample_zo = 4;
        self.local_epochs = 1;
        self.eval_every = 3;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.clients > 0, "clients must be > 0");
        anyhow::ensure!(self.pivot <= self.rounds_total, "pivot beyond total rounds");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.hi_frac),
            "hi_frac must be in [0,1]"
        );
        anyhow::ensure!(self.zo.s_seeds > 0, "S must be >= 1");
        anyhow::ensure!(self.zo.grad_steps > 0, "grad_steps must be >= 1");
        anyhow::ensure!(self.zo.eps > 0.0, "eps must be > 0");
        anyhow::ensure!(
            self.zo.tau > 0.0 && self.zo.tau <= 1.0,
            "tau must be in (0,1]"
        );
        anyhow::ensure!(self.batch > 0, "batch must be > 0");
        // seed-derivation field widths: compact ids (< 2^20 for the
        // per-client RNG, < 2^24 for the SeedIssuer) keep the historical
        // packed streams; larger ids derive through the wide fleet path.
        // The hard bound is the wide packing's 40-bit client field —
        // exceeding it would silently alias another stream.
        anyhow::ensure!(
            self.clients <= crate::fed::client::MAX_FLEET_CLIENTS,
            "clients {} exceeds the fleet RNG-derivation limit {}",
            self.clients,
            crate::fed::client::MAX_FLEET_CLIENTS
        );
        anyhow::ensure!(
            self.rounds_total <= crate::zo::MAX_ROUNDS,
            "rounds_total {} exceeds the seed-derivation limit {}",
            self.rounds_total,
            crate::zo::MAX_ROUNDS
        );
        anyhow::ensure!(
            self.zo.s_seeds.saturating_mul(self.zo.grad_steps)
                <= crate::zo::MAX_SEEDS_PER_ROUND,
            "s_seeds * grad_steps = {} exceeds the per-round seed limit {}",
            self.zo.s_seeds.saturating_mul(self.zo.grad_steps),
            crate::zo::MAX_SEEDS_PER_ROUND
        );
        // adaptive-S bounds: the planner's ceiling must also respect the
        // 16-bit per-round seed-index field, and the range must be sane.
        // With adaptive_s off the knobs are inert and left unvalidated
        // against the seed field (a large s_max can sit in a config file
        // without effect).
        anyhow::ensure!(self.zo.s_min >= 1, "s_min must be >= 1");
        anyhow::ensure!(
            self.zo.s_min <= self.zo.s_max,
            "s_min {} > s_max {}",
            self.zo.s_min,
            self.zo.s_max
        );
        if self.zo.adaptive_s {
            anyhow::ensure!(
                self.zo.s_max.saturating_mul(self.zo.grad_steps)
                    <= crate::zo::MAX_SEEDS_PER_ROUND,
                "s_max * grad_steps = {} exceeds the per-round seed limit {}",
                self.zo.s_max.saturating_mul(self.zo.grad_steps),
                crate::zo::MAX_SEEDS_PER_ROUND
            );
        }
        // async-engine knobs: the decay/arrival parameters must be sane
        // whenever set (they sit in config files even under sync), and
        // the §A.4 mixed FO step-2 arm requires the synchronous barrier
        // (its FedAvg fold needs every participant's full weights at one
        // model version).
        anyhow::ensure!(
            self.async_zo.staleness_decay.is_finite() && self.async_zo.staleness_decay >= 0.0,
            "staleness-decay must be finite and >= 0"
        );
        anyhow::ensure!(
            self.async_zo.arrival_rate.is_finite() && self.async_zo.arrival_rate >= 0.0,
            "arrival-rate must be finite and >= 0"
        );
        if self.engine == EngineKind::Async {
            anyhow::ensure!(
                !self.mixed_step2,
                "--engine async is incompatible with --mixed-step2 \
                 (the mixed FO fold needs the synchronous barrier)"
            );
        }
        // the lanes kernel fast-forwards each lane by a block count, which
        // requires the Rademacher one-u64-per-64-block consumption
        // contract; Gaussian draws are data-dependent and cannot be lane
        // split (same reason the sharded scalar pass falls back).
        if self.zo.kernel == KernelKind::Lanes {
            anyhow::ensure!(
                self.zo.dist == Distribution::Rademacher,
                "--kernel lanes requires --dist rademacher \
                 (Gaussian streams cannot be lane-split)"
            );
        }
        // two-tier topology: at least one aggregator; the cap is far
        // above any plausible deployment and keeps the per-edge tables
        // (ledger attribution, partial-fold headers) trivially small.
        anyhow::ensure!(self.edges >= 1, "edges must be >= 1");
        anyhow::ensure!(
            self.edges <= MAX_EDGES,
            "edges {} exceeds the topology limit {}",
            self.edges,
            MAX_EDGES
        );
        self.scenario.validate()?;
        Ok(())
    }

    /// Apply `--key value` CLI overrides (unknown keys rejected upstream).
    pub fn apply_args(&mut self, a: &Args) -> anyhow::Result<()> {
        self.clients = a.usize_or("clients", self.clients)?;
        self.hi_frac = a.f64_or("hi-frac", self.hi_frac)?;
        self.rounds_total = a.usize_or("rounds", self.rounds_total)?;
        self.pivot = a.usize_or("pivot", self.pivot)?;
        self.sample_warm = a.usize_or("sample-warm", self.sample_warm)?;
        self.sample_zo = a.usize_or("sample-zo", self.sample_zo)?;
        self.local_epochs = a.usize_or("local-epochs", self.local_epochs)?;
        self.batch = a.usize_or("batch", self.batch)?;
        self.lr_client_warm = a.f64_or("lr-client-warm", self.lr_client_warm as f64)? as f32;
        self.lr_server_warm = a.f64_or("lr-server-warm", self.lr_server_warm as f64)? as f32;
        self.lr_client_zo = a.f64_or("lr-client-zo", self.lr_client_zo as f64)? as f32;
        self.lr_server_zo = a.f64_or("lr-server-zo", self.lr_server_zo as f64)? as f32;
        self.zo.eps = a.f64_or("eps", self.zo.eps as f64)? as f32;
        self.zo.tau = a.f64_or("tau", self.zo.tau as f64)? as f32;
        self.zo.s_seeds = a.usize_or("seeds-s", self.zo.s_seeds)?;
        self.zo.grad_steps = a.usize_or("grad-steps", self.zo.grad_steps)?;
        self.zo.adaptive_s = a.bool_or("adaptive-s", self.zo.adaptive_s)?;
        self.zo.s_min = a.usize_or("s-min", self.zo.s_min)?;
        self.zo.s_max = a.usize_or("s-max", self.zo.s_max)?;
        if let Some(g) = a.get("guard") {
            self.zo.guard = VarianceGuard::parse(g)
                .ok_or_else(|| anyhow::anyhow!("bad --guard {g:?} (off|invvar|clip)"))?;
        }
        if let Some(k) = a.get("kernel") {
            self.zo.kernel = KernelKind::parse(k)
                .ok_or_else(|| anyhow::anyhow!("bad --kernel {k:?} (scalar|lanes)"))?;
        }
        self.eval_every = a.usize_or("eval-every", self.eval_every)?;
        self.seed = a.usize_or("seed", self.seed as usize)? as u64;
        self.mixed_step2 = a.bool_or("mixed-step2", self.mixed_step2)?;
        self.threads = a.usize_or("threads", self.threads)?;
        self.ckpt_every = a.usize_or("ckpt-every", self.ckpt_every)?;
        self.edges = a.usize_or("edges", self.edges)?;
        if let Some(e) = a.get("engine") {
            self.engine = EngineKind::parse(e)
                .ok_or_else(|| anyhow::anyhow!("bad --engine {e:?} (sync|async)"))?;
        }
        self.async_zo.buffer_k = a.usize_or("buffer-k", self.async_zo.buffer_k)?;
        self.async_zo.staleness_decay =
            a.f64_or("staleness-decay", self.async_zo.staleness_decay)?;
        self.async_zo.concurrency = a.usize_or("concurrency", self.async_zo.concurrency)?;
        self.async_zo.arrival_rate = a.f64_or("arrival-rate", self.async_zo.arrival_rate)?;
        if let Some(p) = a.get("population") {
            self.population = PopulationMode::parse(p).ok_or_else(|| {
                anyhow::anyhow!("bad --population {p:?} (auto|materialized|lazy)")
            })?;
        }
        if let Some(s) = a.get("scenario") {
            self.scenario = Scenario::load(s)?;
        }
        if let Some(d) = a.get("dist") {
            self.zo.dist =
                Distribution::parse(d).ok_or_else(|| anyhow::anyhow!("bad --dist {d:?}"))?;
        }
        if let Some(o) = a.get("server-opt") {
            self.server_opt =
                ServerOpt::parse(o).ok_or_else(|| anyhow::anyhow!("bad --server-opt {o:?}"))?;
        }
        self.validate()
    }

    /// Load overrides from a JSON config file (flat key/value object using
    /// the same names as the CLI flags).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config root must be an object"))?;
        let mut argv = Vec::new();
        for (k, v) in obj {
            argv.push(format!("--{k}"));
            argv.push(match v {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            });
        }
        let args = Args::parse(&argv)?;
        self.apply_args(&args)
    }
}

/// Data/scale configuration.
#[derive(Debug, Clone)]
pub struct DataConfig {
    pub dataset: String, // "synth10" | "synth100" | "lm"
    pub n_train: usize,
    pub n_test: usize,
    pub alpha: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            dataset: "synth10".into(),
            n_train: 2000,
            n_test: 500,
            alpha: 0.1,
        }
    }
}

impl DataConfig {
    pub fn apply_args(&mut self, a: &Args) -> anyhow::Result<()> {
        self.dataset = a.str_or("dataset", &self.dataset);
        self.n_train = a.usize_or("n-train", self.n_train)?;
        self.n_test = a.usize_or("n-test", self.n_test)?;
        self.alpha = a.f64_or("alpha", self.alpha)?;
        Ok(())
    }
}

/// Experiment scale presets shared by the exp runners and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    pub fn fed(self) -> FedConfig {
        match self {
            Scale::Smoke => FedConfig::default().smoke_scale(),
            Scale::Default => FedConfig::default(),
            Scale::Paper => FedConfig::default().paper_scale(),
        }
    }

    pub fn data(self) -> DataConfig {
        match self {
            Scale::Smoke => DataConfig {
                n_train: 400,
                n_test: 200,
                ..Default::default()
            },
            Scale::Default => DataConfig::default(),
            Scale::Paper => DataConfig {
                n_train: 20_000,
                n_test: 4_000,
                ..Default::default()
            },
        }
    }

    pub fn seeds(self) -> usize {
        match self {
            Scale::Smoke => 1,
            Scale::Default => 3,
            Scale::Paper => 5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        FedConfig::default().validate().unwrap();
        FedConfig::default().paper_scale().validate().unwrap();
        FedConfig::default().smoke_scale().validate().unwrap();
    }

    #[test]
    fn paper_scale_matches_protocol() {
        let c = FedConfig::default().paper_scale();
        assert_eq!(c.clients, 50);
        assert_eq!(c.pivot, 200);
        assert_eq!(c.rounds_total, 500);
        assert_eq!(c.zo.s_seeds, 3);
        assert_eq!(c.zo.tau, 0.75);
        assert_eq!(c.zo.eps, 1e-4);
    }

    #[test]
    fn hi_count_rounds_and_clamps() {
        let mut c = FedConfig::default();
        c.clients = 50;
        c.hi_frac = 0.1;
        assert_eq!(c.hi_count(), 5);
        c.hi_frac = 0.0;
        assert_eq!(c.hi_count(), 1); // at least one high-res client
        c.hi_frac = 1.0;
        assert_eq!(c.hi_count(), 50);
    }

    #[test]
    fn cli_overrides() {
        let argv: Vec<String> = "--clients 12 --pivot 3 --rounds 9 --tau 0.5 --dist gaussian --server-opt adam"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        c.apply_args(&a).unwrap();
        assert_eq!(c.clients, 12);
        assert_eq!(c.pivot, 3);
        assert_eq!(c.zo.tau, 0.5);
        assert_eq!(c.zo.dist, Distribution::Gaussian);
        assert!(matches!(c.server_opt, ServerOpt::Adam { .. }));
    }

    #[test]
    fn invalid_rejected() {
        let mut c = FedConfig::default();
        c.pivot = c.rounds_total + 1;
        assert!(c.validate().is_err());
        let mut c = FedConfig::default();
        c.zo.tau = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn seed_derivation_limits_enforced() {
        let mut c = FedConfig::default();
        c.zo.s_seeds = 4096;
        c.zo.grad_steps = 17; // 4096 * 17 > 2^16
        assert!(c.validate().is_err());
        c.zo.grad_steps = 16; // exactly 2^16: still representable
        assert!(c.validate().is_ok());
        // fleet-scale populations are first-class now: ids past the
        // compact packings derive through the wide stream path, so 10^7
        // clients validate; only the 40-bit wide field is a hard wall
        let mut c = FedConfig::default();
        c.clients = 10_000_000;
        assert!(c.validate().is_ok(), "--clients must accept >= 10^7");
        c.clients = crate::fed::client::MAX_FLEET_CLIENTS + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn population_mode_parses_and_auto_resolves_by_size() {
        for m in [
            PopulationMode::Auto,
            PopulationMode::Materialized,
            PopulationMode::Lazy,
        ] {
            assert_eq!(PopulationMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(PopulationMode::parse("nope"), None);
        let mut c = FedConfig::default();
        assert_eq!(c.population, PopulationMode::Auto);
        assert!(!c.lazy_population(), "20 clients stay materialized");
        c.clients = LAZY_AUTO_THRESHOLD;
        assert!(!c.lazy_population(), "threshold itself stays materialized");
        c.clients = LAZY_AUTO_THRESHOLD + 1;
        assert!(c.lazy_population(), "past the threshold auto goes lazy");
        c.population = PopulationMode::Materialized;
        assert!(!c.lazy_population());
        c.clients = 8;
        c.population = PopulationMode::Lazy;
        assert!(c.lazy_population(), "explicit lazy wins at any size");
        // CLI + JSON plumbing
        let argv: Vec<String> = "--population lazy"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        c.apply_args(&a).unwrap();
        assert_eq!(c.population, PopulationMode::Lazy);
        let j = Json::parse(r#"{"population": "materialized"}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.population, PopulationMode::Materialized);
        let bad: Vec<String> = vec!["--population".into(), "eager".into()];
        let a = Args::parse(&bad).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
    }

    #[test]
    fn adaptive_s_knobs_parse_and_validate() {
        let argv: Vec<String> =
            "--adaptive-s true --s-min 2 --s-max 24 --guard invvar"
                .split_whitespace()
                .map(String::from)
                .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        assert!(!c.zo.adaptive_s); // default off: seed-compatible
        assert_eq!(c.zo.guard, VarianceGuard::Off);
        c.apply_args(&a).unwrap();
        assert!(c.zo.adaptive_s);
        assert_eq!((c.zo.s_min, c.zo.s_max), (2, 24));
        assert_eq!(c.zo.guard, VarianceGuard::InvVar);
        // also flows through JSON configs
        let j = Json::parse(r#"{"adaptive-s": true, "s-max": 8, "guard": "clip"}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert!(c.zo.adaptive_s);
        assert_eq!(c.zo.s_max, 8);
        assert_eq!(c.zo.guard, VarianceGuard::Clip);
        // bad guard mode rejected
        let bad: Vec<String> = vec!["--guard".into(), "median".into()];
        let a = Args::parse(&bad).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
    }

    #[test]
    fn adaptive_s_range_validation() {
        let mut c = FedConfig::default();
        c.zo.s_min = 0;
        assert!(c.validate().is_err());
        let mut c = FedConfig::default();
        c.zo.s_min = 9;
        c.zo.s_max = 4;
        assert!(c.validate().is_err());
        // the 2^16 seed field bounds s_max only when the planner can
        // actually issue it
        let mut c = FedConfig::default();
        c.zo.grad_steps = 16;
        c.zo.s_max = 4097; // 4097 * 16 > 2^16
        assert!(c.validate().is_ok(), "inert knobs stay unvalidated");
        c.zo.adaptive_s = true;
        assert!(c.validate().is_err());
        c.zo.s_max = 4096; // exactly 2^16: still representable
        c.zo.s_min = 1;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn engine_knobs_parse_and_validate() {
        let mut c = FedConfig::default();
        assert_eq!(c.engine, EngineKind::Sync); // default: seed-compatible
        assert_eq!(c.async_zo.buffer_k, 0);
        assert_eq!(c.async_zo.staleness_decay, 0.5);
        // effective-knob resolution: buffer_k falls back to sample_zo,
        // concurrency to 2 × buffer_k
        assert_eq!(c.buffer_k(), c.sample_zo);
        assert_eq!(c.async_concurrency(), 2 * c.sample_zo);
        c.async_zo.buffer_k = 3;
        c.async_zo.concurrency = 11;
        assert_eq!((c.buffer_k(), c.async_concurrency()), (3, 11));

        let argv: Vec<String> =
            "--engine async --buffer-k 4 --staleness-decay 1.5 --concurrency 9 --arrival-rate 0.25"
                .split_whitespace()
                .map(String::from)
                .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        c.apply_args(&a).unwrap();
        assert_eq!(c.engine, EngineKind::Async);
        assert_eq!(c.async_zo.buffer_k, 4);
        assert_eq!(c.async_zo.staleness_decay, 1.5);
        assert_eq!(c.async_zo.concurrency, 9);
        assert_eq!(c.async_zo.arrival_rate, 0.25);

        // also flows through JSON configs
        let j = Json::parse(r#"{"engine": "async", "buffer-k": 2}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.engine, EngineKind::Async);
        assert_eq!(c.async_zo.buffer_k, 2);

        // bad engine name rejected
        let bad: Vec<String> = vec!["--engine".into(), "batch".into()];
        let a = Args::parse(&bad).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
        // round-trip
        for e in [EngineKind::Sync, EngineKind::Async] {
            assert_eq!(EngineKind::parse(e.as_str()), Some(e));
        }
    }

    #[test]
    fn async_engine_validation() {
        let mut c = FedConfig::default();
        c.engine = EngineKind::Async;
        assert!(c.validate().is_ok());
        c.mixed_step2 = true;
        assert!(c.validate().is_err(), "mixed FO step-2 needs the barrier");
        c.engine = EngineKind::Sync;
        assert!(c.validate().is_ok(), "mixed stays legal under sync");

        let mut c = FedConfig::default();
        c.async_zo.staleness_decay = -0.1;
        assert!(c.validate().is_err());
        let mut c = FedConfig::default();
        c.async_zo.arrival_rate = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn kernel_knob_parses_and_validates() {
        let mut c = FedConfig::default();
        assert_eq!(c.zo.kernel, KernelKind::Scalar); // default: seed-compatible
        let argv: Vec<String> = "--kernel lanes"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        c.apply_args(&a).unwrap();
        assert_eq!(c.zo.kernel, KernelKind::Lanes);
        // also flows through JSON configs
        let j = Json::parse(r#"{"kernel": "lanes"}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.zo.kernel, KernelKind::Lanes);
        // bad kernel name rejected
        let bad: Vec<String> = vec!["--kernel".into(), "simd".into()];
        let a = Args::parse(&bad).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
        // lanes is Rademacher-only: the Gaussian combination must die in
        // validation, in either flag order
        let mut c = FedConfig::default();
        c.zo.kernel = KernelKind::Lanes;
        c.zo.dist = Distribution::Gaussian;
        assert!(c.validate().is_err());
        let argv: Vec<String> = "--kernel lanes --dist gaussian"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
        // round-trip
        for k in [KernelKind::Scalar, KernelKind::Lanes] {
            assert_eq!(KernelKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn variance_guard_round_trips() {
        for g in [VarianceGuard::Off, VarianceGuard::InvVar, VarianceGuard::Clip] {
            assert_eq!(VarianceGuard::parse(g.as_str()), Some(g));
        }
        assert_eq!(VarianceGuard::parse("nope"), None);
    }

    #[test]
    fn threads_override() {
        let argv: Vec<String> = "--threads 4"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        assert_eq!(c.threads, 0); // default: auto
        c.apply_args(&a).unwrap();
        assert_eq!(c.threads, 4);
    }

    #[test]
    fn ckpt_every_override() {
        let argv: Vec<String> = "--ckpt-every 5"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        assert_eq!(c.ckpt_every, 0); // default: disabled (seed-compatible)
        c.apply_args(&a).unwrap();
        assert_eq!(c.ckpt_every, 5);
        // also flows through JSON configs
        let j = Json::parse(r#"{"ckpt-every": 3}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.ckpt_every, 3);
    }

    #[test]
    fn edges_override_and_bounds() {
        let argv: Vec<String> = "--edges 4".split_whitespace().map(String::from).collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        assert_eq!(c.edges, 1); // default: flat topology (trace-compatible)
        c.apply_args(&a).unwrap();
        assert_eq!(c.edges, 4);
        // also flows through JSON configs
        let j = Json::parse(r#"{"edges": 16}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.edges, 16);
        // 0 edges is meaningless, and E is capped
        let mut c = FedConfig::default();
        c.edges = 0;
        assert!(c.validate().is_err());
        c.edges = MAX_EDGES;
        assert!(c.validate().is_ok());
        c.edges = MAX_EDGES + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_config() {
        let j = Json::parse(r#"{"clients": 30, "tau": 0.25, "dist": "rademacher"}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.clients, 30);
        assert_eq!(c.zo.tau, 0.25);
    }

    #[test]
    fn scenario_preset_override() {
        let argv: Vec<String> = "--scenario stragglers"
            .split_whitespace()
            .map(String::from)
            .collect();
        let a = Args::parse(&argv).unwrap();
        let mut c = FedConfig::default();
        assert_eq!(c.scenario, Scenario::Binary);
        c.apply_args(&a).unwrap();
        assert_eq!(c.scenario.name(), "stragglers");
        assert!(c.scenario.deadline_ms() > 0.0);

        let bad: Vec<String> = vec!["--scenario".into(), "no-such-thing".into()];
        let a = Args::parse(&bad).unwrap();
        assert!(FedConfig::default().apply_args(&a).is_err());
    }

    #[test]
    fn scenario_embedded_in_json_config() {
        // a scenario object inside a config file flows through apply_json
        // (the Obj value is re-serialized and re-parsed by Scenario::load)
        let j = Json::parse(
            r#"{"clients": 12, "scenario": {
                  "name": "cfg-fleet", "deadline_ms": 3.0,
                  "tiers": [
                    {"frac": 0.25, "mem": "backprop", "up_mbps": 50, "down_mbps": 50},
                    {"frac": 0.75, "mem": "zo", "up_mbps": 2, "down_mbps": 4, "drop_rate": 0.1}
                  ]}}"#,
        )
        .unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.clients, 12);
        assert_eq!(c.scenario.name(), "cfg-fleet");
        assert_eq!(c.scenario.deadline_ms(), 3.0);
        // a preset by name also works in config files
        let j = Json::parse(r#"{"scenario": "flaky"}"#).unwrap();
        let mut c = FedConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.scenario.name(), "flaky");
    }

    #[test]
    fn scale_presets() {
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert!(Scale::Smoke.fed().rounds_total < Scale::Default.fed().rounds_total);
        assert_eq!(Scale::Paper.seeds(), 5);
    }
}
