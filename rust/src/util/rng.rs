//! Deterministic RNG substrate (no `rand` crate offline; see DESIGN.md §2).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256++), which drives all
//! simulation randomness: data generation, Dirichlet partitioning, client
//! sampling, and — crucially — the seeded Rademacher/Gaussian perturbations
//! of the SPSA protocol. A perturbation is *never stored*: both sides of
//! the protocol regenerate it from the 8-byte seed, which is what makes the
//! paper's `S·4`-byte up-link possible.

/// SplitMix64: tiny, full-period seeder (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (the canonical recommendation; avoids the
    /// all-zero state and decorrelates nearby integer seeds).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        advance(&mut self.s);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased
    /// enough for simulation; n ≪ 2^32 here).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast
    /// here — Gaussian perturbation is the paper's *worse* variant).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang; used by [`Self::dirichlet`].
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the paper's non-IID label-skew sampler.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Sample `m` distinct indices from [0, n) — a partial Fisher-Yates
    /// run *sparsely*: instead of materializing the whole `0..n` id
    /// vector, a displacement map records only the slots a swap has
    /// touched, so memory is O(m) while the draw sequence (`m` calls to
    /// [`Self::below`]) and the output stay **bit-identical** to the
    /// dense array walk for every `(n, m)`. This is the streaming index
    /// sampler behind O(sampled)-cost rounds over 10^7-client id spaces
    /// (`choose_sparse_matches_dense_reference` pins the equivalence).
    pub fn choose(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "choose({m}) from {n}");
        // slot -> displaced value; untouched slots implicitly hold their
        // own index. Only ever *indexed* by key (no iteration), so the
        // map's nondeterministic order cannot leak into results.
        let mut disp: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(m.saturating_mul(2));
        let mut out = Vec::with_capacity(m);
        for i in 0..m {
            let j = i + self.below(n - i);
            let at_j = disp.get(&j).copied().unwrap_or(j);
            let at_i = disp.get(&i).copied().unwrap_or(i);
            // dense equivalent: swap(idx[i], idx[j]); out takes idx[i].
            // Slot i is never probed again (future reads are at > i), so
            // only the j side of the swap needs recording — and j == i
            // degenerates to rewriting the slot with its own value.
            out.push(at_j);
            disp.insert(j, at_i);
        }
        out
    }

    /// Fast-forward the stream by `n` `next_u64` draws.
    ///
    /// This is the shard-offset primitive of the parallel ZOUPDATE: a
    /// Rademacher [`PerturbStream`] consumes exactly one u64 per
    /// 64-element weight block (LSB-first), so a worker that owns the
    /// chunk starting at element `offset` (64-aligned) reproduces the
    /// bit-exact sub-stream by discarding `offset / 64` draws.
    ///
    /// Cost: small offsets (`n < `[`JUMP_MIN_DRAWS`]) run the plain O(n)
    /// draw loop; larger offsets apply the xoshiro256 GF(2) jump
    /// specialized to arbitrary `n` — the state transition is linear over
    /// GF(2), so `n` steps are the matrix power `Mⁿ` applied to the
    /// 256-bit state, evaluated in O(log n) vector-matrix products
    /// against the lazily-built table of `M^(2^k)` squarings
    /// (`jump_powers`). This removes the O(offset) setup the last shard
    /// worker used to pay at d=11M (≈4.6M discarded draws across its 30
    /// streams); both paths produce bit-identical states
    /// (`discard_matches_manual_draws`, `discard_large_offset_matches_loop`).
    pub fn discard(&mut self, n: u64) {
        if n < JUMP_MIN_DRAWS {
            for _ in 0..n {
                self.next_u64();
            }
            return;
        }
        let powers = jump_powers();
        let mut v = self.s;
        for (k, m) in powers.iter().enumerate() {
            if (n >> k) & 1 == 1 {
                v = m.apply(&v);
            }
        }
        self.s = v;
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// One xoshiro256 state transition (the part of [`Xoshiro256::next_u64`]
/// after the output is formed). Every operation — xor, left shift,
/// rotate — is linear over GF(2), which is what makes the arbitrary-n
/// jump below possible.
#[inline]
fn advance(s: &mut [u64; 4]) {
    let t = s[1] << 17;
    s[2] ^= s[0];
    s[3] ^= s[1];
    s[1] ^= s[2];
    s[0] ^= s[3];
    s[2] ^= t;
    s[3] = s[3].rotate_left(45);
}

/// Below this many draws the plain loop beats the jump's table lookups
/// (the one-time 512 KB power-table build amortizes across the many
/// per-(stream, worker) discards of a sharded ZOUPDATE run).
pub const JUMP_MIN_DRAWS: u64 = 1 << 12;

/// A 256×256 GF(2) matrix over the xoshiro256 state, column-major:
/// `col[i]` is the image of basis state bit `i` (bit `i % 64` of word
/// `i / 64`). Applying the matrix to a state vector XORs together the
/// columns selected by the state's set bits.
#[derive(Clone)]
struct JumpMatrix {
    col: Vec<[u64; 4]>,
}

impl JumpMatrix {
    /// The one-step transition matrix, built by pushing each basis state
    /// through [`advance`] — definitionally in sync with the generator.
    fn one_step() -> Self {
        let mut col = vec![[0u64; 4]; 256];
        for (i, c) in col.iter_mut().enumerate() {
            let mut s = [0u64; 4];
            s[i / 64] = 1u64 << (i % 64);
            advance(&mut s);
            *c = s;
        }
        Self { col }
    }

    fn apply(&self, v: &[u64; 4]) -> [u64; 4] {
        let mut out = [0u64; 4];
        for (i, c) in self.col.iter().enumerate() {
            if (v[i >> 6] >> (i & 63)) & 1 == 1 {
                out[0] ^= c[0];
                out[1] ^= c[1];
                out[2] ^= c[2];
                out[3] ^= c[3];
            }
        }
        out
    }

    fn square(&self) -> Self {
        Self {
            col: self.col.iter().map(|c| self.apply(c)).collect(),
        }
    }
}

/// Lazily-built table of `M^(2^k)` for k = 0..64 (M = the one-step
/// transition): any `n < 2^64` jump is the product of the powers at `n`'s
/// set bits. Built once per process (~64 squarings, milliseconds, 512 KB).
fn jump_powers() -> &'static [JumpMatrix] {
    use std::sync::OnceLock;
    static POWERS: OnceLock<Vec<JumpMatrix>> = OnceLock::new();
    POWERS.get_or_init(|| {
        let mut v = Vec::with_capacity(64);
        v.push(JumpMatrix::one_step());
        for k in 1..64 {
            let sq = v[k - 1].square();
            v.push(sq);
        }
        v
    })
}

/// Central registry of every RNG domain-separation salt in the
/// workspace (DESIGN.md §14).
///
/// Each salt opens an independent random stream derived from the master
/// seed; the values are arbitrary but **fixed forever** — they are part
/// of the protocol definition exactly like the xoshiro constants are
/// part of the generator's. The registry is the single place a salt may
/// be *defined*: `detlint` fails the build on a `*_SALT: u64` literal
/// anywhere else under `rust/src`, and checks the values here for
/// pairwise distinctness (a collision silently merges two streams that
/// every determinism argument assumes are decorrelated). Consumers keep
/// their historical paths via re-exports (`crate::sim::SIM_SALT`,
/// `crate::fed::population::SHARD_SALT`, ...), so no call site or
/// historical stream changed when the definitions moved here.
pub mod salts {
    /// Domain-separation salt for per-lane key derivation
    /// ([`lane_keys`](super::lane_keys)). Arbitrary odd constant, fixed
    /// forever: it is part of the `--kernel lanes` stream definition
    /// (DESIGN.md §12).
    pub const LANE_KEY_SALT: u64 = 0xA5A5_5EED_1A4E_5107;

    /// Salt for the per-(round, client) availability trace RNG
    /// ([`crate::fed::client::round_client_rng`]) — decorrelated from
    /// the local-SGD (salt 0) and FedKSeed (salt 0x4B) streams.
    pub const SIM_SALT: u64 = 0x51D_7E57;

    /// Salt for the per-(round, client) churn trace (whole-round
    /// absences, [`crate::sim::is_available`]) — a *separate* stream
    /// from [`SIM_SALT`] so enabling churn never perturbs the mid-round
    /// drop/deadline draws of existing scenarios.
    pub const CHURN_SALT: u64 = 0xC4_0E11;

    /// Salt for the async engine's per-dispatch timeline trace
    /// (`fed::engine`). Keyed by the monotone *dispatch sequence* rather
    /// than the round number, so a client redispatched after a drop
    /// draws a fresh timeline instead of replaying the identical
    /// failure — and so the sync engine's [`SIM_SALT`] streams are
    /// untouched by the async path.
    pub const ASYNC_SIM_SALT: u64 = 0xA51_C51D;

    /// Salt for the async engine's Poisson arrival draws
    /// ([`crate::sim::arrival_delay_ms`]) — its own stream so turning
    /// arrival jitter on or off never perturbs the dispatch timeline
    /// draws.
    pub const ARRIVAL_SALT: u64 = 0xA88_14A1;

    /// Stream salt of the keyed edge-aggregator assignment
    /// ([`crate::sim::edge_of`]) — the same SplitMix64-hash idiom as
    /// [`PROFILE_SALT`] in its own domain, so partitioning a population
    /// across edges never perturbs the profile, drop, churn or arrival
    /// streams.
    pub const EDGE_SALT: u64 = 0xED6E_0F;

    /// Stream salt of the per-(round, edge) whole-aggregator failure
    /// trace ([`crate::sim::edge_failed`]) — separate from [`EDGE_SALT`]
    /// so the assignment and the failure draws stay decorrelated.
    pub const EDGE_FAIL_SALT: u64 = 0xED6E_FA11;

    /// Stream salt of the lazy per-client tier draw
    /// ([`crate::sim::Scenario::profile_of`]) — its own domain,
    /// decorrelated from the materialized shuffle stream
    /// ([`ASSIGN_SALT`]), the drop trace ([`SIM_SALT`]) and the churn
    /// trace ([`CHURN_SALT`]).
    pub const PROFILE_SALT: u64 = 0x9_0F11E_0F;

    /// Seed-era salt of the materialized resource-assignment shuffle
    /// ([`crate::sim::Scenario::sample_profiles`], historically inlined
    /// as `seed ^ 0x4E50_11` in `assign_resources`): one shuffle of
    /// `0..k` drawn from `seed ^ ASSIGN_SALT` decides tier membership,
    /// byte-for-byte the seed repo's High/Low stream.
    pub const ASSIGN_SALT: u64 = 0x4E50_11;

    /// Stream salt of the lazy per-client shard draw
    /// (`fed::population`) — its own domain, decorrelated from the
    /// profile draw ([`PROFILE_SALT`]) and every round trace.
    pub const SHARD_SALT: u64 = 0x5AD_D47A;

    /// Stream salt of the wide (fleet-scale) per-(round, client) RNG
    /// derivation ([`crate::fed::client::round_client_rng`]),
    /// decorrelating it from any value the compact linear packing can
    /// reach.
    pub const WIDE_STREAM_SALT: u64 = 0xF1EE7_5CA1E;

    /// Domain salt of the wide (fleet-scale) seed derivation
    /// (`zo::SeedIssuer::seed`), keeping it off every value the compact
    /// 24/24/16 packing can produce.
    pub const WIDE_ISSUER_SALT: u64 = 0xF1EE7_15_5EED;

    /// Every registered salt as `(name, value)` — the surface the
    /// pairwise-distinctness test (and `detlint`'s registry check)
    /// walks; keep it in sync when registering a new salt.
    pub const ALL: [(&str, u64); 12] = [
        ("LANE_KEY_SALT", LANE_KEY_SALT),
        ("SIM_SALT", SIM_SALT),
        ("CHURN_SALT", CHURN_SALT),
        ("ASYNC_SIM_SALT", ASYNC_SIM_SALT),
        ("ARRIVAL_SALT", ARRIVAL_SALT),
        ("EDGE_SALT", EDGE_SALT),
        ("EDGE_FAIL_SALT", EDGE_FAIL_SALT),
        ("PROFILE_SALT", PROFILE_SALT),
        ("ASSIGN_SALT", ASSIGN_SALT),
        ("SHARD_SALT", SHARD_SALT),
        ("WIDE_STREAM_SALT", WIDE_STREAM_SALT),
        ("WIDE_ISSUER_SALT", WIDE_ISSUER_SALT),
    ];
}

pub use salts::LANE_KEY_SALT;

/// Derive `lanes` independent generator keys for one perturbation seed —
/// the keying step of the lane-parallel ZOUPDATE kernel. Mirrors the
/// Pallas exemplar's seed → PRNGKey → bits flow
/// (`python/compile/kernels/perturb.py`): one SplitMix64 chain keyed by
/// `seed ^ LANE_KEY_SALT`, one draw per lane, each draw seeding its own
/// [`Xoshiro256`]. SplitMix64 steps are a bijection, so lane keys never
/// collide within a seed; the salt decorrelates lane 0's generator from
/// the scalar kernel's `seed_from(seed)` state (the two kernels must not
/// share prefixes — they are *different* perturbation streams).
pub fn lane_keys(seed: u64, lanes: usize) -> Vec<u64> {
    let mut sm = SplitMix64(seed ^ LANE_KEY_SALT);
    (0..lanes).map(|_| sm.next_u64()).collect()
}

/// The seeded perturbation stream of the SPSA protocol (§3.1).
///
/// `Rademacher`: ±τ with equal probability — the paper's preferred,
/// lower-variance choice (Table 6). `Gaussian`: τ·N(0,1), kept as the
/// ablation baseline. Every consumer regenerates the identical stream from
/// the same `(seed, tau)` pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    Rademacher,
    Gaussian,
}

impl Distribution {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rademacher" => Some(Self::Rademacher),
            "gaussian" => Some(Self::Gaussian),
            _ => None,
        }
    }
}

/// Stream of perturbation components z_i for one seed.
pub struct PerturbStream {
    rng: Xoshiro256,
    tau: f32,
    dist: Distribution,
    /// 64-bit buffer for Rademacher: one next_u64 yields 64 signs.
    bits: u64,
    left: u32,
}

impl PerturbStream {
    pub fn new(seed: u64, tau: f32, dist: Distribution) -> Self {
        Self {
            rng: Xoshiro256::seed_from(seed),
            tau,
            dist,
            bits: 0,
            left: 0,
        }
    }

    #[inline]
    pub fn next(&mut self) -> f32 {
        match self.dist {
            Distribution::Rademacher => {
                if self.left == 0 {
                    self.bits = self.rng.next_u64();
                    self.left = 64;
                }
                let sign = 1.0 - 2.0 * (self.bits & 1) as f32;
                self.bits >>= 1;
                self.left -= 1;
                self.tau * sign
            }
            Distribution::Gaussian => self.tau * self.rng.normal() as f32,
        }
    }

    /// Fill a whole z-vector (used by tests & the host-side axpy fast path).
    pub fn fill(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.next();
        }
    }

    /// Fused `w[i] += coeff * z_i` over a whole slice — the ZOUPDATE hot
    /// loop (§Perf L3). Rademacher fast path: one `next_u64` yields 64
    /// signs applied branchlessly by XOR-ing the f32 sign bit, consuming
    /// bits LSB-first exactly like [`Self::next`]. Must only be called on
    /// a fresh stream (callers construct one per (seed, coeff) pair).
    pub fn axpy(&mut self, w: &mut [f32], coeff: f32) {
        match self.dist {
            Distribution::Rademacher => {
                debug_assert_eq!(self.left, 0, "axpy requires a fresh stream");
                let ct = coeff * self.tau;
                let ct_bits = ct.to_bits();
                let mut chunks = w.chunks_exact_mut(64);
                for chunk in &mut chunks {
                    let mut bits = self.rng.next_u64();
                    // bit set -> -ct (sign-bit flip), matching next().
                    // (an indexed `bits >> j` variant benched 15% slower —
                    // EXPERIMENTS.md §Perf iteration log)
                    for x in chunk.iter_mut() {
                        *x += f32::from_bits(ct_bits ^ (((bits & 1) as u32) << 31));
                        bits >>= 1;
                    }
                }
                let rem = chunks.into_remainder();
                if !rem.is_empty() {
                    let mut bits = self.rng.next_u64();
                    for x in rem.iter_mut() {
                        *x += f32::from_bits(ct_bits ^ (((bits & 1) as u32) << 31));
                        bits >>= 1;
                    }
                }
            }
            Distribution::Gaussian => {
                for x in w.iter_mut() {
                    *x += coeff * self.next();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 (Vigna's splitmix64.c).
        let mut sm = SplitMix64(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(1);
        let mut c = Xoshiro256::seed_from(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_alpha_controls_skew() {
        let mut r = Xoshiro256::seed_from(6);
        let p = r.dirichlet(0.1, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // alpha=0.1 should be skewed: max component dominates
        let trials: Vec<f64> = (0..200)
            .map(|_| {
                let p = r.dirichlet(0.1, 10);
                p.iter().cloned().fold(0.0, f64::max)
            })
            .collect();
        let mean_max = trials.iter().sum::<f64>() / trials.len() as f64;
        assert!(mean_max > 0.5, "alpha=0.1 should concentrate: {mean_max}");
        let trials: Vec<f64> = (0..200)
            .map(|_| {
                let p = r.dirichlet(100.0, 10);
                p.iter().cloned().fold(0.0, f64::max)
            })
            .collect();
        let mean_max = trials.iter().sum::<f64>() / trials.len() as f64;
        assert!(mean_max < 0.2, "alpha=100 should be flat: {mean_max}");
    }

    #[test]
    fn choose_sparse_matches_dense_reference() {
        // the streaming sampler's contract: identical draw consumption
        // and identical output to the seed repo's dense partial
        // Fisher-Yates, for every (n, m) — including m == n and m == 0
        let dense = |rng: &mut Xoshiro256, n: usize, m: usize| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..m {
                let j = i + rng.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(m);
            idx
        };
        for (n, m) in [(1usize, 1usize), (20, 5), (20, 20), (50, 0), (1000, 64), (7, 6)] {
            for seed in 0..8u64 {
                let mut a = Xoshiro256::seed_from(seed);
                let mut b = Xoshiro256::seed_from(seed);
                assert_eq!(a.choose(n, m), dense(&mut b, n, m), "n={n} m={m} seed={seed}");
                // and the streams stay aligned afterwards
                assert_eq!(a.next_u64(), b.next_u64(), "n={n} m={m} seed={seed}");
            }
        }
        // O(sampled) at fleet scale: a 10^7 id space must not be
        // materialized (this would OOM-or-crawl if it were)
        let mut r = Xoshiro256::seed_from(3);
        let picks = r.choose(10_000_000, 64);
        assert_eq!(picks.len(), 64);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 64);
        assert!(picks.iter().all(|&p| p < 10_000_000));
    }

    #[test]
    fn choose_distinct_in_range() {
        let mut r = Xoshiro256::seed_from(7);
        for _ in 0..100 {
            let picks = r.choose(20, 8);
            assert_eq!(picks.len(), 8);
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "duplicates in {picks:?}");
            assert!(picks.iter().all(|&p| p < 20));
        }
    }

    #[test]
    fn rademacher_stream_is_pm_tau_and_balanced() {
        let mut s = PerturbStream::new(9, 0.75, Distribution::Rademacher);
        let mut z = vec![0.0f32; 100_000];
        s.fill(&mut z);
        assert!(z.iter().all(|&v| v == 0.75 || v == -0.75));
        let mean: f64 = z.iter().map(|&v| v as f64).sum::<f64>() / z.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_stream_scaled() {
        let mut s = PerturbStream::new(10, 0.5, Distribution::Gaussian);
        let mut z = vec![0.0f32; 100_000];
        s.fill(&mut z);
        let var: f64 = z.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / z.len() as f64;
        assert!((var - 0.25).abs() < 0.01, "var {var}");
    }

    #[test]
    fn axpy_fast_path_matches_next_semantics() {
        // the branchless path must consume the identical bit sequence as
        // the scalar next() path — self-consistency of the seed protocol.
        for d in [1usize, 63, 64, 65, 1000] {
            let mut a = vec![0.0f32; d];
            let mut b = vec![0.0f32; d];
            PerturbStream::new(5, 0.75, Distribution::Rademacher).axpy(&mut a, 2.0);
            let mut s = PerturbStream::new(5, 0.75, Distribution::Rademacher);
            for x in b.iter_mut() {
                *x += 2.0 * s.next();
            }
            assert_eq!(a, b, "d={d}");
        }
        // gaussian path too
        let mut a = vec![0.0f32; 257];
        let mut b = vec![0.0f32; 257];
        PerturbStream::new(6, 0.5, Distribution::Gaussian).axpy(&mut a, 1.5);
        let mut s = PerturbStream::new(6, 0.5, Distribution::Gaussian);
        for x in b.iter_mut() {
            *x += 1.5 * s.next();
        }
        assert_eq!(a, b);
    }

    #[test]
    fn perturb_stream_reproducible_across_instances() {
        // the protocol invariant: seed fully determines z
        let mut a = PerturbStream::new(42, 0.75, Distribution::Rademacher);
        let mut b = PerturbStream::new(42, 0.75, Distribution::Rademacher);
        for _ in 0..1000 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn discard_matches_manual_draws() {
        let mut a = Xoshiro256::seed_from(21);
        let mut b = Xoshiro256::seed_from(21);
        for _ in 0..137 {
            a.next_u64();
        }
        b.discard(137);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Xoshiro256::seed_from(21);
        c.discard(0);
        assert_eq!(c.next_u64(), Xoshiro256::seed_from(21).next_u64());
    }

    #[test]
    fn discard_jump_matches_loop_across_the_threshold() {
        // the O(log n) jump must be bit-identical to the draw loop right
        // where discard() switches implementations
        for n in [
            JUMP_MIN_DRAWS - 1,
            JUMP_MIN_DRAWS,
            JUMP_MIN_DRAWS + 1,
            3 * JUMP_MIN_DRAWS + 17,
        ] {
            let mut a = Xoshiro256::seed_from(5);
            let mut b = Xoshiro256::seed_from(5);
            for _ in 0..n {
                a.next_u64();
            }
            b.discard(n);
            assert_eq!(a.s, b.s, "state diverged at n={n}");
            assert_eq!(a.next_u64(), b.next_u64(), "n={n}");
        }
    }

    #[test]
    fn discard_large_offset_matches_loop() {
        // satellite: the last shard worker at d=11M discards millions of
        // draws — the jump path must reproduce the loop's state exactly
        // at that scale, and compose additively
        let n: u64 = 4_600_000 + 37;
        let mut a = Xoshiro256::seed_from(99);
        let mut b = Xoshiro256::seed_from(99);
        for _ in 0..n {
            a.next_u64();
        }
        b.discard(n);
        assert_eq!(a.s, b.s);
        for _ in 0..4 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // discard(x); discard(y) == discard(x + y), mixing both paths
        let mut c = Xoshiro256::seed_from(99);
        let mut d = Xoshiro256::seed_from(99);
        c.discard(1_000_000);
        c.discard(17); // loop path on top of the jump path
        c.discard(3_600_000 + 20);
        d.discard(n);
        assert_eq!(c.s, d.s);
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn lane_keys_deterministic_distinct_and_salted() {
        // the lanes-kernel keying contract: reproducible per seed,
        // pairwise-distinct within a seed, disjoint across seeds, and a
        // strict prefix relation between lane counts (lane j's key does
        // not depend on how many lanes follow it).
        for seed in [0u64, 1, 7, u64::MAX] {
            let k4 = lane_keys(seed, 4);
            assert_eq!(k4, lane_keys(seed, 4));
            let k8 = lane_keys(seed, 8);
            assert_eq!(&k8[..4], &k4[..]);
            let mut sorted = k8.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "lane-key collision for seed {seed}");
        }
        assert_ne!(lane_keys(1, 4), lane_keys(2, 4));
        // the salt keeps lane 0 off the scalar kernel's stream: seeding
        // from key 0 must not reproduce seed_from(seed)'s first draw
        let k = lane_keys(42, 1)[0];
        assert_ne!(
            Xoshiro256::seed_from(k).next_u64(),
            Xoshiro256::seed_from(42).next_u64()
        );
    }

    #[test]
    fn registered_salts_are_pairwise_distinct() {
        // a colliding pair would silently merge two streams every
        // determinism argument assumes are decorrelated — the registry
        // contract (DESIGN.md §14; `detlint` re-checks this from source)
        for (i, (name_a, a)) in salts::ALL.iter().enumerate() {
            for (name_b, b) in &salts::ALL[i + 1..] {
                assert_ne!(a, b, "salt collision: {name_a} == {name_b}");
            }
        }
        // and ALL actually covers the registry's re-exported anchors
        assert!(salts::ALL.iter().any(|&(n, v)| n == "LANE_KEY_SALT" && v == LANE_KEY_SALT));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
