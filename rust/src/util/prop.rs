//! Mini property-testing substrate (proptest is unavailable offline).
//!
//! [`run_prop`] draws `cases` random inputs from a generator closure and
//! checks an invariant; on failure it retries with progressively "smaller"
//! regenerated cases (size-bounded shrinking-lite) and reports the smallest
//! failing seed so the case is reproducible.

use super::rng::Xoshiro256;

/// Size hint passed to generators: shrink attempts re-draw at smaller size.
#[derive(Clone, Copy, Debug)]
pub struct Gen<'a> {
    pub size: usize,
    pub seed: u64,
    _marker: std::marker::PhantomData<&'a ()>,
}

impl<'a> Gen<'a> {
    pub fn rng(&self) -> Xoshiro256 {
        Xoshiro256::seed_from(self.seed)
    }
}

/// Run `cases` random trials of `check(gen)`; `check` returns Err(msg) on
/// invariant violation. Panics with the reproducing seed on failure.
pub fn run_prop<F>(name: &str, cases: usize, mut check: F)
where
    F: FnMut(Gen) -> Result<(), String>,
{
    // Fixed base seed: deterministic CI. Override with PROP_SEED for fuzzing.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let full = Gen {
            size: 100,
            seed,
            _marker: std::marker::PhantomData,
        };
        if let Err(msg) = check(full) {
            // shrinking-lite: re-draw the same seed at smaller sizes and
            // report the smallest size that still fails.
            let mut smallest = (full.size, msg.clone());
            for size in [50, 20, 10, 5, 2, 1] {
                let g = Gen {
                    size,
                    seed,
                    _marker: std::marker::PhantomData,
                };
                if let Err(m) = check(g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, \
                 smallest failing size {}): {}\n\
                 reproduce with PROP_SEED={base} and this case index",
                smallest.0, smallest.1,
            );
        }
    }
}

/// Convenience: a random f32 vector of length up to `g.size * scale`.
pub fn vec_f32(g: &Gen, scale: usize, lo: f32, hi: f32) -> Vec<f32> {
    let mut rng = g.rng();
    let n = 1 + rng.below(g.size.max(1) * scale.max(1));
    (0..n).map(|_| lo + (hi - lo) * rng.next_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("sum_commutes", 25, |g| {
            count += 1;
            let xs = vec_f32(&g, 2, -1.0, 1.0);
            let fwd: f32 = xs.iter().sum();
            let rev: f32 = xs.iter().rev().sum();
            if (fwd - rev).abs() < 1e-3 {
                Ok(())
            } else {
                Err(format!("{fwd} vs {rev}"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        run_prop("always_fails", 3, |_g| Err("nope".into()));
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let g = Gen {
            size: 10,
            seed: 7,
            _marker: std::marker::PhantomData,
        };
        assert_eq!(vec_f32(&g, 1, 0.0, 1.0), vec_f32(&g, 1, 0.0, 1.0));
    }
}
