//! Minimal JSON substrate (serde is unavailable offline; DESIGN.md §2).
//!
//! Parses the full JSON grammar into a [`Json`] tree and serializes back.
//! Used for `artifacts/manifest.json`, config files and metrics output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers are kept as f64 (sufficient here: the
/// manifest's largest integers are parameter offsets ≪ 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ----- typed accessors ---------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that returns a descriptive error (for manifest/config loading).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----- builders ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs are out of scope for our data
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m":{"dim":175258,"arr":[1,2.5,-3],"s":"he\"llo","n":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"models":{"lm":{"dim":79424,"batch":16,
            "params":[{"name":"embed","shape":[64,64],"offset":0,"size":4096,
            "fan_in":64,"kind":"embed","fill":0.0}]}}}"#;
        let v = Json::parse(src).unwrap();
        let lm = v.get("models").unwrap().get("lm").unwrap();
        assert_eq!(lm.get("dim").unwrap().as_usize(), Some(79424));
        assert_eq!(
            lm.get("params").unwrap().as_arr().unwrap()[0]
                .get("kind")
                .unwrap()
                .as_str(),
            Some("embed")
        );
    }
}
