//! Scoped parallel-map substrate (tokio/rayon unavailable offline).
//!
//! Client-local computations inside a federated round are independent, so
//! the server fans them out with `parallel_map`. On a 1-core testbed this
//! degrades gracefully to the sequential path (thread overhead avoided).

/// Number of worker threads to use (respects `ZOWARMUP_THREADS`).
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("ZOWARMUP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` preserving order, using scoped threads when more
/// than one worker is available and the job count warrants it.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = worker_count();
    if workers <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // Work queue: (index, item) pairs pulled by workers via a mutex.
    let queue = std::sync::Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let slots_ref = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item);
                        slots_ref.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(Vec::<i32>::new(), |x| x), Vec::<i32>::new());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn respects_env_override() {
        // worker_count is advisory; just exercise the parse path
        assert!(worker_count() >= 1);
    }
}
