//! Scoped parallel-map substrate (tokio/rayon unavailable offline).
//!
//! Client-local computations inside a federated round are independent, so
//! the server fans them out with [`parallel_map_n`]. On a 1-core testbed
//! this degrades gracefully to the sequential path (thread overhead
//! avoided).
//!
//! ## Determinism contract
//!
//! `parallel_map_n` preserves item order in its output regardless of the
//! worker count or scheduling, so any caller that (a) derives all
//! per-item randomness *before* the fan-out and (b) folds results back in
//! item order produces bit-identical state for every worker count. The
//! federated round engines (`fed::server`, `baselines::*`) are built on
//! exactly this contract — see the crate-level "Threading model" docs.

/// Number of worker threads to use (respects `ZOWARMUP_THREADS`).
///
/// An unparseable override is ignored with a one-time stderr warning
/// naming the offending value — silently falling back to autodetect made
/// `ZOWARMUP_THREADS=four` indistinguishable from no override at all.
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("ZOWARMUP_THREADS") {
        match v.parse::<usize>() {
            Ok(n) => return n.max(1),
            Err(_) => warn_bad_threads_once(&v),
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

fn warn_bad_threads_once(value: &str) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "warning: ignoring unparseable ZOWARMUP_THREADS={value:?} \
             (expected a thread count); using available parallelism"
        );
    });
}

/// Resolve a config-level thread count: `0` means "auto" (the
/// `ZOWARMUP_THREADS` env override, else the machine's parallelism).
pub fn resolve_workers(threads: usize) -> usize {
    if threads == 0 {
        worker_count()
    } else {
        threads
    }
}

/// Map `f` over `items` preserving order with an explicit worker count.
/// `workers <= 1` (or a single item) runs inline on the calling thread.
pub fn parallel_map_n<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if workers <= 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    // Work queue: (index, item) pairs pulled by workers via a mutex.
    let queue = std::sync::Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
    let slots_ref = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    None => break,
                    Some((i, item)) => {
                        let r = f(item);
                        slots_ref.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker died")).collect()
}

/// Map `f` over `items` preserving order, using scoped threads when more
/// than one worker is available and the job count warrants it.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_n(worker_count(), items, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..100).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn explicit_worker_counts_agree() {
        let items: Vec<i32> = (0..57).collect();
        let seq = parallel_map_n(1, items.clone(), |x| x * x - 3);
        for w in [2, 3, 8] {
            assert_eq!(parallel_map_n(w, items.clone(), |x| x * x - 3), seq);
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(parallel_map(Vec::<i32>::new(), |x| x), Vec::<i32>::new());
        assert_eq!(parallel_map(vec![7], |x| x + 1), vec![8]);
        assert_eq!(parallel_map_n(4, Vec::<i32>::new(), |x| x), Vec::<i32>::new());
    }

    #[test]
    fn respects_env_override() {
        // worker_count is advisory; just exercise the parse path
        assert!(worker_count() >= 1);
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }

    #[test]
    fn env_override_parse_paths() {
        // single test covering both parse outcomes sequentially — the
        // env var is process-global, so splitting these across tests
        // would race. Every other test here is count-agnostic by design.
        let prev = std::env::var("ZOWARMUP_THREADS").ok();
        std::env::set_var("ZOWARMUP_THREADS", "5");
        assert_eq!(resolve_workers(0), 5, "valid override drives auto");
        assert_eq!(resolve_workers(2), 2, "explicit count beats the env");
        std::env::set_var("ZOWARMUP_THREADS", "not-a-number");
        // unparseable: warned once on stderr, falls back to autodetect
        assert!(resolve_workers(0) >= 1);
        std::env::set_var("ZOWARMUP_THREADS", "0");
        assert_eq!(resolve_workers(0), 1, "0 clamps to 1, not autodetect");
        match prev {
            Some(v) => std::env::set_var("ZOWARMUP_THREADS", v),
            None => std::env::remove_var("ZOWARMUP_THREADS"),
        }
    }

    #[test]
    fn fallible_jobs_surface_errors_in_order() {
        let out: Vec<Result<i32, String>> = parallel_map_n(
            4,
            (0..20).collect::<Vec<i32>>(),
            |x| if x == 13 { Err(format!("bad {x}")) } else { Ok(x) },
        );
        assert_eq!(out.len(), 20);
        assert_eq!(out[13], Err("bad 13".to_string()));
        assert_eq!(out[12], Ok(12));
    }
}
