//! Summary statistics for experiment tables ("mean(std) over seeds") and
//! the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator, like the paper's tables).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// p in [0,1]; linear interpolation on the sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = p.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

/// The paper's table cell format: "54.3(4.8)".
pub fn mean_std_cell(xs: &[f64]) -> String {
    format!("{:.1}({:.1})", mean(xs), std_dev(xs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
    }

    #[test]
    fn cell_format() {
        assert_eq!(mean_std_cell(&[54.0, 55.0, 53.0]), "54.0(1.0)");
    }
}
