//! Summary statistics for experiment tables ("mean(std) over seeds") and
//! the bench harness.
//!
//! Every reduction here honors the `finite_signal` contract the CSV
//! summaries rely on: empty (or all-NaN) input yields 0.0, never ±inf or
//! NaN, and NaN samples are filtered rather than poisoning the reduction
//! (the old `min`/`max` returned ±inf on empty input and `percentile`
//! panicked on NaN via `partial_cmp().unwrap()`).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator, like the paper's tables).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Minimum over the non-NaN samples; 0.0 when none remain.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .fold(None, |m: Option<f64>, x| {
            Some(match m {
                None => x,
                Some(m) => m.min(x),
            })
        })
        .unwrap_or(0.0)
}

/// Maximum over the non-NaN samples; 0.0 when none remain.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .fold(None, |m: Option<f64>, x| {
            Some(match m {
                None => x,
                Some(m) => m.max(x),
            })
        })
        .unwrap_or(0.0)
}

/// p in [0,1]; linear interpolation on the sorted copy of the non-NaN
/// samples (0.0 when none remain).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if s.is_empty() {
        return 0.0;
    }
    s.sort_by(f64::total_cmp);
    let pos = p.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

/// The paper's table cell format: "54.3(4.8)".
pub fn mean_std_cell(xs: &[f64]) -> String {
    format!("{:.1}({:.1})", mean(xs), std_dev(xs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
    }

    #[test]
    fn min_max_empty_is_finite_zero() {
        // the old fold identities leaked ±inf into CSV summaries
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert!(min(&[]).is_finite());
        assert!(max(&[]).is_finite());
        assert_eq!(min(&[3.0, -1.0, 2.0]), -1.0);
        assert_eq!(max(&[3.0, -1.0, 2.0]), 3.0);
        assert_eq!(min(&[5.0]), 5.0);
        assert_eq!(max(&[5.0]), 5.0);
    }

    #[test]
    fn min_max_filter_nan() {
        assert_eq!(min(&[f64::NAN, 2.0, 1.0]), 1.0);
        assert_eq!(max(&[2.0, f64::NAN, 1.0]), 2.0);
        // all-NaN behaves like empty
        assert_eq!(min(&[f64::NAN, f64::NAN]), 0.0);
        assert_eq!(max(&[f64::NAN]), 0.0);
    }

    #[test]
    fn percentile_is_nan_safe() {
        // the old sort_by(partial_cmp().unwrap()) panicked on NaN
        let xs = [1.0, f64::NAN, 3.0, 2.0, f64::NAN, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
        assert_eq!(percentile(&[f64::NAN], 0.5), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
    }

    #[test]
    fn cell_format() {
        assert_eq!(mean_std_cell(&[54.0, 55.0, 53.0]), "54.0(1.0)");
    }
}
