//! In-repo substrates replacing crates unavailable in the offline build
//! sandbox (DESIGN.md §2): RNG, JSON, CLI parsing, CSV, stats, a bench
//! harness and a mini property-test runner.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
