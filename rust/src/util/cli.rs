//! Tiny CLI argument substrate (clap is unavailable offline; DESIGN.md §2).
//!
//! Grammar: `zowarmup <subcommand> [--key value]... [--flag]...`.
//! Unknown keys are an error — typos in experiment sweeps must not silently
//! fall back to defaults.

use std::collections::BTreeMap;

/// Parsed command line: one positional subcommand plus `--key value` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let (k, v) = if let Some((k, v)) = key.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    (key.to_string(), argv[i].clone())
                } else {
                    (key.to_string(), "true".to_string()) // bare flag
                };
                if out.kv.insert(k.clone(), v).is_some() {
                    anyhow::bail!("duplicate flag --{k}");
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => anyhow::bail!("--{key} expects true/false, got {v:?}"),
        }
    }

    /// Comma-separated list, e.g. `--splits 10,30,50`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Call after all `get`s: errors on flags nobody consumed (typo guard).
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .filter(|k| !consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flag(s): {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_and_flags() {
        let a = Args::parse(&argv("exp table2 --seeds 3 --scale=smoke --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.usize_or("seeds", 1).unwrap(), 3);
        assert_eq!(a.str_or("scale", "default"), "smoke");
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn defaults_and_type_errors() {
        let a = Args::parse(&argv("train --lr abc")).unwrap();
        assert_eq!(a.usize_or("rounds", 7).unwrap(), 7);
        assert!(a.f64_or("lr", 0.1).is_err());
    }

    #[test]
    fn duplicate_flags_rejected() {
        assert!(Args::parse(&argv("x --a 1 --a 2")).is_err());
    }

    #[test]
    fn unknown_flag_guard() {
        let a = Args::parse(&argv("train --rounds 5 --typo 1")).unwrap();
        let _ = a.usize_or("rounds", 0).unwrap();
        assert!(a.reject_unknown().is_err());
        let b = Args::parse(&argv("train --rounds 5")).unwrap();
        let _ = b.usize_or("rounds", 0).unwrap();
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn lists() {
        let a = Args::parse(&argv("x --splits 10,30,50")).unwrap();
        assert_eq!(a.list_or("splits", &[]), vec!["10", "30", "50"]);
        let b = Args::parse(&argv("x")).unwrap();
        assert_eq!(b.list_or("splits", &["90"]), vec!["90"]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = Args::parse(&argv("x --lr -0.5")).unwrap();
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }
}
