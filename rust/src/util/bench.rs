//! Micro/endto-end bench harness (criterion is unavailable offline).
//!
//! Usage from a `[[bench]] harness = false` target:
//! ```no_run
//! use zowarmup::util::bench::Bench;
//! let mut b = Bench::new("rademacher_axpy");
//! b.iter("d=175k", || { /* work */ });
//! b.report();
//! ```
//! Warms up, then runs timed batches until both a minimum wall time and a
//! minimum iteration count are reached; reports mean/p50/p95 per iteration.

use std::time::{Duration, Instant};

use super::stats;

/// One measured case inside a bench group.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional user-provided throughput denominator (items per iter)
    pub items_per_iter: f64,
}

impl Measurement {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            return 0.0;
        }
        self.items_per_iter * 1e9 / self.mean_ns
    }
}

/// A named group of measurements with a shared time budget per case.
pub struct Bench {
    pub group: String,
    pub min_time: Duration,
    pub min_iters: usize,
    pub warmup_iters: usize,
    pub results: Vec<Measurement>,
}

/// True when `ZOWARMUP_BENCH_QUICK` is set (non-empty, not "0"): the CI
/// bench-smoke mode — tiny time budgets, and the bench mains skip their
/// ResNet-scale cases so the whole suite runs in seconds. Quick numbers
/// are for trajectory tracking, not absolute comparison.
pub fn quick() -> bool {
    std::env::var("ZOWARMUP_BENCH_QUICK").map_or(false, |v| !v.is_empty() && v != "0")
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let mut b = Self {
            group: group.to_string(),
            min_time: Duration::from_millis(300),
            min_iters: 10,
            warmup_iters: 2,
            results: Vec::new(),
        };
        if quick() {
            b.min_time = Duration::from_millis(10);
            b.min_iters = 3;
            b.warmup_iters = 1;
        }
        b
    }

    /// Quick preset for expensive end-to-end cases.
    pub fn slow(group: &str) -> Self {
        let mut b = Self::new(group);
        b.min_time = Duration::from_millis(0);
        b.min_iters = 3;
        b.warmup_iters = 1;
        b
    }

    pub fn iter<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.iter_with_items(name, 1.0, f)
    }

    /// `items` feeds the throughput column (e.g. parameters touched).
    pub fn iter_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples_ns.len() < 10_000)
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 0.5),
            p95_ns: stats::percentile(&samples_ns, 0.95),
            items_per_iter: items,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Serialize the group's measurements as a JSON object — the
    /// machine-readable counterpart of [`Self::report`], consumed by the
    /// CI bench-smoke step and diffed against the committed
    /// `BENCH_baseline.json` so the perf trajectory is tracked, not
    /// anecdotal.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"group\": \"{}\",\n", self.group));
        out.push_str(&format!("  \"quick\": {},\n", quick()));
        out.push_str("  \"results\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"throughput_per_sec\": {:.1}}}{}\n",
                m.name,
                m.iters,
                m.mean_ns,
                m.p50_ns,
                m.p95_ns,
                m.throughput_per_sec(),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`Self::to_json`] to `path` (parent dirs created).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
    }

    /// Print a criterion-ish table to stdout.
    pub fn report(&self) {
        println!("\n== bench {} ==", self.group);
        println!(
            "{:<42} {:>8} {:>12} {:>12} {:>12} {:>14}",
            "case", "iters", "mean", "p50", "p95", "throughput/s"
        );
        for m in &self.results {
            println!(
                "{:<42} {:>8} {:>12} {:>12} {:>12} {:>14}",
                m.name,
                m.iters,
                fmt_ns(m.mean_ns),
                fmt_ns(m.p50_ns),
                fmt_ns(m.p95_ns),
                fmt_qty(m.throughput_per_sec()),
            );
        }
    }
}

/// Human duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Human quantity (1.2M, 3.4G, ...).
pub fn fmt_qty(q: f64) -> String {
    if q >= 1e9 {
        format!("{:.2}G", q / 1e9)
    } else if q >= 1e6 {
        format!("{:.2}M", q / 1e6)
    } else if q >= 1e3 {
        format!("{:.2}k", q / 1e3)
    } else {
        format!("{q:.1}")
    }
}

/// Guard against the optimizer deleting benched work (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test");
        b.min_time = Duration::from_millis(5);
        b.min_iters = 3;
        let m = b.iter_with_items("spin", 100.0, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.iters >= 3);
        assert!(m.mean_ns > 0.0);
        assert!(m.throughput_per_sec() > 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500_000.0), "1.50ms");
        assert_eq!(fmt_qty(2_000_000.0), "2.00M");
    }

    #[test]
    fn json_export_round_trips_through_parser() {
        let mut b = Bench::new("jgroup");
        b.min_time = Duration::from_millis(1);
        b.min_iters = 2;
        b.iter("case_a", || {
            black_box(1 + 1);
        });
        b.iter_with_items("case_b", 10.0, || {
            black_box(2 + 2);
        });
        let j = crate::util::json::Json::parse(&b.to_json()).unwrap();
        assert_eq!(j.get("group").and_then(|v| v.as_str()), Some("jgroup"));
        let results = j.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").and_then(|v| v.as_str()),
            Some("case_a")
        );
        assert!(results[1]
            .get("throughput_per_sec")
            .and_then(|v| v.as_f64())
            .unwrap()
            > 0.0);
        // and the file writer lands it on disk
        let path = std::env::temp_dir().join("zow_bench_json_test.json");
        b.write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        std::fs::remove_file(path).ok();
    }
}
