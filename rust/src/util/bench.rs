//! Micro/endto-end bench harness (criterion is unavailable offline).
//!
//! Usage from a `[[bench]] harness = false` target:
//! ```no_run
//! use zowarmup::util::bench::Bench;
//! let mut b = Bench::new("rademacher_axpy");
//! b.iter("d=175k", || { /* work */ });
//! b.report();
//! ```
//! Warms up, then runs timed batches until both a minimum wall time and a
//! minimum iteration count are reached; reports mean/p50/p95 per iteration.

use std::time::{Duration, Instant};

use super::stats;

/// One measured case inside a bench group.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// optional user-provided throughput denominator (items per iter)
    pub items_per_iter: f64,
}

impl Measurement {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            return 0.0;
        }
        self.items_per_iter * 1e9 / self.mean_ns
    }
}

/// A named group of measurements with a shared time budget per case.
pub struct Bench {
    pub group: String,
    pub min_time: Duration,
    pub min_iters: usize,
    pub warmup_iters: usize,
    pub results: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Self {
            group: group.to_string(),
            min_time: Duration::from_millis(300),
            min_iters: 10,
            warmup_iters: 2,
            results: Vec::new(),
        }
    }

    /// Quick preset for expensive end-to-end cases.
    pub fn slow(group: &str) -> Self {
        let mut b = Self::new(group);
        b.min_time = Duration::from_millis(0);
        b.min_iters = 3;
        b.warmup_iters = 1;
        b
    }

    pub fn iter<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.iter_with_items(name, 1.0, f)
    }

    /// `items` feeds the throughput column (e.g. parameters touched).
    pub fn iter_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        mut f: F,
    ) -> &Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples_ns.len() < 10_000)
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 0.5),
            p95_ns: stats::percentile(&samples_ns, 0.95),
            items_per_iter: items,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print a criterion-ish table to stdout.
    pub fn report(&self) {
        println!("\n== bench {} ==", self.group);
        println!(
            "{:<42} {:>8} {:>12} {:>12} {:>12} {:>14}",
            "case", "iters", "mean", "p50", "p95", "throughput/s"
        );
        for m in &self.results {
            println!(
                "{:<42} {:>8} {:>12} {:>12} {:>12} {:>14}",
                m.name,
                m.iters,
                fmt_ns(m.mean_ns),
                fmt_ns(m.p50_ns),
                fmt_ns(m.p95_ns),
                fmt_qty(m.throughput_per_sec()),
            );
        }
    }
}

/// Human duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Human quantity (1.2M, 3.4G, ...).
pub fn fmt_qty(q: f64) -> String {
    if q >= 1e9 {
        format!("{:.2}G", q / 1e9)
    } else if q >= 1e6 {
        format!("{:.2}M", q / 1e6)
    } else if q >= 1e3 {
        format!("{:.2}k", q / 1e3)
    } else {
        format!("{q:.1}")
    }
}

/// Guard against the optimizer deleting benched work (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test");
        b.min_time = Duration::from_millis(5);
        b.min_iters = 3;
        let m = b.iter_with_items("spin", 100.0, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.iters >= 3);
        assert!(m.mean_ns > 0.0);
        assert!(m.throughput_per_sec() > 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500_000.0), "1.50ms");
        assert_eq!(fmt_qty(2_000_000.0), "2.00M");
    }
}
