//! CSV writer for experiment outputs under `runs/` (plotting-friendly).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Append-only CSV file with a fixed header written on creation.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self {
            w,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.cols,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.w, "{}", escaped.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, fields: &[CsvField]) -> anyhow::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Typed field helper so call sites stay tidy.
pub enum CsvField {
    U(usize),
    F(f64),
    S(String),
}

impl std::fmt::Display for CsvField {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvField::U(v) => write!(f, "{v}"),
            CsvField::F(v) => write!(f, "{v:.6}"),
            CsvField::S(v) => write!(f, "{v}"),
        }
    }
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("zowarmup_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["round", "acc", "note"]).unwrap();
            w.row(&["1".into(), "0.5".into(), "plain".into()]).unwrap();
            w.row(&["2".into(), "0.6".into(), "has,comma".into()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "round,acc,note\n1,0.5,plain\n2,0.6,\"has,comma\"\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_arity() {
        let dir = std::env::temp_dir().join("zowarmup_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
