//! Build-time stub for the PJRT runtime, compiled when the `xla` cargo
//! feature is off (the default — the `xla` crate and its xla_extension
//! binaries are not fetchable in the offline build sandbox).
//!
//! The stub preserves the full public surface of [`Engine`] /
//! [`XlaBackend`] so every caller (CLI `train --backend xla`, `check`,
//! `exp fig5/table5`, benches, examples) type-checks unchanged; the only
//! reachable entrypoint, [`Engine::cpu`], fails with a clear message, so
//! XLA-dependent paths degrade to a runtime error instead of a compile
//! error. Rebuild with `--features xla` (after vendoring the `xla` crate
//! — see rust/Cargo.toml) for the real PJRT path.

use std::path::Path;
use std::sync::Arc;

use crate::model::backend::{Batch, LossSums, ModelBackend};
use crate::model::manifest::Manifest;
use crate::model::params::ParamVec;
use crate::util::rng::Distribution;

const MSG: &str = "zowarmup was built without the `xla` cargo feature; \
rebuild with `cargo build --features xla` (requires the vendored xla \
crate — see rust/Cargo.toml) to use the PJRT runtime";

/// Placeholder for a compiled PJRT executable handle.
pub struct Executable;

/// Stub PJRT engine: construction always fails, so the remaining methods
/// are unreachable by construction.
pub struct Engine {
    _unconstructible: (),
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Self> {
        anyhow::bail!(MSG)
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn compile(&self, _path: &Path) -> anyhow::Result<Arc<Executable>> {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn backend(&self, _manifest: &Manifest, _model: &str) -> anyhow::Result<XlaBackend<'_>> {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stub compiled-model backend (unreachable: only [`Engine::backend`]
/// constructs it).
pub struct XlaBackend<'e> {
    _engine: &'e Engine,
}

impl<'e> XlaBackend<'e> {
    pub fn zo_delta_fused(
        &self,
        _params: &ParamVec,
        _batch: &Batch,
        _seed: i32,
        _coeff: f32,
    ) -> anyhow::Result<f64> {
        unreachable!("stub XlaBackend cannot be constructed")
    }
}

impl<'e> ModelBackend for XlaBackend<'e> {
    fn dim(&self) -> usize {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn batch_size(&self) -> usize {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn fwd_loss(&self, _params: &ParamVec, _batch: &Batch) -> anyhow::Result<LossSums> {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn sgd_step(
        &self,
        _params: &mut ParamVec,
        _batch: &Batch,
        _lr: f32,
    ) -> anyhow::Result<LossSums> {
        unreachable!("stub XlaBackend cannot be constructed")
    }

    fn zo_delta(
        &self,
        _params: &ParamVec,
        _batch: &Batch,
        _seed: u64,
        _eps: f32,
        _tau: f32,
        _dist: Distribution,
    ) -> anyhow::Result<f64> {
        unreachable!("stub XlaBackend cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_fails_with_feature_hint() {
        let err = Engine::cpu().unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
        assert!(err.contains("feature"), "{err}");
    }
}
