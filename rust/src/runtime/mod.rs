//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! coordinator's hot path. This is the only module that touches the `xla`
//! crate. Python never runs here.
//!
//! Pattern (per /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — the crate's xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos (64-bit instruction ids).
//!
//! The real backend is gated behind the `xla` cargo feature (off by
//! default — the xla crate is not fetchable offline). Without it,
//! [`stub`] provides the identical public surface with a runtime error
//! from `Engine::cpu()`, so the linear-probe paths and tier-1 tests build
//! and run with zero external native dependencies.

#[cfg(feature = "xla")]
pub mod xla_backend;

#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
pub mod xla_backend;

pub use xla_backend::{Engine, XlaBackend};
