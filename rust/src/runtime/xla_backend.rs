//! `XlaBackend`: the production `ModelBackend` over compiled PJRT
//! executables, plus the `Engine` (client + executable cache).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use crate::model::backend::{Batch, BatchX, LossSums, ModelBackend};
use crate::model::manifest::{Manifest, ModelEntry};
use crate::model::params::ParamVec;

/// Convert the xla crate's error type (no std::error::Error impl needed).
fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Shared PJRT CPU client + a compile cache keyed by artifact path.
/// Compilation is the expensive one-time cost; executions are cheap and
/// reentrant.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT CPU client and its loaded executables are internally
// thread-safe — compilation is memoized behind the `cache` mutex and PJRT
// `Execute` is reentrant (the runtime takes no exclusive state per call;
// see the Engine docs above). The xla FFI wrappers only lack the auto
// markers because they hold opaque C++ pointers.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl<'e> Send for XlaBackend<'e> {}
unsafe impl<'e> Sync for XlaBackend<'e> {}

impl Engine {
    pub fn cpu() -> anyhow::Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(xerr)?,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact (memoized).
    pub fn compile(&self, path: &Path) -> anyhow::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&key).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp).map_err(xerr)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Build the `ModelBackend` for one manifest model.
    pub fn backend(&self, manifest: &Manifest, model: &str) -> anyhow::Result<XlaBackend<'_>> {
        let entry = manifest.model(model)?.clone();
        let fwd = self.compile(&entry.artifact_path(&manifest.dir, "fwd_loss")?)?;
        let sgd = self.compile(&entry.artifact_path(&manifest.dir, "sgd_step")?)?;
        let zo = match entry.artifacts.contains_key("zo_delta") {
            true => Some(self.compile(&entry.artifact_path(&manifest.dir, "zo_delta")?)?),
            false => None,
        };
        Ok(XlaBackend {
            _engine: self,
            entry,
            fwd,
            sgd,
            zo,
        })
    }
}

/// Compiled executables for one model variant.
pub struct XlaBackend<'e> {
    _engine: &'e Engine,
    pub entry: ModelEntry,
    fwd: std::sync::Arc<xla::PjRtLoadedExecutable>,
    sgd: std::sync::Arc<xla::PjRtLoadedExecutable>,
    zo: Option<std::sync::Arc<xla::PjRtLoadedExecutable>>,
}

fn dims_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&d| d as i64).collect()
}

impl<'e> XlaBackend<'e> {
    fn literal_params(&self, params: &ParamVec) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(
            params.dim() == self.entry.dim,
            "param dim {} != model dim {}",
            params.dim(),
            self.entry.dim
        );
        Ok(xla::Literal::vec1(&params.0))
    }

    fn literal_x(&self, batch: &Batch) -> anyhow::Result<xla::Literal> {
        let dims = dims_i64(&self.entry.input_shape);
        let lit = match (&batch.x, self.entry.kind.as_str()) {
            (BatchX::F32(v), "image") => {
                anyhow::ensure!(v.len() == self.entry.input_len(), "x len");
                xla::Literal::vec1(v).reshape(&dims).map_err(xerr)?
            }
            (BatchX::I32(v), "lm") => {
                anyhow::ensure!(v.len() == self.entry.input_len(), "x len");
                xla::Literal::vec1(v).reshape(&dims).map_err(xerr)?
            }
            _ => anyhow::bail!(
                "batch x type does not match model kind {:?}",
                self.entry.kind
            ),
        };
        Ok(lit)
    }

    fn literal_y_mask(&self, batch: &Batch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        let dims = dims_i64(&self.entry.mask_shape);
        anyhow::ensure!(batch.y.len() == self.entry.mask_len(), "y len");
        anyhow::ensure!(batch.mask.len() == self.entry.mask_len(), "mask len");
        let y = xla::Literal::vec1(&batch.y).reshape(&dims).map_err(xerr)?;
        let mask = xla::Literal::vec1(&batch.mask).reshape(&dims).map_err(xerr)?;
        Ok((y, mask))
    }

    fn exec(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(inputs).map_err(xerr)?;
        let lit = out[0][0].to_literal_sync().map_err(xerr)?;
        lit.to_tuple().map_err(xerr)
    }

    fn scalar_f32(lit: &xla::Literal) -> anyhow::Result<f64> {
        let v = lit.to_vec::<f32>().map_err(xerr)?;
        anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
        Ok(v[0] as f64)
    }

    /// The fused in-graph SPSA numerator (threefry z inside the artifact;
    /// Pallas perturb kernel). NOTE: its z differs from the host
    /// `PerturbStream`, so it pairs only with an in-graph update — it is
    /// exposed for the §Perf graph-vs-host comparison, not the default
    /// protocol (see DESIGN.md §6).
    pub fn zo_delta_fused(
        &self,
        params: &ParamVec,
        batch: &Batch,
        seed: i32,
        coeff: f32,
    ) -> anyhow::Result<f64> {
        let zo = self
            .zo
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("model {} has no zo_delta artifact", self.entry.name))?;
        let (y, mask) = self.literal_y_mask(batch)?;
        let outs = self.exec(
            zo,
            &[
                self.literal_params(params)?,
                xla::Literal::scalar(seed),
                xla::Literal::scalar(coeff),
                self.literal_x(batch)?,
                y,
                mask,
            ],
        )?;
        Self::scalar_f32(&outs[0])
    }
}

impl<'e> ModelBackend for XlaBackend<'e> {
    fn dim(&self) -> usize {
        self.entry.dim
    }

    fn batch_size(&self) -> usize {
        self.entry.batch
    }

    fn cost_model(&self) -> crate::comm::CostModel {
        crate::comm::CostModel::from_manifest(&self.entry)
    }

    fn fwd_loss(&self, params: &ParamVec, batch: &Batch) -> anyhow::Result<LossSums> {
        let (y, mask) = self.literal_y_mask(batch)?;
        let outs = self.exec(
            &self.fwd,
            &[self.literal_params(params)?, self.literal_x(batch)?, y, mask],
        )?;
        anyhow::ensure!(outs.len() == 2, "fwd_loss returns 2 outputs");
        Ok(LossSums {
            loss_sum: Self::scalar_f32(&outs[0])?,
            correct: Self::scalar_f32(&outs[1])?,
            count: batch.real_count(),
        })
    }

    fn sgd_step(
        &self,
        params: &mut ParamVec,
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<LossSums> {
        let (y, mask) = self.literal_y_mask(batch)?;
        let outs = self.exec(
            &self.sgd,
            &[
                self.literal_params(params)?,
                self.literal_x(batch)?,
                y,
                mask,
                xla::Literal::scalar(lr),
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "sgd_step returns 2 outputs");
        let new_params = outs[0].to_vec::<f32>().map_err(xerr)?;
        anyhow::ensure!(new_params.len() == self.entry.dim, "sgd output dim");
        params.0 = new_params;
        Ok(LossSums {
            loss_sum: Self::scalar_f32(&outs[1])?,
            correct: f64::NAN, // sgd artifact does not report accuracy
            count: batch.real_count(),
        })
    }
}
