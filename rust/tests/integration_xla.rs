//! Integration tests over the full XLA/PJRT path: Rust coordinator →
//! compiled HLO artifacts → JAX/Pallas compute. Skipped gracefully (with a
//! loud eprintln) when `artifacts/` has not been built, so plain
//! `cargo test` stays green pre-`make artifacts`.

use std::sync::Arc;

use zowarmup::config::Scale;
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::{ClientData, Source};
use zowarmup::data::synthetic::{generate, train_test, GenConfig, SynthKind};
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::model::backend::ModelBackend;
use zowarmup::model::manifest::Manifest;
use zowarmup::model::params::ParamVec;
use zowarmup::runtime::Engine;
use zowarmup::util::rng::Distribution;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP xla integration: {e}");
            None
        }
    }
}

fn image_batch(backend_batch: usize, seed: u64) -> zowarmup::model::backend::Batch {
    let data = generate(SynthKind::Synth10, backend_batch, GenConfig { seed, ..Default::default() });
    let cd = ClientData {
        source: Source::Image(Arc::new(data)),
        indices: (0..backend_batch).collect(),
    };
    cd.chunks(backend_batch).pop().unwrap()
}

#[test]
fn manifest_validates_and_all_models_present() {
    let Some(m) = manifest() else { return };
    m.validate().unwrap();
    for name in ["cnn10", "cnn10_half", "cnn100", "cnn100_half", "vit10", "lm"] {
        assert!(m.models.contains_key(name), "missing model {name}");
    }
}

#[test]
fn cnn_init_loss_is_near_uniform_and_sgd_learns() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let backend = engine.backend(&m, "cnn10").unwrap();
    let entry = m.model("cnn10").unwrap();
    let mut params = ParamVec::he_init(entry, 0);
    let batch = image_batch(entry.batch, 0);
    let init = backend.fwd_loss(&params, &batch).unwrap();
    // He-init CE should be in the ballpark of ln(10) ≈ 2.30
    assert!(
        (1.5..5.0).contains(&init.mean_loss()),
        "init loss {}",
        init.mean_loss()
    );
    for _ in 0..8 {
        backend.sgd_step(&mut params, &batch, 0.05).unwrap();
    }
    let after = backend.fwd_loss(&params, &batch).unwrap();
    assert!(
        after.mean_loss() < init.mean_loss() - 0.2,
        "sgd must learn: {} -> {}",
        init.mean_loss(),
        after.mean_loss()
    );
    assert!(params.is_finite());
}

#[test]
fn host_zo_delta_is_antisymmetric_and_seed_dependent() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let backend = engine.backend(&m, "cnn10").unwrap();
    let entry = m.model("cnn10").unwrap();
    let params = ParamVec::he_init(entry, 1);
    let batch = image_batch(entry.batch, 1);
    let d1 = backend
        .zo_delta(&params, &batch, 5, 1e-3, 0.75, Distribution::Rademacher)
        .unwrap();
    let d1_neg = backend
        .zo_delta(&params, &batch, 5, -1e-3, 0.75, Distribution::Rademacher)
        .unwrap();
    assert!((d1 + d1_neg).abs() < 1e-4 * d1.abs().max(1.0), "{d1} vs {d1_neg}");
    let d2 = backend
        .zo_delta(&params, &batch, 6, 1e-3, 0.75, Distribution::Rademacher)
        .unwrap();
    assert_ne!(d1, d2);
}

#[test]
fn fused_zo_delta_matches_host_semantics() {
    // different PRNGs → different z per seed, but the *law* must match:
    // coeff=0 gives exactly 0, and magnitudes are comparable across seeds.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let backend = engine.backend(&m, "cnn10").unwrap();
    let entry = m.model("cnn10").unwrap();
    let params = ParamVec::he_init(entry, 2);
    let batch = image_batch(entry.batch, 2);
    let zero = backend.zo_delta_fused(&params, &batch, 3, 0.0).unwrap();
    assert_eq!(zero, 0.0);
    let host: Vec<f64> = (0..4)
        .map(|s| {
            backend
                .zo_delta(&params, &batch, s, 1e-3, 0.75, Distribution::Rademacher)
                .unwrap()
                .abs()
        })
        .collect();
    let fused: Vec<f64> = (0..4)
        .map(|s| backend.zo_delta_fused(&params, &batch, s, 7.5e-4).unwrap().abs())
        .collect();
    let mh = host.iter().sum::<f64>() / 4.0;
    let mf = fused.iter().sum::<f64>() / 4.0;
    assert!(
        mf > mh / 10.0 && mf < mh * 10.0,
        "fused |ΔL| {mf} vs host {mh} out of family"
    );
}

#[test]
fn mini_federation_over_xla_runs_both_phases() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let backend = engine.backend(&m, "cnn10").unwrap();
    let entry = m.model("cnn10").unwrap();

    let mut cfg = Scale::Smoke.fed();
    cfg.clients = 4;
    cfg.hi_frac = 0.5;
    cfg.rounds_total = 4;
    cfg.pivot = 2;
    cfg.sample_warm = 2;
    cfg.sample_zo = 2;
    cfg.local_epochs = 1;
    cfg.batch = entry.batch;
    cfg.eval_every = 1;
    cfg.lr_client_warm = 0.05;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;

    let (train, test) = train_test(SynthKind::Synth10, 128, 64, 0);
    let part = dirichlet_split(&train, cfg.clients, 0.5, 0);
    let src = Source::Image(Arc::new(train));
    let shards = shards_from_partition(&src, &part);
    let init = ParamVec::he_init(entry, 0);
    let mut fed =
        Federation::new(cfg, &backend, shards, Source::Image(Arc::new(test)), init).unwrap();
    fed.run().unwrap();
    assert!(fed.global.is_finite());
    assert_eq!(fed.log.rounds.len(), 4);
    assert!(fed.log.final_accuracy().is_finite());
    // ZO rounds transmitted only seed-sized payloads
    let zo_up = fed.log.rounds.last().unwrap().bytes_up;
    assert!(zo_up <= (fed.cfg.zo.s_seeds * 4 * fed.cfg.sample_zo) as u64);
}

#[test]
fn lm_backend_fwd_and_half_cnn_slice_map() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    // lm forward
    let lm_backend = engine.backend(&m, "lm").unwrap();
    let lm_entry = m.model("lm").unwrap();
    let data = zowarmup::data::lm::generate(64, 64, lm_entry.batch, 0);
    let cd = ClientData {
        source: Source::Lm(Arc::new(data)),
        indices: (0..lm_entry.batch).collect(),
    };
    let batch = cd.chunks(lm_entry.batch).pop().unwrap();
    let params = ParamVec::he_init(lm_entry, 0);
    let sums = lm_backend.fwd_loss(&params, &batch).unwrap();
    assert!((2.0..6.0).contains(&sums.mean_loss()), "{}", sums.mean_loss());

    // HeteroFL slice map derives mechanically from the manifest pair
    let full = m.model("cnn10").unwrap();
    let half = m.model("cnn10_half").unwrap();
    let map = zowarmup::baselines::SliceMap::from_manifest_pair(full, half).unwrap();
    assert_eq!(map.half_dim(), half.dim);
    assert_eq!(map.full_dim, full.dim);
    // slicing He-init params gives finite values at the right positions
    let fp = ParamVec::he_init(full, 3);
    let hp = map.slice(&fp);
    assert_eq!(hp.dim(), half.dim);
    assert!(hp.is_finite());
}
