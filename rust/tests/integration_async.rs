//! Integration tests for the buffered-async round engine: deterministic
//! event traces and bit-identical results at every worker count, real
//! staleness under a heterogeneous fleet, and the sync default left
//! untouched.

use std::sync::Arc;

use zowarmup::config::{EngineKind, FedConfig, Scale};
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test, SynthKind};
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::fed::AsyncEvent;
use zowarmup::metrics::Phase;
use zowarmup::model::backend::{LinearBackend, ModelBackend};
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn probe() -> LinearBackend {
    LinearBackend::pooled(32 * 32 * 3, 2, 10, 32)
}

fn setup(cfg: &FedConfig) -> (Vec<zowarmup::data::loader::ClientData>, Source) {
    let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
    let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
    let src = Source::Image(Arc::new(train));
    (
        shards_from_partition(&src, &part),
        Source::Image(Arc::new(test)),
    )
}

/// Pinned async scenario: a wide compute spread (8–10x) with no
/// deadline, so slow dispatches straddle several logical rounds and
/// arrive genuinely stale, and a small failure rate so the drop path is
/// exercised without starving the buffer.
fn async_scenario() -> Scenario {
    Scenario::load(
        r#"{"name": "async-mix", "deadline_ms": 0,
            "tiers": [
              {"name": "fast", "frac": 0.5, "mem": "backprop",
               "up_mbps": 80, "down_mbps": 80, "compute": 4.0},
              {"name": "slow", "frac": 0.5, "mem": "zo",
               "up_mbps": 4, "down_mbps": 8, "compute": 0.4,
               "drop_rate": 0.15}
            ]}"#,
    )
    .unwrap()
}

fn async_cfg(threads: usize) -> FedConfig {
    let mut cfg = Scale::Smoke.fed();
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    cfg.threads = threads;
    cfg.rounds_total = 20;
    cfg.pivot = 2;
    cfg.eval_every = 4;
    cfg.ckpt_every = 2;
    cfg.engine = EngineKind::Async;
    cfg.async_zo.buffer_k = 3;
    cfg.async_zo.arrival_rate = 0.05;
    cfg.scenario = async_scenario();
    cfg
}

fn run_async(threads: usize) -> (
    ParamVec,
    Vec<AsyncEvent>,
    zowarmup::metrics::RunLog,
    zowarmup::comm::CommLedger,
) {
    let cfg = async_cfg(threads);
    let (shards, test) = setup(&cfg);
    let be = probe();
    let init = ParamVec::zeros(be.dim());
    let mut fed = Federation::new(cfg, &be, shards, test, init).unwrap();
    fed.run().unwrap();
    (
        fed.global.clone(),
        fed.async_trace().to_vec(),
        fed.log.clone(),
        fed.ledger.clone(),
    )
}

#[test]
fn async_engine_is_bit_identical_across_workers() {
    // acceptance: the event-driven engine is deterministic because event
    // *ordering* decides everything — worker counts {1, 2, 4} must yield
    // byte-identical event traces, logs, ledgers, and final parameters.
    let (g1, tr1, log1, led1) = run_async(1);
    let (g2, tr2, log2, led2) = run_async(2);
    let (g4, tr4, log4, led4) = run_async(4);

    assert!(!tr1.is_empty(), "async rounds must fold completion events");
    for (trace, tag) in [(&tr2, "2"), (&tr4, "4")] {
        assert_eq!(trace.len(), tr1.len(), "trace length (threads {tag})");
        for (a, b) in tr1.iter().zip(trace.iter()) {
            assert_eq!(a.t_ms.to_bits(), b.t_ms.to_bits(), "event time (threads {tag})");
            assert_eq!(
                (a.seq, a.cid, a.version, a.survived),
                (b.seq, b.cid, b.version, b.survived),
                "event identity (threads {tag})"
            );
        }
    }
    assert_eq!(g1, g2, "weights must not depend on threads");
    assert_eq!(g1, g4, "weights must not depend on threads");
    for (led, tag) in [(&led2, "2"), (&led4, "4")] {
        assert_eq!((led1.up_total, led1.down_total), (led.up_total, led.down_total), "threads {tag}");
        assert_eq!(led1.catch_up_down_total, led.catch_up_down_total, "threads {tag}");
        assert_eq!(led1.seeds_total, led.seeds_total, "threads {tag}");
    }
    for (log, tag) in [(&log2, "2"), (&log4, "4")] {
        assert_eq!(log1.rounds.len(), log.rounds.len());
        for (a, b) in log1.rounds.iter().zip(&log.rounds) {
            // everything except the host wall clock must be bit-equal
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "threads {tag}");
            assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "threads {tag}");
            assert_eq!(
                (a.bytes_up, a.bytes_down, a.dropped, a.catch_up_down, a.seeds_issued),
                (b.bytes_up, b.bytes_down, b.dropped, b.catch_up_down, b.seeds_issued),
                "threads {tag}"
            );
            assert_eq!(a.eff_var.to_bits(), b.eff_var.to_bits(), "threads {tag}");
            assert_eq!(a.staleness.to_bits(), b.staleness.to_bits(), "threads {tag}");
            assert_eq!(a.model_version, b.model_version, "threads {tag}");
            assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits(), "threads {tag}");
        }
    }

    // the scenario must actually exercise the async semantics:
    // out-of-version arrivals, a moving version counter, event-clock time
    let event_clock_monotone = tr1.windows(2).all(|w| w[0].t_ms <= w[1].t_ms);
    assert!(event_clock_monotone, "completion events must pop in time order");
    assert!(
        log1.rounds.iter().any(|r| r.phase == Phase::Zo && r.staleness > 0.0),
        "the compute spread must produce at least one stale fold"
    );
    assert!(
        log1.rounds.last().unwrap().model_version > 2,
        "parameter-mutating folds must advance the version counter"
    );
    assert!(
        log1.rounds.iter().any(|r| r.phase == Phase::Zo && r.makespan_ms > 0.0),
        "folds must consume event-clock time"
    );
    assert!(log1.total_dropped() > 0, "the flaky tier should drop someone");
    assert!(led1.catch_up_down_total > 0, "stale dispatches must pay catch-up");
    assert!(g1.is_finite());
    assert!(log1.final_accuracy() > 0.2, "async training should still learn");
}

#[test]
fn sync_default_is_untouched_by_the_async_engine() {
    // the default engine stays the barrier: no async state, no trace, a
    // zero staleness column — the golden-trace fixture pins the full
    // bit-identity, this pins the engine selection itself.
    assert_eq!(FedConfig::default().engine, EngineKind::Sync);
    let mut cfg = Scale::Smoke.fed();
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    cfg.rounds_total = 4;
    cfg.pivot = 1;
    let (shards, test) = setup(&cfg);
    let be = probe();
    let mut fed =
        Federation::new(cfg, &be, shards, test, ParamVec::zeros(be.dim())).unwrap();
    fed.run().unwrap();
    assert!(fed.async_trace().is_empty(), "sync runs must not build event state");
    assert!(fed.log.rounds.iter().all(|r| r.staleness == 0.0));
    assert!(fed.log.mean_staleness() == 0.0);
}
