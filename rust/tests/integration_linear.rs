//! Integration tests over the full federated stack with the host-side
//! probe backend: the paper's qualitative claims at miniature scale, plus
//! cross-module wiring (metrics, comm ledger, config plumbing).

use zowarmup::config::{DataConfig, Scale};
use zowarmup::data::synthetic::SynthKind;
use zowarmup::exp::common::{run_method, Method};
use zowarmup::metrics::Phase;

fn default_cfg(hi_frac: f64, seed: u64) -> (zowarmup::config::FedConfig, DataConfig) {
    // between smoke and default: big enough for ordering to show, small
    // enough for CI
    let mut cfg = Scale::Smoke.fed();
    cfg.clients = 10;
    cfg.rounds_total = 56;
    cfg.pivot = 16;
    cfg.sample_warm = 4;
    cfg.sample_zo = 5;
    cfg.local_epochs = 3;
    cfg.hi_frac = hi_frac;
    cfg.seed = seed;
    cfg.eval_every = 4;
    let data = DataConfig {
        n_train: 1000,
        n_test: 300,
        ..DataConfig::default()
    };
    (cfg, data)
}

#[test]
fn zowarmup_beats_high_res_only_at_10_90() {
    // Table 2's headline ordering, averaged over 2 seeds.
    let mut wins = 0;
    for seed in 0..2 {
        let (cfg, data) = default_cfg(0.1, seed);
        let zo = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
            .unwrap()
            .final_accuracy();
        let hi = run_method(Method::HighResOnly, SynthKind::Synth10, &data, &cfg)
            .unwrap()
            .final_accuracy();
        if zo > hi {
            wins += 1;
        }
        eprintln!("seed {seed}: zowarmup {zo:.3} vs highres {hi:.3}");
    }
    assert!(wins >= 1, "ZOWarmUp should beat High-Res-Only at 10/90");
}

#[test]
fn zo_phase_keeps_improving_test_loss_at_10_90() {
    // Figure 3's phenomenon at integration scale: once low-res clients
    // join, the *test loss* keeps falling (their data is new information)
    // and accuracy does not collapse. The accuracy jump itself is
    // validated at experiment scale (exp fig3 / EXPERIMENTS.md).
    let (mut cfg, data) = default_cfg(0.1, 0);
    cfg.eval_every = 2;
    let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg).unwrap();
    let losses: Vec<(usize, f64)> = log
        .rounds
        .iter()
        .filter(|r| !r.test_loss.is_nan())
        .map(|r| (r.round, r.test_loss))
        .collect();
    let at_pivot = losses
        .iter()
        .filter(|(r, _)| *r < cfg.pivot)
        .map(|(_, l)| *l)
        .last()
        .unwrap();
    let final_loss = losses.last().unwrap().1;
    assert!(
        final_loss < at_pivot - 0.05,
        "test loss should fall through the ZO phase: {at_pivot:.3} -> {final_loss:.3}"
    );
    let curve = log.accuracy_curve();
    let acc_pivot = curve
        .iter()
        .filter(|(r, _)| *r < cfg.pivot)
        .map(|(_, a)| *a)
        .last()
        .unwrap();
    assert!(
        log.final_accuracy() > acc_pivot - 0.03,
        "accuracy must not collapse: {acc_pivot:.3} -> {:.3}",
        log.final_accuracy()
    );
}

#[test]
fn comm_ledger_reflects_protocol_phases() {
    let (cfg, data) = default_cfg(0.5, 0);
    let log = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg).unwrap();
    let warm_bytes: u64 = log
        .rounds
        .iter()
        .filter(|r| r.phase == Phase::Warm)
        .map(|r| r.bytes_up)
        .sum();
    let zo_bytes: u64 = log
        .rounds
        .iter()
        .filter(|r| r.phase == Phase::Zo)
        .map(|r| r.bytes_up)
        .sum();
    // warm: full weights; zo: S scalars — orders apart even summed
    assert!(warm_bytes > zo_bytes * 400, "{warm_bytes} vs {zo_bytes}");
    // ZO up-link per round per client is exactly S*4 bytes
    let zo_round = log
        .rounds
        .iter()
        .find(|r| r.phase == Phase::Zo)
        .unwrap();
    assert_eq!(
        zo_round.bytes_up,
        (cfg.zo.s_seeds * 4) as u64 * cfg.sample_zo as u64
    );
}

#[test]
fn fedkseed_warm_beats_cold_on_probe() {
    let (cfg, data) = default_cfg(0.3, 1);
    let warm = run_method(Method::ZoWarmupFedKSeed, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    let cold = run_method(Method::FedKSeedCold, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    eprintln!("fedkseed warm {warm:.3} vs cold {cold:.3}");
    assert!(warm > cold, "warm-started FedKSeed must beat cold ({warm} vs {cold})");
}

#[test]
fn more_grad_steps_is_not_better() {
    // Table 3's direction: 1 step (τ=0.75) >= 6 steps (τ=0.01), same data.
    let (mut cfg, data) = default_cfg(0.5, 2);
    cfg.zo.grad_steps = 1;
    cfg.zo.tau = 0.75;
    let one = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    cfg.zo.grad_steps = 6;
    cfg.zo.tau = 0.01;
    let six = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    eprintln!("1 step {one:.3} vs 6 steps {six:.3}");
    assert!(one + 0.02 >= six, "multi-step should not win ({one} vs {six})");
}

#[test]
fn synth100_runs_and_is_harder() {
    let (cfg, mut data) = default_cfg(0.5, 0);
    data.dataset = "synth100".into();
    let acc100 = run_method(Method::ZoWarmup, SynthKind::Synth100, &data, &cfg)
        .unwrap()
        .final_accuracy();
    data.dataset = "synth10".into();
    let acc10 = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    assert!(acc100 > 0.015, "must beat random on 100 classes: {acc100}");
    assert!(acc10 > acc100, "100-class task must be harder");
}

#[test]
fn heterofl_budget_limits_rounds() {
    // the paper's fixed-budget rule: HeteroFL gets fewer rounds as the
    // high-resource fraction grows — reflected in its logged round count.
    let (cfg_lo, data) = default_cfg(0.1, 0);
    let (cfg_hi, _) = default_cfg(0.9, 0);
    let lo_rounds = run_method(Method::HeteroFl, SynthKind::Synth10, &data, &cfg_lo)
        .unwrap()
        .rounds
        .len();
    let hi_rounds = run_method(Method::HeteroFl, SynthKind::Synth10, &data, &cfg_hi)
        .unwrap()
        .rounds
        .len();
    assert!(
        hi_rounds <= lo_rounds,
        "budget should shrink rounds as hi_frac grows ({lo_rounds} vs {hi_rounds})"
    );
}

#[test]
fn run_is_reproducible_per_seed_and_varies_across_seeds() {
    let (cfg, data) = default_cfg(0.3, 7);
    let a = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    let b = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg)
        .unwrap()
        .final_accuracy();
    assert_eq!(a, b);
    let (cfg2, _) = default_cfg(0.3, 8);
    let c = run_method(Method::ZoWarmup, SynthKind::Synth10, &data, &cfg2)
        .unwrap()
        .final_accuracy();
    assert_ne!(a, c);
}
