//! Golden-trace determinism: a fixed-seed 3-round smoke run — one warm
//! round plus two ZO rounds under a straggler-drop scenario — is hashed
//! (final params, ledger totals, per-round byte/drop/signal trace) and
//! pinned against a committed fixture, and must stay bit-identical for
//! every worker count (extends `thread_count_does_not_change_results`).
//!
//! The fixture ships as an `unblessed` sentinel because the build sandbox
//! has no Rust toolchain: the first toolchain-equipped run writes the
//! real hash into `tests/fixtures/golden_trace.txt` (commit it), and
//! every later run — any machine, any thread count — must reproduce it
//! exactly. To re-bless intentionally, reset the file to `unblessed`.

use std::sync::Arc;

use zowarmup::config::{FedConfig, KernelKind, Scale};
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test, SynthKind};
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::metrics::RunLog;
use zowarmup::model::backend::{LinearBackend, ModelBackend};
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

const FIXTURE: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/fixtures/golden_trace.txt"
);

/// The lanes kernel defines its own perturbation stream (per-lane
/// split keys), so it gets its own fixture — pinned with the same
/// bless-once protocol as the scalar one.
const FIXTURE_LANES: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/fixtures/golden_trace_lanes.txt"
);

/// The pinned scenario is spelled inline (not a preset) so future preset
/// tuning cannot silently invalidate the fixture.
const SCENARIO: &str = r#"{
  "name": "golden-stragglers",
  "deadline_ms": 5.0,
  "tiers": [
    {"name": "fast", "frac": 0.5, "mem": "backprop",
     "up_mbps": 100, "down_mbps": 100, "compute": 8.0, "drop_rate": 0.3},
    {"name": "slow", "frac": 0.5, "mem": "zo",
     "up_mbps": 0.01, "down_mbps": 0.01, "compute": 0.05, "drop_rate": 0.2}
  ]
}"#;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    bytes.iter().fold(h, |h, &b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    })
}

fn fnv_u64(h: u64, v: u64) -> u64 {
    fnv1a(h, &v.to_le_bytes())
}

fn golden_cfg(threads: usize) -> FedConfig {
    let mut cfg = Scale::Smoke.fed();
    cfg.rounds_total = 3;
    cfg.pivot = 1;
    cfg.eval_every = 1;
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    cfg.seed = 7;
    cfg.threads = threads;
    cfg.scenario = Scenario::load(SCENARIO).unwrap();
    cfg
}

fn run(threads: usize) -> (ParamVec, RunLog, u64, u64) {
    run_kernel(threads, KernelKind::Scalar)
}

fn run_kernel(threads: usize, kernel: KernelKind) -> (ParamVec, RunLog, u64, u64) {
    let mut cfg = golden_cfg(threads);
    cfg.zo.kernel = kernel;
    let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
    let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
    let src = Source::Image(Arc::new(train));
    let shards = shards_from_partition(&src, &part);
    let be = LinearBackend::pooled(32 * 32 * 3, 2, 10, 32);
    let init = ParamVec::zeros(be.dim());
    let mut fed = Federation::new(cfg, &be, shards, Source::Image(Arc::new(test)), init).unwrap();
    fed.run().unwrap();
    (
        fed.global.clone(),
        fed.log.clone(),
        fed.ledger.up_total,
        fed.ledger.down_total,
    )
}

fn trace_hash(global: &ParamVec, log: &RunLog, up: u64, down: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for r in &log.rounds {
        h = fnv_u64(h, r.round as u64);
        h = fnv_u64(h, r.train_loss.to_bits());
        h = fnv_u64(h, r.bytes_up);
        h = fnv_u64(h, r.bytes_down);
        h = fnv_u64(h, r.dropped as u64);
    }
    for w in &global.0 {
        h = fnv1a(h, &w.to_bits().to_le_bytes());
    }
    h = fnv_u64(h, up);
    fnv_u64(h, down)
}

#[test]
fn golden_trace_is_thread_invariant_and_pinned() {
    let (g1, log1, up1, down1) = run(1);
    // the straggler scenario must actually exercise the drop path,
    // otherwise the fixture pins nothing interesting
    let dropped: usize = log1.rounds.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "golden scenario should drop clients");
    assert!(g1.is_finite());
    assert!(log1.rounds.iter().all(|r| r.train_loss.is_finite()));

    let h1 = trace_hash(&g1, &log1, up1, down1);
    for threads in [2usize, 4] {
        let (g, log, up, down) = run(threads);
        assert_eq!(g1, g, "weights diverged at threads={threads}");
        assert_eq!(
            h1,
            trace_hash(&g, &log, up, down),
            "trace diverged at threads={threads}"
        );
    }

    pin_against(FIXTURE, h1);
}

/// Compare `hash` against the committed fixture at `path`, blessing it
/// in place (for a later commit) while the file still says `unblessed`.
fn pin_against(path: &str, hash: u64) {
    let line = format!("fnv64:{hash:016x}");
    match std::fs::read_to_string(path).ok().as_deref().map(str::trim) {
        Some(committed) if committed.starts_with("fnv64:") => {
            assert_eq!(
                committed, line,
                "golden trace drifted from the committed fixture; if the \
                 change is intentional, reset {path} to `unblessed`"
            );
        }
        _ => {
            std::fs::write(path, format!("{line}\n")).unwrap();
            eprintln!("blessed golden trace fixture: {line} (commit {path})");
        }
    }
}

/// The opt-in lanes kernel is a different (but fixed) stream: it must be
/// thread-invariant and pinned like the scalar path, and must NOT
/// reproduce the scalar trace — if the two hashes ever collide, the
/// kernels have silently merged and the opt-in knob is dead.
#[test]
fn golden_trace_lanes_is_thread_invariant_and_pinned() {
    let (g1, log1, up1, down1) = run_kernel(1, KernelKind::Lanes);
    let dropped: usize = log1.rounds.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "golden scenario should drop clients");
    assert!(g1.is_finite());
    assert!(log1.rounds.iter().all(|r| r.train_loss.is_finite()));

    let h1 = trace_hash(&g1, &log1, up1, down1);
    for threads in [2usize, 4] {
        let (g, log, up, down) = run_kernel(threads, KernelKind::Lanes);
        assert_eq!(g1, g, "lanes weights diverged at threads={threads}");
        assert_eq!(
            h1,
            trace_hash(&g, &log, up, down),
            "lanes trace diverged at threads={threads}"
        );
    }

    let (gs, logs, ups, downs) = run(1);
    assert_ne!(
        h1,
        trace_hash(&gs, &logs, ups, downs),
        "lanes kernel reproduced the scalar trace — streams must differ"
    );

    pin_against(FIXTURE_LANES, h1);
}
