//! Cross-mode equivalence matrix: smoke-train every combination of
//! {engine sync/async} × {kernel scalar/lanes} × {population
//! materialized/lazy} × {edges 1/4}, at workers {1, 2, 4}, and pin the
//! equivalence classes the repo's determinism contract promises:
//!
//! * **within every mode**: worker counts are bit-identical — params,
//!   ledgers, per-round logs, and the checkpoint seed log;
//! * **edges 1 vs 4** (plain scenario, same everything else): byte-
//!   identical — the two-tier fold merges edge partials in edge-index
//!   order back to the exact flat item list, and per-edge ledgers are a
//!   pure sub-attribution (DESIGN.md §13);
//! * **lazy vs materialized**: byte-identical when the materialized
//!   population mirrors the lazy derivation (below the warm enumeration
//!   threshold, where lazy warm sampling enumerates exactly like the
//!   materialized path);
//! * **scalar vs lanes**, **sync vs async**: merely finite — different
//!   seed schedules / fold semantics, pinned as *different* so an
//!   accidental unification (or a kernel that silently falls back)
//!   fails loudly.

use std::sync::Arc;

use zowarmup::config::{EngineKind, FedConfig, KernelKind, Scale};
use zowarmup::data::dirichlet::dirichlet_split;
use zowarmup::data::loader::Source;
use zowarmup::data::synthetic::{train_test, SynthKind};
use zowarmup::fed::server::{shards_from_partition, Federation};
use zowarmup::fed::{clients_from_profiles, Population};
use zowarmup::model::backend::{LinearBackend, ModelBackend};
use zowarmup::model::params::ParamVec;
use zowarmup::sim::Scenario;

fn probe() -> LinearBackend {
    LinearBackend::pooled(32 * 32 * 3, 2, 10, 32)
}

/// Plain capability spread — a fast FO-capable tier and a slow flaky ZO
/// tier, NO `edges` list — so `--edges E` stays pure attribution and the
/// edges-1-vs-4 byte-identity class is exercised, not vacuous.
fn plain_scenario() -> Scenario {
    Scenario::load(
        r#"{"name": "matrix-mix", "deadline_ms": 0,
            "tiers": [
              {"name": "fast", "frac": 0.5, "mem": "backprop",
               "up_mbps": 80, "down_mbps": 80, "compute": 4.0},
              {"name": "slow", "frac": 0.5, "mem": "zo",
               "up_mbps": 4, "down_mbps": 8, "compute": 0.4,
               "drop_rate": 0.15}
            ]}"#,
    )
    .unwrap()
}

fn base_cfg(threads: usize) -> FedConfig {
    let mut cfg = Scale::Smoke.fed();
    cfg.clients = 24;
    cfg.sample_warm = 4;
    cfg.sample_zo = 8;
    cfg.rounds_total = 10;
    cfg.pivot = 2;
    cfg.eval_every = 4;
    cfg.ckpt_every = 2;
    cfg.threads = threads;
    cfg.lr_client_warm = 0.06;
    cfg.lr_client_zo = 1.0;
    cfg.lr_server_zo = 0.01;
    cfg.zo.eps = 1e-3;
    cfg.async_zo.buffer_k = 3;
    cfg.async_zo.arrival_rate = 0.05;
    cfg.scenario = plain_scenario();
    cfg
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Mode {
    engine: EngineKind,
    kernel: KernelKind,
    lazy: bool,
    edges: usize,
}

/// Everything a run leaves behind that the contract speaks about.
struct Outcome {
    global: ParamVec,
    log: zowarmup::metrics::RunLog,
    ledger: zowarmup::comm::CommLedger,
    /// the live checkpoint seed log: (round, fused items)
    tail: Vec<(usize, Vec<(u64, f32)>)>,
}

fn run_mode(m: Mode, threads: usize) -> Outcome {
    let mut cfg = base_cfg(threads);
    cfg.engine = m.engine;
    cfg.zo.kernel = m.kernel;
    cfg.edges = m.edges;
    let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
    let be = probe();
    let init = ParamVec::zeros(be.dim());
    let test_src = Source::Image(Arc::new(test));
    let mut fed = if m.lazy {
        Federation::new_lazy(cfg, &be, Source::Image(Arc::new(train)), test_src, init)
            .unwrap()
    } else {
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        Federation::new(cfg, &be, shards, test_src, init).unwrap()
    };
    fed.run().unwrap();
    Outcome {
        global: fed.global.clone(),
        log: fed.log.clone(),
        ledger: fed.ledger.clone(),
        tail: fed
            .ckpt
            .tail_log()
            .iter()
            .map(|e| (e.round, e.items.clone()))
            .collect(),
    }
}

/// Bit-level equality of two outcomes (host wall clock excluded).
fn assert_outcomes_identical(a: &Outcome, b: &Outcome, what: &str) {
    assert_eq!(a.global, b.global, "{what}: weights");
    assert_eq!(
        (a.ledger.up_total, a.ledger.down_total),
        (b.ledger.up_total, b.ledger.down_total),
        "{what}: ledger totals"
    );
    assert_eq!(a.ledger.per_round, b.ledger.per_round, "{what}: per-round ledger");
    assert_eq!(
        a.ledger.catch_up_down_total, b.ledger.catch_up_down_total,
        "{what}: catch-up"
    );
    assert_eq!(a.ledger.seeds_total, b.ledger.seeds_total, "{what}: seeds");
    assert_eq!(a.log.rounds.len(), b.log.rounds.len(), "{what}: round count");
    for (x, y) in a.log.rounds.iter().zip(&b.log.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what}: train");
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{what}: acc");
        assert_eq!(
            (x.bytes_up, x.bytes_down, x.dropped, x.catch_up_down, x.seeds_issued),
            (y.bytes_up, y.bytes_down, y.dropped, y.catch_up_down, y.seeds_issued),
            "{what}: round bytes/drops"
        );
        assert_eq!(x.eff_var.to_bits(), y.eff_var.to_bits(), "{what}: eff_var");
        assert_eq!(x.staleness.to_bits(), y.staleness.to_bits(), "{what}: staleness");
        assert_eq!(x.model_version, y.model_version, "{what}: version");
        assert_eq!(x.makespan_ms.to_bits(), y.makespan_ms.to_bits(), "{what}: makespan");
        assert_eq!(x.edge_drops, y.edge_drops, "{what}: edge_drops");
    }
    assert_eq!(a.tail.len(), b.tail.len(), "{what}: seed-log tail length");
    for ((ra, ia), (rb, ib)) in a.tail.iter().zip(&b.tail) {
        assert_eq!(ra, rb, "{what}: tail round");
        assert_eq!(ia.len(), ib.len(), "{what}: tail items");
        for (x, y) in ia.iter().zip(ib) {
            assert_eq!(x.0, y.0, "{what}: tail seed");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{what}: tail coeff");
        }
    }
}

#[test]
fn cross_mode_matrix_pins_equivalence_classes() {
    let engines = [EngineKind::Sync, EngineKind::Async];
    let kernels = [KernelKind::Scalar, KernelKind::Lanes];
    let mut outcomes: Vec<(Mode, Outcome)> = Vec::new();
    for &engine in &engines {
        for &kernel in &kernels {
            for &lazy in &[false, true] {
                for &edges in &[1usize, 4] {
                    let m = Mode { engine, kernel, lazy, edges };
                    // thread bit-identity within the mode
                    let o1 = run_mode(m, 1);
                    let o2 = run_mode(m, 2);
                    let o4 = run_mode(m, 4);
                    assert_outcomes_identical(&o1, &o2, &format!("{m:?} w1-vs-w2"));
                    assert_outcomes_identical(&o1, &o4, &format!("{m:?} w1-vs-w4"));
                    assert!(o1.global.is_finite(), "{m:?}: weights finite");
                    assert!(!o1.tail.is_empty(), "{m:?}: ckpt must log seed rounds");
                    outcomes.push((m, o1));
                }
            }
        }
    }
    let find = |m: Mode| -> &Outcome {
        &outcomes.iter().find(|(x, _)| *x == m).unwrap().1
    };
    for &engine in &engines {
        for &kernel in &kernels {
            for &lazy in &[false, true] {
                // byte-identical pair: edges 1 vs 4 on a plain scenario.
                // The two-tier fold merges to the flat item list and the
                // edge ledger is sub-attribution, so every trace matches.
                let flat = find(Mode { engine, kernel, lazy, edges: 1 });
                let tiered = find(Mode { engine, kernel, lazy, edges: 4 });
                let what = format!("{engine:?}/{kernel:?}/lazy={lazy} edges 1-vs-4");
                assert_outcomes_identical(flat, tiered, &what);
                // ... and the attribution itself: flat runs keep no
                // per-edge table, two-tier tables reduce to flat totals
                assert!(flat.ledger.per_edge.is_empty(), "{what}: flat per-edge table");
                assert!(!tiered.ledger.per_edge.is_empty(), "{what}: tiered table");
                let (eu, ed, ec) = tiered.ledger.edge_totals();
                assert_eq!(
                    (eu, ed, ec),
                    (
                        tiered.ledger.up_total,
                        tiered.ledger.down_total,
                        tiered.ledger.catch_up_down_total
                    ),
                    "{what}: per-edge reduction"
                );
            }
        }
        // merely finite: scalar vs lanes run different perturbation
        // schedules — pinned as different so a silent fallback to the
        // scalar path can never pass for lane coverage
        let scalar = find(Mode { engine, kernel: KernelKind::Scalar, lazy: false, edges: 1 });
        let lanes = find(Mode { engine, kernel: KernelKind::Lanes, lazy: false, edges: 1 });
        assert_ne!(
            scalar.global, lanes.global,
            "{engine:?}: lanes must not collapse into the scalar schedule"
        );
    }
    // merely finite: sync vs async differ (buffered folds, staleness
    // weights); the async runs must actually exercise staleness
    let sync = find(Mode {
        engine: EngineKind::Sync,
        kernel: KernelKind::Scalar,
        lazy: false,
        edges: 1,
    });
    let asy = find(Mode {
        engine: EngineKind::Async,
        kernel: KernelKind::Scalar,
        lazy: false,
        edges: 1,
    });
    assert_ne!(sync.global, asy.global, "sync and async must stay distinct modes");
    assert!(sync.log.rounds.iter().all(|r| r.staleness == 0.0));
    assert!(asy.log.rounds.iter().any(|r| r.staleness > 0.0));
}

#[test]
fn lazy_mirrors_materialized_below_the_enum_threshold() {
    // byte-identity class: a materialized population holding exactly the
    // profiles and shards the lazy path derives (the `exp fleet`
    // materialization) is indistinguishable from the lazy run — below
    // the warm enumeration threshold lazy sampling IS the materialized
    // hi-list draw. This pins the population layer's equivalence claim
    // at the federation level, not just per-accessor.
    let run = |mirror: bool| {
        let cfg = base_cfg(2);
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let src = Source::Image(Arc::new(train));
        let test_src = Source::Image(Arc::new(test));
        let mut fed = if mirror {
            let lazy = Population::lazy(
                cfg.clients,
                cfg.hi_count(),
                cfg.seed,
                cfg.scenario.clone(),
                be.cost_model(),
                src,
            )
            .unwrap();
            let shards = (0..cfg.clients).map(|cid| lazy.data(cid)).collect();
            let profiles = (0..cfg.clients).map(|cid| lazy.profile(cid)).collect();
            let clients = clients_from_profiles(shards, profiles, &be.cost_model());
            Federation::with_population(
                cfg,
                &be,
                Population::materialized(clients),
                test_src,
                init,
            )
            .unwrap()
        } else {
            Federation::new_lazy(cfg, &be, src, test_src, init).unwrap()
        };
        fed.run().unwrap();
        (fed.global.clone(), fed.log.clone(), fed.ledger.clone())
    };
    let (g_lazy, log_lazy, led_lazy) = run(false);
    let (g_mat, log_mat, led_mat) = run(true);
    assert_eq!(g_lazy, g_mat, "mirrored materialization must be byte-identical");
    assert_eq!(led_lazy.per_round, led_mat.per_round);
    assert_eq!(led_lazy.catch_up_down_total, led_mat.catch_up_down_total);
    for (a, b) in log_lazy.rounds.iter().zip(&log_mat.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!((a.dropped, a.seeds_issued), (b.dropped, b.seeds_issued));
    }
}

#[test]
fn two_tier_fold_is_bit_identical_to_flat_at_scale_e() {
    // acceptance: E ∈ {1, 4, 16} × workers {1, 2, 4} all produce
    // bit-identical parameters, ledgers and seed logs on a plain
    // scenario — E=16 over 24 clients leaves some edges empty, which
    // must be harmless (empty partials, zero ledger rows)
    let run = |edges: usize, threads: usize| {
        run_mode(
            Mode {
                engine: EngineKind::Sync,
                kernel: KernelKind::Scalar,
                lazy: false,
                edges,
            },
            threads,
        )
    };
    let flat = run(1, 1);
    for edges in [1usize, 4, 16] {
        for threads in [1usize, 2, 4] {
            let o = run(edges, threads);
            assert_outcomes_identical(
                &flat,
                &o,
                &format!("E={edges} w={threads} vs flat"),
            );
            if edges > 1 {
                let (eu, ed, ec) = o.ledger.edge_totals();
                assert_eq!(
                    (eu, ed, ec),
                    (
                        o.ledger.up_total,
                        o.ledger.down_total,
                        o.ledger.catch_up_down_total
                    ),
                    "E={edges} w={threads}: per-edge reduction"
                );
                assert_eq!(o.ledger.per_edge.len(), edges, "table spans every edge");
            }
        }
    }
    assert!(flat.ledger.catch_up_down_total > 0, "churny fleet must pay catch-up");
}

#[test]
fn edge_failures_drop_whole_cohorts_only_under_edge_scenarios() {
    // the divergence half of the tentpole: a geo scenario declares edge
    // profiles, so a down aggregator drops its whole sampled cohort and
    // the round reports them as edge_drops (a subset of dropped) — while
    // the per-edge ledger still reduces exactly to the flat totals.
    let run = |edges: usize, engine: EngineKind, threads: usize| {
        let mut cfg = base_cfg(threads);
        cfg.engine = engine;
        cfg.edges = edges;
        // enough rounds that geo-iot's failing regions (rates 0.1/0.2,
        // keyed per (seed, round, edge)) all but surely go dark at least
        // once with a sampled cohort on them
        cfg.rounds_total = 32;
        // pure ZO: geo-iot's FO gateway tier is 5% of the fleet, too thin
        // to guarantee a warm-capable client at this population size
        cfg.pivot = 0;
        cfg.scenario = Scenario::preset("geo-iot").unwrap();
        let (train, test) = train_test(SynthKind::Synth10, 400, 120, cfg.seed);
        let be = probe();
        let init = ParamVec::zeros(be.dim());
        let part = dirichlet_split(&train, cfg.clients, 0.5, cfg.seed);
        let src = Source::Image(Arc::new(train));
        let shards = shards_from_partition(&src, &part);
        let mut fed =
            Federation::new(cfg, &be, shards, Source::Image(Arc::new(test)), init)
                .unwrap();
        fed.run().unwrap();
        (fed.log.clone(), fed.ledger.clone(), fed.global.clone())
    };
    for engine in [EngineKind::Sync, EngineKind::Async] {
        let (log, ledger, global) = run(4, engine, 1);
        assert!(global.is_finite(), "{engine:?}");
        assert!(
            log.total_edge_drops() > 0,
            "{engine:?}: geo-iot's failing regions must cost whole cohorts"
        );
        for r in &log.rounds {
            assert!(
                r.edge_drops <= r.dropped,
                "{engine:?}: edge drops are a subset of drops (round {})",
                r.round
            );
        }
        let (eu, ed, ec) = ledger.edge_totals();
        assert_eq!(
            (eu, ed, ec),
            (ledger.up_total, ledger.down_total, ledger.catch_up_down_total),
            "{engine:?}: per-edge reduction under edge failures"
        );
        // determinism survives the divergent topology
        let (log4, ledger4, global4) = run(4, engine, 4);
        assert_eq!(global, global4, "{engine:?}: weights vs threads");
        assert_eq!(ledger.per_round, ledger4.per_round, "{engine:?}");
        assert_eq!(ledger.per_edge, ledger4.per_edge, "{engine:?}");
        assert_eq!(
            log.total_edge_drops(),
            log4.total_edge_drops(),
            "{engine:?}: edge drops vs threads"
        );
    }
    // flat runs under the same geo scenario: edge 0 (metro) never fails,
    // so a single aggregator run records no edge drops at all
    let (log_flat, ledger_flat, _) = run(1, EngineKind::Sync, 1);
    assert_eq!(log_flat.total_edge_drops(), 0, "metro never goes dark");
    assert!(ledger_flat.per_edge.is_empty(), "flat runs keep no per-edge table");
}
